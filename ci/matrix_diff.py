#!/usr/bin/env python3
"""Scenario-matrix regression gate.

Compares two matrix reports written by ``feddd matrix`` (one-line-per-cell
JSON, see DESIGN.md §Scenario-Matrix) and exits non-zero when the current
report regressed. The rules mirror the in-binary compare mode
(``feddd matrix --compare``) exactly:

* cells match on their ``scenario/scheme/seed/tier`` key;
* **accuracy** may not drop by more than ``--tol-acc`` (default 0.01,
  absolute) — every cell runs on the fixed-seed virtual-clock machinery,
  so at equal code the value is exactly reproducible and a drop beyond
  tolerance is a real quality regression, not noise;
* the deterministic byte totals (``wire_bytes``, ``uploaded_bytes``) may
  not increase at all;
* a cell present only in the current report is reported as **new** but
  never fails the gate — there is no baseline for it, so no delta or
  ratio is ever computed (the undefined-division rule);
* a cell that **vanished** from the current report fails: a gate that
  silently stops covering a cell is itself a regression;
* an empty current report fails outright.

A baseline marked ``"bootstrap": true`` (the committed placeholder in
``reports/baseline_smoke.json``) skips the per-cell gates, still fails an
empty current report, and exits 0 with a loud reminder to promote a green
run's ``MATRIX_*.json`` via ``ci/arm_gates.py`` as the real baseline.

Only regressions (and new-cell notes) are printed — never the full table.

Usage:
    python3 ci/matrix_diff.py reports/MATRIX_smoke_base.json \
        matrix-out/MATRIX_smoke_ci.json --tol-acc 0.01 \
        --out matrix-out/MATRIX_diff.md
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"matrix_diff: cannot read {path}: {e}")


def cell_key(cell):
    return "{}/{}/seed{}/{}".format(
        cell.get("scenario", "?"),
        cell.get("scheme", "?"),
        cell.get("seed", "?"),
        cell.get("tier", "?"),
    )


def cells_by_key(doc):
    out = {}
    for cell in doc.get("cells", []) or []:
        if isinstance(cell, dict):
            out[cell_key(cell)] = cell
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--tol-acc", type=float, default=0.01,
                    help="allowed absolute accuracy drop per cell (default 0.01)")
    ap.add_argument("--out", default=None,
                    help="write a markdown diff report here (PR artifact)")
    args = ap.parse_args()

    base_doc = load(args.baseline)
    base = cells_by_key(base_doc)
    cur = cells_by_key(load(args.current))

    failures = []
    notes = []
    if not cur:
        failures.append("current report has no cells — the matrix did not run")

    if base_doc.get("bootstrap"):
        notes.append(
            "baseline is a bootstrap placeholder — per-cell gates skipped. "
            "Promote a green run's MATRIX report with ci/arm_gates.py to arm "
            "the gate.")
        base = {}

    for key in sorted(base):
        b = base[key]
        c = cur.get(key)
        if c is None:
            failures.append(
                f"{key}: cell vanished from the current report — its gate "
                "would be silently disarmed")
            continue
        ba, ca = b.get("accuracy"), c.get("accuracy")
        if isinstance(ba, (int, float)) and isinstance(ca, (int, float)):
            if ca < ba - args.tol_acc:
                failures.append(
                    f"{key}: accuracy {ba:.4f} -> {ca:.4f} "
                    f"(drop {ba - ca:.4f} > tol {args.tol_acc})")
        for field in ("wire_bytes", "uploaded_bytes"):
            bv, cv = b.get(field), c.get(field)
            if isinstance(bv, (int, float)) and isinstance(cv, (int, float)):
                if cv > bv:
                    failures.append(
                        f"{key}: {field} {bv:.0f} -> {cv:.0f} "
                        "(deterministic byte total may not grow)")

    if not base_doc.get("bootstrap"):
        for key in sorted(cur):
            if key not in base:
                notes.append(f"new cell {key} — no baseline, no delta computed")

    lines = ["# Matrix diff", ""]
    lines.append(f"baseline: `{args.baseline}`  ·  current: `{args.current}`")
    lines.append(f"accuracy tolerance: {args.tol_acc}  ·  "
                 "byte gate: any increase")
    lines.append("")
    if failures:
        lines.append(f"## ❌ {len(failures)} regression(s)")
        lines.extend(f"- FAIL {f}" for f in failures)
    else:
        lines.append("## ✅ No regressions.")
    if notes:
        lines.append("")
        lines.extend(f"- note: {n}" for n in notes)
    report = "\n".join(lines) + "\n"
    print(report)
    if args.out:
        try:
            with open(args.out, "w", encoding="utf-8") as f:
                f.write(report)
        except OSError as e:
            sys.exit(f"matrix_diff: cannot write {args.out}: {e}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
