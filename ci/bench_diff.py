#!/usr/bin/env python3
"""Bench-baseline regression gate.

Compares a freshly produced BENCH_round.json (written by
`FEDDD_BENCH_JSON=... cargo bench --bench round`) against the committed
baseline in BENCH_baseline/, and exits non-zero when the run regressed:

* **timing**: any case whose mean ns/round exceeds the baseline's by more
  than --max-regress (default 0.20, i.e. >20%) fails;
* **wire volume / fleet state**: any run-level key starting with
  ``wire_``, ``payload_``, ``client_state``, ``sim_state`` or
  ``data_state`` that *increased* at all fails — these totals come from
  a fixed-seed, fixed-round-count run, so at equal config (= equal
  dropout schedule) they are exactly reproducible and any growth is a
  real encoding, client-state, simulation-runtime or data-plane
  regression, not noise;
* **plane mix**: run-level ``plane_`` keys (the per-plane layer counts
  of the value-plane sweep) are gated with zero tolerance — any change,
  up or down, fails. A deterministic layer count that moved means the
  auto-pick quantizer changed behaviour at equal config; shrinking wire
  bytes show up in the ``wire_`` keys, never as a mix drift.
* **serve transport**: run-level ``serve_`` keys from BENCH_serve.json.
  Keys containing ``bytes`` are deterministic loopback totals — any
  increase fails, and a vanished key is refused like the wire keys.
  Keys ending ``_ns`` are round-close latency percentiles, gated at
  --max-regress like the case timings (also with missing-key refusal).
  Everything else (``serve_conns_per_s``) is report-only.

Cases present on only one side are reported but never fail the gate
(benches come and go); timing *improvements* are reported so maintainers
can ratchet the baseline.

A baseline marked ``"bootstrap": true`` (no recorded numbers yet) skips
the numeric gates, still validates the fresh run's shape, and exits 0
with a loud reminder to commit the fresh artifact as the real baseline.

Usage:
    python3 ci/bench_diff.py BENCH_baseline/BENCH_round.json \
        bench-out/BENCH_round.json --max-regress 0.20 \
        --out bench-out/BENCH_diff.md

Local dry-run (documented in BENCH_baseline/README.md): feed the script a
synthetic current file whose mean_ns is 25% above the baseline's and
check it exits 1.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench_diff: cannot read {path}: {e}")


def cases_by_name(doc):
    out = {}
    for case in doc.get("cases", []) or []:
        name = case.get("case")
        if isinstance(name, str):
            out[name] = case
    return out


def run_level_bytes(doc):
    gated = ("wire_", "payload_", "client_state", "sim_state", "data_state",
             "plane_")
    return {
        k: v
        for k, v in doc.items()
        if k.startswith(gated) and isinstance(v, (int, float))
    }


def serve_level(doc):
    return {
        k: v
        for k, v in doc.items()
        if k.startswith("serve_") and isinstance(v, (int, float))
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="allowed fractional ns/round growth (default 0.20)")
    ap.add_argument("--out", default=None,
                    help="write a markdown diff report here (PR artifact)")
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)

    lines = ["# Bench baseline diff", ""]
    lines.append(f"baseline: `{args.baseline}`  ·  current: `{args.current}`")
    lines.append(f"timing gate: +{args.max_regress:.0%} ns/round  ·  "
                 "wire/state gate: any byte increase")
    lines.append("")
    failures = []

    cur_cases = cases_by_name(cur)
    if not cur_cases:
        failures.append("current run has no cases — bench did not produce output")

    if base.get("bootstrap"):
        lines.append("**baseline is a bootstrap placeholder — numeric gates "
                     "skipped.** Commit the fresh `BENCH_round.json` artifact "
                     "as `BENCH_baseline/BENCH_round.json` to arm the gate.")
    else:
        base_cases = cases_by_name(base)
        compared = 0
        lines.append("| case | baseline ns | current ns | delta | verdict |")
        lines.append("|---|---|---|---|---|")
        for name in sorted(set(base_cases) | set(cur_cases)):
            b, c = base_cases.get(name), cur_cases.get(name)
            if b is None:
                lines.append(f"| {name} | — | {c.get('mean_ns', 0):.0f} | new | ok |")
                continue
            if c is None:
                lines.append(f"| {name} | {b.get('mean_ns', 0):.0f} | — | removed | ok |")
                continue
            bn, cn = b.get("mean_ns"), c.get("mean_ns")
            if not bn or cn is None:
                lines.append(f"| {name} | ? | ? | — | skipped |")
                continue
            compared += 1
            ratio = cn / bn
            verdict = "ok"
            if ratio > 1.0 + args.max_regress:
                verdict = "**REGRESSION**"
                failures.append(
                    f"case {name}: {cn:.0f} ns vs baseline {bn:.0f} ns "
                    f"({ratio - 1.0:+.1%} > +{args.max_regress:.0%})")
            elif ratio < 1.0 - args.max_regress:
                verdict = "improved (consider ratcheting the baseline)"
            lines.append(f"| {name} | {bn:.0f} | {cn:.0f} | {ratio - 1.0:+.1%} | {verdict} |")
        if compared == 0 and base_cases and cur_cases:
            # An armed baseline where no case pair was comparable means the
            # bench output format drifted — that must not silently disarm
            # the timing gate.
            failures.append(
                "no case could be compared (mean_ns missing or case names "
                "all changed) — timing gate would be silently disarmed")

        lines.append("")
        lines.append("| wire/payload/state key | baseline | current | verdict |")
        lines.append("|---|---|---|---|")
        base_bytes = run_level_bytes(base)
        cur_bytes = run_level_bytes(cur)
        for key in sorted(set(base_bytes) | set(cur_bytes)):
            bv, cv = base_bytes.get(key), cur_bytes.get(key)
            if cv is None:
                # A baseline wire key the fresh run no longer emits would
                # silently disarm the zero-tolerance gate (key renames
                # included) — refuse, and make the rename update the
                # baseline explicitly.
                failures.append(
                    f"{key}: present in baseline but missing from the current "
                    "run — wire gate would be silently disarmed (update "
                    "BENCH_baseline/ if the key legitimately changed)")
                lines.append(f"| {key} | {bv:.0f} | — | **MISSING** |")
                continue
            if bv is None:
                lines.append(f"| {key} | — | {cv:.0f} | new — ok |")
                continue
            if key.startswith("plane_"):
                if cv != bv:
                    failures.append(
                        f"{key}: {cv:.0f} != baseline {bv:.0f} "
                        "(plane-mix counts are deterministic and gated exactly)")
                    lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | **REGRESSION** |")
                else:
                    lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | ok |")
                continue
            if cv > bv:
                failures.append(
                    f"{key}: {cv:.0f} B > baseline {bv:.0f} B "
                    "(wire/state bytes may never increase at equal dropout rate)")
                lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | **REGRESSION** |")
            else:
                note = "ok" if cv == bv else "improved"
                lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | {note} |")

        base_serve = serve_level(base)
        cur_serve = serve_level(cur)
        if base_serve or cur_serve:
            lines.append("")
            lines.append("| serve key | baseline | current | verdict |")
            lines.append("|---|---|---|---|")
        for key in sorted(set(base_serve) | set(cur_serve)):
            bv, cv = base_serve.get(key), cur_serve.get(key)
            gated = "bytes" in key or key.endswith("_ns")
            if cv is None:
                if gated:
                    failures.append(
                        f"{key}: present in baseline but missing from the "
                        "current run — serve gate would be silently disarmed "
                        "(update BENCH_baseline/ if the key legitimately "
                        "changed)")
                    lines.append(f"| {key} | {bv:.0f} | — | **MISSING** |")
                else:
                    lines.append(f"| {key} | {bv:.0f} | — | removed — ok |")
                continue
            if bv is None:
                lines.append(f"| {key} | — | {cv:.0f} | new — ok |")
                continue
            if "bytes" in key:
                if cv > bv:
                    failures.append(
                        f"{key}: {cv:.0f} B > baseline {bv:.0f} B (loopback "
                        "serve byte totals are deterministic and may never "
                        "increase at equal config)")
                    lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | **REGRESSION** |")
                else:
                    note = "ok" if cv == bv else "improved"
                    lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | {note} |")
            elif key.endswith("_ns"):
                if bv and cv / bv > 1.0 + args.max_regress:
                    failures.append(
                        f"{key}: {cv:.0f} ns vs baseline {bv:.0f} ns "
                        f"({cv / bv - 1.0:+.1%} > +{args.max_regress:.0%})")
                    lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | **REGRESSION** |")
                else:
                    lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | ok |")
            else:
                lines.append(f"| {key} | {bv:.0f} | {cv:.0f} | report-only |")

    lines.append("")
    if failures:
        lines.append(f"## ❌ {len(failures)} gate failure(s)")
        lines.extend(f"- {f}" for f in failures)
    else:
        lines.append("## ✅ within baseline")
    report = "\n".join(lines) + "\n"
    print(report)
    if args.out:
        try:
            with open(args.out, "w", encoding="utf-8") as f:
                f.write(report)
        except OSError as e:
            sys.exit(f"bench_diff: cannot write {args.out}: {e}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
