#!/usr/bin/env python3
"""Arm the CI regression gates from a green run's artifacts.

The committed baselines under ``BENCH_baseline/`` (and the matrix
baseline ``reports/baseline_smoke.json``) start life as
``"bootstrap": true`` placeholders: the diff gates validate shape but
skip every numeric comparison. This tool promotes a green run's fresh
``BENCH_round.json`` / ``BENCH_fleet.json`` / ``MATRIX_*.json``
artifacts into those baseline slots — after which every ns/round mean,
byte total and matrix cell is gated — while **refusing any promotion
that would disarm an armed gate**:

* a fresh artifact that is itself a ``"bootstrap": true`` placeholder is
  rejected — a bootstrap -> bootstrap copy arms nothing;
* a fresh bench run missing a gated run-level key (``wire_*`` /
  ``payload_*`` / ``plane_*`` / ``client_state*`` / ``sim_state*`` /
  ``data_state*``) that the armed baseline records is rejected — key
  renames must edit the committed baseline explicitly;
* a fresh matrix report missing a cell the armed baseline covers is
  rejected — shrinking the matrix silently disarms that cell's gate;
* empty case/cell lists and unreadable files are rejected.

Every input is validated before anything is written, so a failed run
never leaves a half-armed baseline behind.

Usage (the CI arm-gates job; see BENCH_baseline/README.md):
    python3 ci/arm_gates.py --bench bench-out/BENCH_round.json \
        --bench bench-out/BENCH_fleet.json \
        --matrix matrix-out/MATRIX_smoke_ci.json \
        --dest BENCH_baseline --matrix-dest reports/baseline_smoke.json
"""

import argparse
import json
import os
import sys

# Single source of truth for what the gates key on.
from bench_diff import run_level_bytes, serve_level
from matrix_diff import cells_by_key


def gated_serve_keys(doc):
    """The serve keys the diff gate enforces (byte totals and latency
    percentiles); ``serve_conns_per_s``-style keys are report-only and
    free to come and go."""
    return {
        k: v
        for k, v in serve_level(doc).items()
        if "bytes" in k or k.endswith("_ns")
    }


def load(path, errors):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        errors.append(f"cannot read {path}: {e}")
        return None


def load_optional(path):
    """The current baseline slot, or None when absent/unreadable (a
    missing slot is armable; a broken one is replaced wholesale)."""
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def validate_bench(fresh, path, baseline, errors):
    if fresh.get("bootstrap"):
        errors.append(
            f"{path}: fresh artifact is itself a bootstrap placeholder — "
            "a bootstrap -> bootstrap copy arms nothing; feed it a real "
            "green run")
        return
    cases = [c for c in fresh.get("cases", []) or []
             if isinstance(c, dict) and isinstance(c.get("case"), str)]
    if not cases:
        errors.append(f"{path}: no cases — this run produced no bench output")
    if baseline is not None and not baseline.get("bootstrap"):
        fresh_keys = run_level_bytes(fresh)
        fresh_keys.update(gated_serve_keys(fresh))
        gated = dict(run_level_bytes(baseline))
        gated.update(gated_serve_keys(baseline))
        for key in sorted(gated):
            if key not in fresh_keys:
                errors.append(
                    f"{path}: gated key {key} is in the armed baseline but "
                    "missing from the fresh run — promoting would silently "
                    "disarm it (edit the baseline explicitly if the key "
                    "legitimately changed)")


def validate_matrix(fresh, path, baseline, errors):
    if fresh.get("bootstrap"):
        errors.append(
            f"{path}: fresh matrix report is itself a bootstrap placeholder "
            "— a bootstrap -> bootstrap copy arms nothing")
        return
    cells = cells_by_key(fresh)
    if not cells:
        errors.append(f"{path}: no cells — the matrix did not run")
    if baseline is not None and not baseline.get("bootstrap"):
        for key in sorted(cells_by_key(baseline)):
            if key not in cells:
                errors.append(
                    f"{path}: cell {key} is in the armed baseline but "
                    "missing from the fresh report — promoting would "
                    "silently disarm it")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", action="append", default=[],
                    help="fresh BENCH_*.json to promote (repeatable)")
    ap.add_argument("--matrix", default=None,
                    help="fresh MATRIX_*.json to promote as the matrix baseline")
    ap.add_argument("--dest", default="BENCH_baseline",
                    help="baseline directory for bench artifacts")
    ap.add_argument("--matrix-dest", default="reports/baseline_smoke.json",
                    help="baseline path for the matrix report")
    args = ap.parse_args()

    if not args.bench and args.matrix is None:
        sys.exit("arm_gates: nothing to promote (pass --bench and/or --matrix)")

    errors = []
    writes = []  # (dest_path, fresh_doc)

    for path in args.bench:
        fresh = load(path, errors)
        if fresh is None:
            continue
        dest = os.path.join(args.dest, os.path.basename(path))
        validate_bench(fresh, path, load_optional(dest), errors)
        writes.append((dest, fresh))

    if args.matrix is not None:
        fresh = load(args.matrix, errors)
        if fresh is not None:
            validate_matrix(
                fresh, args.matrix, load_optional(args.matrix_dest), errors)
            writes.append((args.matrix_dest, fresh))

    if errors:
        for e in errors:
            print(f"arm_gates: REFUSED: {e}", file=sys.stderr)
        sys.exit(1)

    for dest, doc in writes:
        parent = os.path.dirname(dest)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(dest, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"arm_gates: armed {dest}")
    print(f"arm_gates: {len(writes)} baseline(s) armed — commit them to "
          "finish arming the gates")


if __name__ == "__main__":
    main()
