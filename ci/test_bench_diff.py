#!/usr/bin/env python3
"""Unit tests for the bench-baseline gate (``ci/bench_diff.py``).

The gate's red/green logic must itself be tested even while the committed
baselines are still ``"bootstrap": true`` placeholders — otherwise arming
the numeric gates (committing the first green CI run's artifacts) could
arm a gate that never fires. Exercised end-to-end by invoking the script
as a subprocess on synthetic baseline/current JSON pairs:

* green: equal runs, sub-threshold timing growth, timing improvements,
  byte decreases, new cases/keys, bootstrap placeholders;
* red: >20% ns/round growth, a single extra ``wire_*`` /
  ``client_state*`` / ``sim_state*`` / ``data_state*`` byte, any change
  at all in a ``plane_*`` layer count (exact-match gate, both
  directions), a vanished wire or plane key (silent disarm), an empty
  current run, an all-incomparable case set;
* serve keys (BENCH_serve.json): a ``serve_*bytes*`` increase or a
  vanished gated serve key fails, a >20% ``serve_*_ns`` latency growth
  fails, while ``serve_conns_per_s`` swings and vanishing report-only
  keys stay green.

Stdlib only; run with ``python3 ci/test_bench_diff.py -v`` (the CI step).
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

HERE = os.path.dirname(os.path.abspath(__file__))
SCRIPT = os.path.join(HERE, "bench_diff.py")


def doc(cases=None, **run_level):
    """A minimal BENCH_*.json document; ``cases`` maps name -> mean_ns."""
    body = {
        "bench": "round",
        "cases": [
            {"case": name, "mean_ns": ns}
            for name, ns in sorted((cases or {}).items())
        ],
    }
    body.update(run_level)
    return body


def run_gate(base, cur, extra=()):
    """Run bench_diff.py on the two documents; returns CompletedProcess."""
    with tempfile.TemporaryDirectory() as d:
        bp = os.path.join(d, "base.json")
        cp = os.path.join(d, "cur.json")
        with open(bp, "w", encoding="utf-8") as f:
            json.dump(base, f)
        with open(cp, "w", encoding="utf-8") as f:
            json.dump(cur, f)
        return subprocess.run(
            [sys.executable, SCRIPT, bp, cp, *extra],
            capture_output=True,
            text=True,
            check=False,
        )


class GreenPaths(unittest.TestCase):
    def test_identical_run_passes(self):
        d = doc({"step_round": 1000.0}, wire_bytes_sync_8r=4096)
        proc = run_gate(d, d)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("within baseline", proc.stdout)

    def test_timing_growth_within_threshold_passes(self):
        base = doc({"step_round": 1000.0})
        cur = doc({"step_round": 1190.0})  # +19% < +20%
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_timing_improvement_passes_and_suggests_ratchet(self):
        base = doc({"step_round": 1000.0})
        cur = doc({"step_round": 500.0})
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 0)
        self.assertIn("improved", proc.stdout)

    def test_byte_decrease_passes(self):
        base = doc({"step_round": 1000.0}, wire_bytes_sync_8r=4096)
        cur = doc({"step_round": 1000.0}, wire_bytes_sync_8r=4095)
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_new_case_and_new_byte_key_pass(self):
        base = doc({"step_round": 1000.0})
        cur = doc(
            {"step_round": 1000.0, "step_round_pooled": 800.0},
            wire_bytes_sync_8r=4096,
        )
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_removed_case_alone_passes(self):
        # Cases come and go (benches are renamed); only byte KEYS are
        # held to the never-vanish rule.
        base = doc({"step_round": 1000.0, "old_case": 50.0})
        cur = doc({"step_round": 1000.0})
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_bootstrap_baseline_skips_numeric_gates(self):
        base = {"bootstrap": True, "bench": "round", "cases": []}
        # Numbers that would fail an armed gate sail through bootstrap...
        cur = doc({"step_round": 99999.0}, wire_bytes_sync_8r=10**9)
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        # ...with a loud reminder to commit the artifact.
        self.assertIn("bootstrap placeholder", proc.stdout)


class RedPaths(unittest.TestCase):
    def test_timing_regression_over_threshold_fails(self):
        base = doc({"step_round": 1000.0})
        cur = doc({"step_round": 1250.0})  # +25% > +20%
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("REGRESSION", proc.stdout)

    def test_custom_threshold_is_honored(self):
        base = doc({"step_round": 1000.0})
        cur = doc({"step_round": 1150.0})  # +15%
        self.assertEqual(run_gate(base, cur).returncode, 0)
        self.assertEqual(
            run_gate(base, cur, ("--max-regress", "0.10")).returncode, 1
        )

    def test_one_extra_wire_byte_fails(self):
        base = doc({"step_round": 1000.0}, wire_bytes_sync_8r=4096)
        cur = doc({"step_round": 1000.0}, wire_bytes_sync_8r=4097)
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("wire_bytes_sync_8r", proc.stdout)

    def test_one_extra_client_state_byte_fails(self):
        base = doc({"step_round": 1000.0}, client_state_peak_bytes_10k_h1_2r=500)
        cur = doc({"step_round": 1000.0}, client_state_peak_bytes_10k_h1_2r=501)
        self.assertEqual(run_gate(base, cur).returncode, 1)

    def test_one_extra_payload_byte_fails(self):
        base = doc({"step_round": 1000.0}, payload_bytes_sync_8r=100)
        cur = doc({"step_round": 1000.0}, payload_bytes_sync_8r=101)
        self.assertEqual(run_gate(base, cur).returncode, 1)

    def test_one_extra_sim_state_byte_fails(self):
        base = doc({"step_round": 1000.0}, sim_state_peak_bytes_100k_h1_2r=4000)
        cur = doc({"step_round": 1000.0}, sim_state_peak_bytes_100k_h1_2r=4001)
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("sim_state_peak_bytes_100k_h1_2r", proc.stdout)

    def test_one_extra_data_state_byte_fails(self):
        base = doc({"step_round": 1000.0}, data_state_bytes_100k_h1_2r=9000)
        cur = doc({"step_round": 1000.0}, data_state_bytes_100k_h1_2r=9001)
        self.assertEqual(run_gate(base, cur).returncode, 1)

    def test_sim_and_data_state_equality_passes(self):
        d = doc(
            {"step_round": 1000.0},
            sim_state_peak_bytes_100k_h1_2r=4000,
            data_state_bytes_100k_h1_2r=9000,
        )
        self.assertEqual(run_gate(d, d).returncode, 0)

    def test_plane_key_equality_passes(self):
        d = doc({"step_round": 1000.0}, plane_i8_layers_auto_8r=240)
        self.assertEqual(run_gate(d, d).returncode, 0)

    def test_plane_key_increase_fails(self):
        base = doc({"step_round": 1000.0}, plane_i8_layers_auto_8r=240)
        cur = doc({"step_round": 1000.0}, plane_i8_layers_auto_8r=241)
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("plane_i8_layers_auto_8r", proc.stdout)

    def test_plane_key_decrease_also_fails(self):
        # Unlike the byte totals, the plane mix is gated exactly: fewer
        # i8 layers is not an "improvement", it is a quantizer drift.
        base = doc({"step_round": 1000.0}, plane_i8_layers_auto_8r=240)
        cur = doc({"step_round": 1000.0}, plane_i8_layers_auto_8r=239)
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("gated exactly", proc.stdout)

    def test_vanished_plane_key_fails(self):
        base = doc({"step_round": 1000.0}, plane_f16_layers_auto_8r=0)
        cur = doc({"step_round": 1000.0})
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("silently disarmed", proc.stdout)

    def test_vanished_wire_key_fails(self):
        # A renamed/dropped byte key would silently disarm the
        # zero-tolerance gate — must be an explicit baseline update.
        base = doc({"step_round": 1000.0}, wire_bytes_sync_8r=4096)
        cur = doc({"step_round": 1000.0})
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("silently disarmed", proc.stdout)

    def test_empty_current_run_fails_even_against_bootstrap(self):
        base = {"bootstrap": True, "bench": "round", "cases": []}
        cur = {"bench": "round", "cases": []}
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("no cases", proc.stdout)

    def test_all_cases_incomparable_fails(self):
        # Wholesale case renames would leave zero timing comparisons —
        # that must not pass as a silently disarmed gate.
        base = doc({"old_name": 1000.0})
        cur = doc({"new_name": 1000.0})
        self.assertEqual(run_gate(base, cur).returncode, 1)


class ServeKeys(unittest.TestCase):
    def test_equal_serve_run_passes(self):
        d = doc(
            {"serve_round_close": 1000.0},
            serve_wire_bytes_loopback_8r=4096,
            serve_round_close_p99_ns=5e6,
            serve_conns_per_s=900.0,
        )
        proc = run_gate(d, d)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)

    def test_serve_byte_increase_fails(self):
        base = doc({"c": 1000.0}, serve_wire_bytes_loopback_8r=4096)
        cur = doc({"c": 1000.0}, serve_wire_bytes_loopback_8r=4097)
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("serve_wire_bytes_loopback_8r", proc.stdout)

    def test_serve_byte_decrease_passes(self):
        base = doc({"c": 1000.0}, serve_payload_bytes_loopback_8r=4096)
        cur = doc({"c": 1000.0}, serve_payload_bytes_loopback_8r=4000)
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_serve_latency_regression_fails(self):
        base = doc({"c": 1000.0}, serve_round_close_p99_ns=1e6)
        cur = doc({"c": 1000.0}, serve_round_close_p99_ns=1.25e6)  # +25%
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("serve_round_close_p99_ns", proc.stdout)

    def test_serve_latency_within_threshold_passes(self):
        base = doc({"c": 1000.0}, serve_round_close_p50_ns=1e6)
        cur = doc({"c": 1000.0}, serve_round_close_p50_ns=1.19e6)  # +19%
        self.assertEqual(run_gate(base, cur).returncode, 0)
        # The custom threshold applies to serve latency keys too.
        self.assertEqual(
            run_gate(base, cur, ("--max-regress", "0.10")).returncode, 1
        )

    def test_serve_conns_per_s_is_report_only(self):
        # Connection throughput is host noise: a 10x collapse reports but
        # never fails.
        base = doc({"c": 1000.0}, serve_conns_per_s=1000.0)
        cur = doc({"c": 1000.0}, serve_conns_per_s=100.0)
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 0)
        self.assertIn("report-only", proc.stdout)

    def test_vanished_gated_serve_key_fails(self):
        base = doc({"c": 1000.0}, serve_wire_bytes_loopback_8r=4096)
        cur = doc({"c": 1000.0})
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("silently disarmed", proc.stdout)

    def test_vanished_report_only_serve_key_passes(self):
        base = doc({"c": 1000.0}, serve_conns_per_s=1000.0)
        cur = doc({"c": 1000.0})
        self.assertEqual(run_gate(base, cur).returncode, 0)


class ReportOutput(unittest.TestCase):
    def test_out_flag_writes_the_markdown_report(self):
        base = doc({"step_round": 1000.0}, wire_bytes_sync_8r=4096)
        cur = doc({"step_round": 1250.0}, wire_bytes_sync_8r=4097)
        with tempfile.TemporaryDirectory() as d:
            bp = os.path.join(d, "base.json")
            cp = os.path.join(d, "cur.json")
            out = os.path.join(d, "BENCH_diff.md")
            with open(bp, "w", encoding="utf-8") as f:
                json.dump(base, f)
            with open(cp, "w", encoding="utf-8") as f:
                json.dump(cur, f)
            proc = subprocess.run(
                [sys.executable, SCRIPT, bp, cp, "--out", out],
                capture_output=True,
                text=True,
                check=False,
            )
            self.assertEqual(proc.returncode, 1)
            with open(out, encoding="utf-8") as f:
                report = f.read()
        self.assertIn("# Bench baseline diff", report)
        self.assertIn("2 gate failure(s)", report)


if __name__ == "__main__":
    unittest.main()
