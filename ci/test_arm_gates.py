#!/usr/bin/env python3
"""Unit tests for the gate-arming tool (``ci/arm_gates.py``).

Arming is the one moment the baselines are rewritten wholesale, so its
refusal paths matter more than its happy path: a promotion that silently
disarmed a gate would undo what the diff gates exist for. Exercised
end-to-end by invoking the script as a subprocess on synthetic
artifacts:

* green: arming bootstrap slots from a green run, re-arming an armed
  baseline (ratchet), arming a missing slot, matrix promotion;
* red: a fresh artifact that is itself bootstrap (bootstrap -> bootstrap
  copy), a vanished gated run-level key vs the armed baseline, a
  vanished matrix cell, empty case/cell lists, unreadable inputs — and
  in every red case **nothing is written** (no half-armed baselines).

Stdlib only; run with ``python3 ci/test_arm_gates.py -v`` (the CI step).
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

HERE = os.path.dirname(os.path.abspath(__file__))
SCRIPT = os.path.join(HERE, "arm_gates.py")


def bench_doc(cases=None, **run_level):
    body = {
        "bench": "round",
        "cases": [
            {"case": name, "mean_ns": ns}
            for name, ns in sorted((cases or {"step_round": 1000.0}).items())
        ],
    }
    body.update(run_level)
    return body


def matrix_doc(cells):
    return {"matrix": {"tier": "smoke", "label": "test"}, "cells": cells}


def cell(**overrides):
    body = {"scenario": "baseline_iid", "scheme": "feddd", "tier": "smoke",
            "seed": 17, "accuracy": 0.8125, "wire_bytes": 130000,
            "uploaded_bytes": 123456}
    body.update(overrides)
    return body


class ArmHarness(unittest.TestCase):
    """Builds a scratch repo layout per test and runs the tool in it."""

    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.root = self._tmp.name
        self.dest = os.path.join(self.root, "BENCH_baseline")
        os.makedirs(self.dest)
        self.matrix_dest = os.path.join(self.root, "reports",
                                        "baseline_smoke.json")

    def tearDown(self):
        self._tmp.cleanup()

    def write(self, relpath, doc):
        path = os.path.join(self.root, relpath)
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f)
        return path

    def read(self, path):
        with open(path, encoding="utf-8") as f:
            return json.load(f)

    def arm(self, *extra):
        return subprocess.run(
            [sys.executable, SCRIPT, "--dest", self.dest,
             "--matrix-dest", self.matrix_dest, *extra],
            capture_output=True, text=True, check=False, cwd=self.root,
        )


class GreenPaths(ArmHarness):
    def test_arms_bootstrap_bench_slots(self):
        self.write("BENCH_baseline/BENCH_round.json",
                   {"bootstrap": True, "bench": "round", "cases": []})
        fresh = bench_doc(wire_bytes_sync_8r=4096, plane_i8_layers_auto_8r=240)
        fp = self.write("bench-out/BENCH_round.json", fresh)
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("armed", proc.stdout)
        armed = self.read(os.path.join(self.dest, "BENCH_round.json"))
        self.assertEqual(armed, fresh)
        self.assertNotIn("bootstrap", armed)

    def test_arms_a_missing_slot(self):
        fp = self.write("bench-out/BENCH_fleet.json",
                        bench_doc(client_state_peak_bytes_1k_h5_3r=500))
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertTrue(
            os.path.exists(os.path.join(self.dest, "BENCH_fleet.json")))

    def test_rearms_armed_baseline_with_same_keys(self):
        self.write("BENCH_baseline/BENCH_round.json",
                   bench_doc(wire_bytes_sync_8r=5000))
        fp = self.write("bench-out/BENCH_round.json",
                        bench_doc(wire_bytes_sync_8r=4096))
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        armed = self.read(os.path.join(self.dest, "BENCH_round.json"))
        self.assertEqual(armed["wire_bytes_sync_8r"], 4096)

    def test_promotes_a_matrix_report(self):
        self.write("reports/baseline_smoke.json",
                   {"bootstrap": True, "cells": []})
        fp = self.write("matrix-out/MATRIX_smoke_ci.json",
                        matrix_doc([cell()]))
        proc = self.arm("--matrix", fp)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        armed = self.read(self.matrix_dest)
        self.assertEqual(len(armed["cells"]), 1)
        self.assertNotIn("bootstrap", armed)

    def test_dropout_family_bench_keys_arm_onto_an_existing_baseline(self):
        # PR adds wire/payload totals for the fed_dropout scheme: fresh
        # keys are armable without touching the committed baseline first.
        self.write("BENCH_baseline/BENCH_round.json",
                   bench_doc(wire_bytes_sync_8r=5000))
        fp = self.write("bench-out/BENCH_round.json",
                        bench_doc(wire_bytes_sync_8r=4096,
                                  wire_bytes_fed_dropout_8r=2048,
                                  payload_bytes_fed_dropout_8r=1024))
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        armed = self.read(os.path.join(self.dest, "BENCH_round.json"))
        self.assertEqual(armed["wire_bytes_fed_dropout_8r"], 2048)

    def test_matrix_promotion_may_widen_the_scheme_axis(self):
        # A six-scheme report arms over a four-scheme baseline: new cells
        # (fed_dropout, afd) widen coverage, which is never a disarm.
        self.write("reports/baseline_smoke.json",
                   matrix_doc([cell(), cell(scheme="fedavg")]))
        fp = self.write("matrix-out/MATRIX_smoke_ci.json",
                        matrix_doc([cell(), cell(scheme="fedavg"),
                                    cell(scheme="fed_dropout"),
                                    cell(scheme="afd")]))
        proc = self.arm("--matrix", fp)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertEqual(len(self.read(self.matrix_dest)["cells"]), 4)

    def test_fresh_run_may_add_new_keys_and_cases(self):
        self.write("BENCH_baseline/BENCH_round.json",
                   bench_doc(wire_bytes_sync_8r=5000))
        fp = self.write(
            "bench-out/BENCH_round.json",
            bench_doc({"step_round": 900.0, "brand_new_case": 10.0},
                      wire_bytes_sync_8r=4096,
                      wire_i8_bytes_auto_8r=123))
        self.assertEqual(self.arm("--bench", fp).returncode, 0)


class RedPaths(ArmHarness):
    def test_bootstrap_fresh_artifact_is_refused(self):
        self.write("BENCH_baseline/BENCH_round.json",
                   {"bootstrap": True, "bench": "round", "cases": []})
        fp = self.write("bench-out/BENCH_round.json",
                        {"bootstrap": True, "bench": "round", "cases": []})
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("bootstrap", proc.stderr)
        # the slot is untouched
        self.assertTrue(
            self.read(os.path.join(self.dest, "BENCH_round.json"))["bootstrap"])

    def test_vanished_gated_key_is_refused(self):
        self.write("BENCH_baseline/BENCH_round.json",
                   bench_doc(wire_bytes_sync_8r=5000,
                             payload_bytes_sync_8r=900))
        fp = self.write("bench-out/BENCH_round.json",
                        bench_doc(wire_bytes_sync_8r=4096))
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("payload_bytes_sync_8r", proc.stderr)
        self.assertIn("disarm", proc.stderr)
        armed = self.read(os.path.join(self.dest, "BENCH_round.json"))
        self.assertEqual(armed["wire_bytes_sync_8r"], 5000)

    def test_vanished_fed_dropout_key_is_refused(self):
        # Once the dropout-family totals are armed they gate like any
        # other wire_* key: a run that stops emitting them is refused.
        self.write("BENCH_baseline/BENCH_round.json",
                   bench_doc(wire_bytes_sync_8r=5000,
                             wire_bytes_fed_dropout_8r=2048))
        fp = self.write("bench-out/BENCH_round.json",
                        bench_doc(wire_bytes_sync_8r=4096))
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("wire_bytes_fed_dropout_8r", proc.stderr)
        armed = self.read(os.path.join(self.dest, "BENCH_round.json"))
        self.assertEqual(armed["wire_bytes_fed_dropout_8r"], 2048)

    def test_vanished_gated_serve_key_is_refused(self):
        self.write("BENCH_baseline/BENCH_serve.json",
                   bench_doc(serve_wire_bytes_loopback_8r=4096,
                             serve_round_close_p99_ns=5e6,
                             serve_conns_per_s=900.0))
        # The gated byte + latency keys vanished; only the report-only
        # throughput key survives — refuse.
        fp = self.write("bench-out/BENCH_serve.json",
                        bench_doc(serve_conns_per_s=950.0))
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("serve_wire_bytes_loopback_8r", proc.stderr)
        self.assertIn("serve_round_close_p99_ns", proc.stderr)
        armed = self.read(os.path.join(self.dest, "BENCH_serve.json"))
        self.assertEqual(armed["serve_wire_bytes_loopback_8r"], 4096)

    def test_vanished_report_only_serve_key_is_promotable(self):
        self.write("BENCH_baseline/BENCH_serve.json",
                   bench_doc(serve_wire_bytes_loopback_8r=4096,
                             serve_conns_per_s=900.0))
        fp = self.write("bench-out/BENCH_serve.json",
                        bench_doc(serve_wire_bytes_loopback_8r=4096))
        self.assertEqual(self.arm("--bench", fp).returncode, 0)

    def test_empty_case_list_is_refused(self):
        fp = self.write("bench-out/BENCH_round.json",
                        {"bench": "round", "cases": []})
        proc = self.arm("--bench", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("no cases", proc.stderr)

    def test_unreadable_input_is_refused(self):
        missing = os.path.join(self.root, "bench-out", "nope.json")
        proc = self.arm("--bench", missing)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("cannot read", proc.stderr)

    def test_bootstrap_matrix_report_is_refused(self):
        fp = self.write("matrix-out/MATRIX_smoke_ci.json",
                        {"bootstrap": True, "cells": []})
        proc = self.arm("--matrix", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("bootstrap", proc.stderr)

    def test_vanished_matrix_cell_is_refused(self):
        self.write("reports/baseline_smoke.json",
                   matrix_doc([cell(), cell(scheme="oort")]))
        fp = self.write("matrix-out/MATRIX_smoke_ci.json",
                        matrix_doc([cell()]))
        proc = self.arm("--matrix", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("baseline_iid/oort/seed17/smoke", proc.stderr)
        armed = self.read(self.matrix_dest)
        self.assertEqual(len(armed["cells"]), 2)

    def test_empty_matrix_cells_are_refused(self):
        fp = self.write("matrix-out/MATRIX_smoke_ci.json", matrix_doc([]))
        proc = self.arm("--matrix", fp)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("no cells", proc.stderr)

    def test_one_bad_input_blocks_every_write(self):
        # Validate-all-then-write-all: a good bench artifact next to a
        # bad one must leave both slots untouched.
        good = self.write("bench-out/BENCH_round.json",
                          bench_doc(wire_bytes_sync_8r=4096))
        bad = self.write("bench-out/BENCH_fleet.json",
                         {"bootstrap": True, "bench": "fleet", "cases": []})
        proc = self.arm("--bench", good, "--bench", bad)
        self.assertEqual(proc.returncode, 1)
        self.assertFalse(
            os.path.exists(os.path.join(self.dest, "BENCH_round.json")))
        self.assertFalse(
            os.path.exists(os.path.join(self.dest, "BENCH_fleet.json")))

    def test_no_inputs_is_an_error(self):
        proc = self.arm()
        self.assertEqual(proc.returncode, 1)
        self.assertIn("nothing to promote", proc.stderr)


if __name__ == "__main__":
    unittest.main()
