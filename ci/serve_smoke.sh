#!/usr/bin/env bash
# Loopback serve smoke: one real `feddd serve` process plus two `feddd
# agent` processes on 127.0.0.1 must complete a short run end-to-end and
# write serve.json. This exercises the CLI wiring (ephemeral-port bind,
# serve_addr.txt publication, slot-range handshake, DONE shutdown) as
# separate OS processes — the bitwise-equivalence claims are covered
# in-process by rust/tests/serve_loopback.rs.
#
# Usage: ci/serve_smoke.sh [out-dir]   (FEDDD_BIN overrides the binary)
set -euo pipefail

BIN="${FEDDD_BIN:-target/release/feddd}"
OUT="${1:-serve-smoke-out}"
ROUNDS=3
rm -rf "$OUT"
mkdir -p "$OUT"

"$BIN" serve --n_clients 4 --rounds "$ROUNDS" --local_steps 2 \
    --train_per_client 60 --test_n 64 --eval_every "$ROUNDS" --workers 1 \
    --listen 127.0.0.1:0 --out "$OUT" >"$OUT/serve.log" 2>&1 &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT

# The server publishes the resolved ephemeral address before accepting.
for _ in $(seq 1 100); do
    [ -s "$OUT/serve_addr.txt" ] && break
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "serve exited before binding:" >&2
        cat "$OUT/serve.log" >&2
        exit 1
    fi
    sleep 0.1
done
ADDR="$(tr -d '[:space:]' <"$OUT/serve_addr.txt")"
echo "serve listening on $ADDR"

"$BIN" agent --connect "$ADDR" --slot_start 0 --slot_count 2 \
    >"$OUT/agent0.log" 2>&1 &
AGENT0=$!
"$BIN" agent --connect "$ADDR" --slot_start 2 \
    >"$OUT/agent1.log" 2>&1 &
AGENT1=$!

fail() {
    echo "$1" >&2
    for f in serve agent0 agent1; do
        echo "---- $f.log ----" >&2
        cat "$OUT/$f.log" >&2 || true
    done
    exit 1
}

wait "$AGENT0" || fail "agent 0 failed"
wait "$AGENT1" || fail "agent 1 failed"
wait "$SERVE_PID" || fail "serve failed"
trap - EXIT

[ -s "$OUT/serve.json" ] || fail "serve.json missing"
python3 - "$OUT/serve.json" "$ROUNDS" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
want = int(sys.argv[2])
rounds = doc["result"]["rounds"]
assert len(rounds) == want, f"expected {want} rounds, got {len(rounds)}"
assert all(r["participants"] > 0 for r in rounds), "a round had no uploads"
assert all(r["wire_bytes"] > 0 for r in rounds), "a round moved no wire bytes"
evals = doc["result"]["evals"]
assert evals, "no eval records"
assert 0.0 <= evals[-1]["accuracy"] <= 1.0, evals[-1]
print(f"serve smoke OK: {want} rounds, final accuracy {evals[-1]['accuracy']:.4f}")
EOF
grep -q "agent done" "$OUT/agent0.log" || fail "agent 0 never reported completion"
grep -q "agent done" "$OUT/agent1.log" || fail "agent 1 never reported completion"
