#!/usr/bin/env python3
"""Unit tests for the scenario-matrix gate (``ci/matrix_diff.py``).

The gate's red/green logic is itself the first CI step — a regression
gate that never fires is worse than none. Exercised end-to-end by
invoking the script as a subprocess on synthetic report pairs:

* green: identical reports, accuracy drop within tolerance, byte
  decreases, accuracy improvements, new cells (reported, never fatal),
  a ``"bootstrap": true`` baseline placeholder (per-cell gates skipped
  with a loud arming reminder);
* red: accuracy drop beyond tolerance, a single extra ``wire_bytes`` /
  ``uploaded_bytes`` byte, a vanished cell (silent disarm), an empty
  current report (even against a bootstrap baseline).

Stdlib only; run with ``python3 ci/test_matrix_diff.py -v`` (the CI
step).
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

HERE = os.path.dirname(os.path.abspath(__file__))
SCRIPT = os.path.join(HERE, "matrix_diff.py")


def cell(**overrides):
    """A minimal matrix cell; overrides patch the defaults."""
    body = {
        "scenario": "baseline_iid",
        "scheme": "feddd",
        "tier": "smoke",
        "seed": 17,
        "rounds": 6,
        "accuracy": 0.8125,
        "rare_accuracy": None,
        "uploaded_bytes": 123456,
        "wire_bytes": 130000,
        "v_time": 901.5,
        "mean_staleness": 0.25,
        "mean_stragglers": 1.5,
        "mean_participants": 7.0,
        "churned": 0,
        "peak_client_state_bytes": 40000,
    }
    body.update(overrides)
    return body


def doc(cells):
    return {
        "matrix": {"tier": "smoke", "label": "test", "scenarios": [],
                   "schemes": [], "seeds": [17]},
        "cells": cells,
    }


def run_gate(base, cur, extra=()):
    """Run matrix_diff.py on the two documents; returns CompletedProcess."""
    with tempfile.TemporaryDirectory() as d:
        bp = os.path.join(d, "base.json")
        cp = os.path.join(d, "cur.json")
        with open(bp, "w", encoding="utf-8") as f:
            json.dump(base, f)
        with open(cp, "w", encoding="utf-8") as f:
            json.dump(cur, f)
        return subprocess.run(
            [sys.executable, SCRIPT, bp, cp, *extra],
            capture_output=True,
            text=True,
            check=False,
        )


class GreenPaths(unittest.TestCase):
    def test_identical_reports_pass(self):
        d = doc([cell()])
        proc = run_gate(d, d)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("No regressions", proc.stdout)

    def test_accuracy_drop_within_tolerance_passes(self):
        base = doc([cell(accuracy=0.8125)])
        cur = doc([cell(accuracy=0.8075)])  # -0.005 < tol 0.01
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_accuracy_improvement_passes(self):
        base = doc([cell(accuracy=0.80)])
        cur = doc([cell(accuracy=0.90)])
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_byte_decrease_passes(self):
        base = doc([cell(wire_bytes=130000, uploaded_bytes=123456)])
        cur = doc([cell(wire_bytes=129999, uploaded_bytes=123455)])
        self.assertEqual(run_gate(base, cur).returncode, 0)

    def test_new_cell_is_reported_but_not_fatal(self):
        base = doc([cell()])
        cur = doc([cell(), cell(scheme="oort")])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("new cell", proc.stdout)
        self.assertIn("baseline_iid/oort/seed17/smoke", proc.stdout)
        # the undefined-division rule: no delta/ratio for a new cell
        self.assertIn("no delta computed", proc.stdout)


    def test_dropout_family_cells_are_new_cells_not_failures(self):
        # Widening the scheme axis (fed_dropout, afd) against an armed
        # four-scheme baseline: the fresh cells are notes with no delta —
        # the undefined-division rule — and never fail the gate.
        base = doc([cell(), cell(scheme="fedavg")])
        cur = doc([
            cell(),
            cell(scheme="fedavg"),
            cell(scheme="fed_dropout", wire_bytes=90000, uploaded_bytes=80000),
            cell(scheme="afd", wire_bytes=95000, uploaded_bytes=85000),
        ])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("new cell baseline_iid/fed_dropout/seed17/smoke", proc.stdout)
        self.assertIn("new cell baseline_iid/afd/seed17/smoke", proc.stdout)

    def test_bootstrap_baseline_skips_per_cell_gates(self):
        base = {"bootstrap": True, "cells": []}
        # Numbers that would fail an armed gate sail through bootstrap...
        cur = doc([cell(accuracy=0.01, wire_bytes=10**9)])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        # ...with a loud reminder to promote a green run's report.
        self.assertIn("bootstrap placeholder", proc.stdout)
        self.assertIn("arm_gates.py", proc.stdout)


class RedPaths(unittest.TestCase):
    def test_accuracy_regression_beyond_tolerance_fails(self):
        base = doc([cell(accuracy=0.8125)])
        cur = doc([cell(accuracy=0.75)])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("accuracy", proc.stdout)
        self.assertIn("baseline_iid/feddd/seed17/smoke", proc.stdout)

    def test_custom_tolerance_is_honored(self):
        base = doc([cell(accuracy=0.8125)])
        cur = doc([cell(accuracy=0.78)])  # -0.0325
        self.assertEqual(run_gate(base, cur, ("--tol-acc", "0.05")).returncode, 0)
        self.assertEqual(run_gate(base, cur, ("--tol-acc", "0.01")).returncode, 1)

    def test_one_extra_wire_byte_fails(self):
        base = doc([cell(wire_bytes=130000)])
        cur = doc([cell(wire_bytes=130001)])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("wire_bytes", proc.stdout)

    def test_one_extra_uploaded_byte_fails(self):
        base = doc([cell(uploaded_bytes=123456)])
        cur = doc([cell(uploaded_bytes=123457)])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("uploaded_bytes", proc.stdout)

    def test_vanished_cell_fails(self):
        # A cell that stops being run would silently disarm its gate —
        # shrinking the matrix must be an explicit baseline update.
        base = doc([cell(), cell(scheme="oort")])
        cur = doc([cell()])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("silently disarmed", proc.stdout)

    def test_armed_dropout_family_cells_gate_like_any_other(self):
        # Once fed_dropout/afd cells are promoted into the baseline they
        # gate byte-exactly: one extra wire byte fails, and a cell that
        # stops being run fails as silently disarmed.
        base = doc([cell(scheme="fed_dropout", wire_bytes=90000)])
        cur = doc([cell(scheme="fed_dropout", wire_bytes=90001)])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("baseline_iid/fed_dropout/seed17/smoke", proc.stdout)
        self.assertIn("wire_bytes", proc.stdout)

        base = doc([cell(), cell(scheme="afd")])
        cur = doc([cell()])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("baseline_iid/afd/seed17/smoke", proc.stdout)
        self.assertIn("silently disarmed", proc.stdout)

    def test_empty_current_report_fails(self):
        base = doc([cell()])
        cur = doc([])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("no cells", proc.stdout)

    def test_empty_current_report_fails_even_against_bootstrap(self):
        base = {"bootstrap": True, "cells": []}
        cur = doc([])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        self.assertIn("no cells", proc.stdout)


class ReportOutput(unittest.TestCase):
    def test_out_flag_writes_the_markdown_report(self):
        base = doc([cell(accuracy=0.8125, wire_bytes=130000)])
        cur = doc([cell(accuracy=0.75, wire_bytes=130001)])
        with tempfile.TemporaryDirectory() as d:
            bp = os.path.join(d, "base.json")
            cp = os.path.join(d, "cur.json")
            out = os.path.join(d, "MATRIX_diff.md")
            with open(bp, "w", encoding="utf-8") as f:
                json.dump(base, f)
            with open(cp, "w", encoding="utf-8") as f:
                json.dump(cur, f)
            proc = subprocess.run(
                [sys.executable, SCRIPT, bp, cp, "--out", out],
                capture_output=True,
                text=True,
                check=False,
            )
            self.assertEqual(proc.returncode, 1)
            with open(out, encoding="utf-8") as f:
                report = f.read()
        self.assertIn("# Matrix diff", report)
        self.assertIn("2 regression(s)", report)

    def test_diff_prints_only_regressions_not_the_full_table(self):
        base = doc([cell(), cell(scheme="fedavg"), cell(scheme="fedcs")])
        cur = doc([cell(accuracy=0.5), cell(scheme="fedavg"),
                   cell(scheme="fedcs")])
        proc = run_gate(base, cur)
        self.assertEqual(proc.returncode, 1)
        # only the regressed cell's key appears
        self.assertIn("baseline_iid/feddd/seed17/smoke", proc.stdout)
        self.assertNotIn("baseline_iid/fedavg/seed17/smoke", proc.stdout)
        self.assertNotIn("baseline_iid/fedcs/seed17/smoke", proc.stdout)


if __name__ == "__main__":
    unittest.main()
