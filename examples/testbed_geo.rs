//! The Table 5 geo-distributed testbed: the `geo_testbed` registry
//! scenario (docs/SCENARIOS.md) at the small tier — 10 VM-like clients
//! whose compute and link quality mirror the paper's Alibaba-cloud fleet
//! (Guangzhou / Nanjing / Beijing / Zhangjiakou / Shanghai vs an Ulanqab
//! server), h=1. Reports time-to-accuracy of FedDD vs FedAvg on the
//! virtual clock. The fleet/h knobs live in the scenario registry,
//! shared with `feddd matrix`.

use feddd::prelude::*;
use feddd::scenarios::{example_config, Tier};

fn main() -> anyhow::Result<()> {
    feddd::util::logging::init();
    let mk = |scheme: &str| -> anyhow::Result<ExpConfig> {
        let mut cfg = example_config("geo_testbed", Tier::Small)?;
        cfg.scheme = scheme.into();
        cfg.eval_every = 2;
        Ok(cfg)
    };

    println!("== Table 5 testbed fleet ==");
    let mut rng = Rng::new(17);
    let fleet = Fleet::testbed(&mut rng);
    for (i, p) in fleet.profiles.iter().enumerate() {
        println!(
            "  client {i}: cpu {:.1} GHz  up {:>5.1} kbps  down {:>6.1} kbps",
            p.cpu_hz / 1e9,
            p.up_bps / 1e3,
            p.down_bps / 1e3
        );
    }

    let feddd_res = run_experiment(mk("feddd")?)?;
    let fedavg_res = run_experiment(mk("fedavg")?)?;

    let target = 0.9 * fedavg_res.best_accuracy();
    println!("\ntarget accuracy (90% of FedAvg best): {target:.3}");
    for (name, res) in [("feddd", &feddd_res), ("fedavg", &fedavg_res)] {
        match res.time_to_accuracy(target) {
            Some(t) => println!("  {name:<7} reaches it at virtual t = {t:.0}s"),
            None => println!("  {name:<7} never reaches it"),
        }
    }
    if let (Some(a), Some(b)) = (
        feddd_res.time_to_accuracy(target),
        fedavg_res.time_to_accuracy(target),
    ) {
        println!("  speedup: {:.2}x ({:.0}% time reduction)", b / a, 100.0 * (1.0 - a / b));
    }
    Ok(())
}
