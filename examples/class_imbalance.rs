//! §6.7 generalization on class-imbalanced data (Fig. 21): the
//! `class_imbalance` registry scenario (docs/SCENARIOS.md) at the small
//! tier — three rare classes at 0.4× frequency, Non-IID-b shards, a
//! tight 20% communication budget. Client selection starves the rare
//! classes; FedDD keeps them. The knobs live in the scenario registry,
//! shared with `feddd matrix`.

use feddd::prelude::*;
use feddd::scenarios::{example_config, Tier, MATRIX_SCHEMES};

fn main() -> anyhow::Result<()> {
    feddd::util::logging::init();
    println!("== class-imbalanced MNIST-like, rare classes {{0,1,2}} @ 0.4x, budget 20% ==\n");
    println!(
        "{:<8} {:>8} {:>8} {:>8} | per-class accuracy (0..9)",
        "scheme", "overall", "rare", "common"
    );
    for scheme in MATRIX_SCHEMES {
        let mut cfg = example_config("class_imbalance", Tier::Small)?;
        cfg.scheme = (*scheme).into();
        let rare_classes = cfg.rare_classes.clone();
        let res = run_experiment(cfg)?;
        let pca = res
            .evals
            .last()
            .map(|e| e.per_class_accuracy.clone())
            .unwrap_or_default();
        let rare = res.rare_class_accuracy(&rare_classes).unwrap_or(0.0);
        let n_rare = rare_classes.len();
        let common = pca.iter().skip(n_rare).sum::<f64>() / (pca.len() - n_rare).max(1) as f64;
        let cells: Vec<String> = pca.iter().map(|a| format!("{a:.2}")).collect();
        println!(
            "{:<8} {:>8.3} {:>8.3} {:>8.3} | {}",
            scheme,
            res.final_accuracy().unwrap_or(0.0),
            rare,
            common,
            cells.join(" ")
        );
    }
    Ok(())
}
