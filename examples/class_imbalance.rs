//! §6.7 generalization on class-imbalanced data (Fig. 21): three rare
//! classes at 0.4× frequency, Non-IID-b shards, a tight 20% communication
//! budget. Client selection starves the rare classes; FedDD keeps them.

use feddd::prelude::*;

fn base(scheme: &str) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.partition = "noniid_b".into();
    cfg.rare_classes = vec![0, 1, 2];
    cfg.rare_ratio = 0.4;
    cfg.a_server = 0.2;
    cfg.d_max = 0.85;
    cfg.rounds = 25;
    cfg.eval_every = 25;
    cfg.workers = 0; // parallel round engine: one worker per core
    cfg.artifacts_dir = feddd::runtime::default_artifacts_dir()
        .to_string_lossy()
        .into_owned();
    cfg
}

fn main() -> anyhow::Result<()> {
    feddd::util::logging::init();
    println!("== class-imbalanced MNIST-like, rare classes {{0,1,2}} @ 0.4x, budget 20% ==\n");
    println!("{:<8} {:>8} {:>8} {:>8} | per-class accuracy (0..9)", "scheme", "overall", "rare", "common");
    for scheme in ["fedavg", "fedcs", "oort", "feddd"] {
        let res = run_experiment(base(scheme))?;
        let pca = res
            .evals
            .last()
            .map(|e| e.per_class_accuracy.clone())
            .unwrap_or_default();
        let rare = pca.iter().take(3).sum::<f64>() / 3.0;
        let common = pca.iter().skip(3).sum::<f64>() / 7.0;
        let cells: Vec<String> = pca.iter().map(|a| format!("{a:.2}")).collect();
        println!(
            "{:<8} {:>8.3} {:>8.3} {:>8.3} | {}",
            scheme,
            res.final_accuracy().unwrap_or(0.0),
            rare,
            common,
            cells.join(" ")
        );
    }
    Ok(())
}
