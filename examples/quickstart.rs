//! Quickstart: the `baseline_iid` registry scenario at the smoke tier
//! (docs/SCENARIOS.md), printing the accuracy curve and the allocator's
//! byte budget. The config comes straight from the scenario registry —
//! the same cell `feddd matrix --tier smoke` runs — so this example and
//! the matrix can never drift apart.
//!
//!     make artifacts && cargo run --release --example quickstart

use feddd::prelude::*;
use feddd::scenarios::{example_config, Tier};

fn main() -> anyhow::Result<()> {
    feddd::util::logging::init();
    let mut cfg = example_config("baseline_iid", Tier::Smoke)?;
    cfg.rounds = 12;
    cfg.eval_every = 3;

    println!("== FedDD quickstart: {} clients, {} rounds ==", cfg.n_clients, cfg.rounds);
    let mut run = FedRun::new(cfg)?;
    println!(
        "byte budget per round: {} KiB (A_server = {})",
        run.budget_bytes() / 1024,
        run.cfg.a_server
    );
    let result = run.run()?;

    println!("\nround  v_time(s)  accuracy");
    for e in &result.evals {
        println!("{:>5}  {:>9.1}  {:>7.3}", e.round, e.v_time, e.accuracy);
    }
    println!(
        "\nfinal accuracy {:.3}, total uploaded {:.1} MiB, wall {:.1}s",
        result.final_accuracy().unwrap_or(0.0),
        result.total_uploaded() as f64 / (1024.0 * 1024.0),
        result.wall_seconds
    );
    Ok(())
}
