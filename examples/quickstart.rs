//! Quickstart: a 12-round FedDD run on the smoke preset (10 simulated
//! clients, MLP on the MNIST stand-in), printing the accuracy curve and
//! the allocator's dropout decisions.
//!
//!     make artifacts && cargo run --release --example quickstart

use feddd::prelude::*;

fn main() -> anyhow::Result<()> {
    feddd::util::logging::init();
    let mut cfg = ExpConfig::smoke();
    cfg.rounds = 12;
    cfg.workers = 0; // fan client training/aggregation over all cores
    cfg.artifacts_dir = feddd::runtime::default_artifacts_dir()
        .to_string_lossy()
        .into_owned();

    println!("== FedDD quickstart: {} clients, {} rounds ==", cfg.n_clients, cfg.rounds);
    let mut run = FedRun::new(cfg)?;
    println!(
        "byte budget per round: {} KiB (A_server = {})",
        run.budget_bytes() / 1024,
        run.cfg.a_server
    );
    let result = run.run()?;

    println!("\nround  v_time(s)  accuracy");
    for e in &result.evals {
        println!("{:>5}  {:>9.1}  {:>7.3}", e.round, e.v_time, e.accuracy);
    }
    println!(
        "\nfinal accuracy {:.3}, total uploaded {:.1} MiB, wall {:.1}s",
        result.final_accuracy().unwrap_or(0.0),
        result.total_uploaded() as f64 / (1024.0 * 1024.0),
        result.wall_seconds
    );
    Ok(())
}
