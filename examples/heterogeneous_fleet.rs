//! Model-heterogeneous fleet (the paper's Table 6 "het_b" setting): five
//! different VGG-style sub-models across the clients, differential
//! dropout-rate allocation, and the coverage-rate-corrected importance
//! selection (Eq. 21). Compares FedDD against FedCS under the same byte
//! budget and prints the per-client dropout profile.

use feddd::prelude::*;

fn base() -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.dataset = "cifar10".into();
    cfg.model = "het_b".into();
    cfg.width_pct = 25;
    cfg.lr = 0.02;
    cfg.rounds = 40;
    cfg.local_steps = 4;
    cfg.n_clients = 10;
    cfg.eval_every = 4;
    cfg.workers = 0; // parallel round engine: one worker per core
    cfg.artifacts_dir = feddd::runtime::default_artifacts_dir()
        .to_string_lossy()
        .into_owned();
    cfg
}

fn main() -> anyhow::Result<()> {
    feddd::util::logging::init();

    // Show the sub-model spread of the fleet.
    let cfg = base();
    println!("== heterogeneous fleet (Table 6 sub-models, width 25%) ==");
    for n in 0..5 {
        let name = cfg.client_model_name(n);
        let spec = feddd::model::ModelSpec::get(&name, 0.25)?;
        println!(
            "  client {n}: {:<10} {:>8} params  {:>6} KiB",
            name,
            spec.param_count(),
            spec.size_bytes() / 1024
        );
    }

    let mut feddd_run = FedRun::new(base())?;
    let feddd_res = feddd_run.run()?;

    let mut cs_cfg = base();
    cs_cfg.scheme = "fedcs".into();
    let cs_res = FedRun::new(cs_cfg)?.run()?;

    println!("\n== results under identical byte budget ==");
    println!(
        "FedDD : final acc {:.3}  best {:.3}  vtime {:.0}s",
        feddd_res.final_accuracy().unwrap_or(0.0),
        feddd_res.best_accuracy(),
        feddd_res.evals.last().map(|e| e.v_time).unwrap_or(0.0)
    );
    println!(
        "FedCS : final acc {:.3}  best {:.3}  vtime {:.0}s",
        cs_res.final_accuracy().unwrap_or(0.0),
        cs_res.best_accuracy(),
        cs_res.evals.last().map(|e| e.v_time).unwrap_or(0.0)
    );
    println!(
        "\nFedDD engaged all {} clients every round; FedCS averaged {:.1} participants.",
        feddd_res.rounds.last().map(|r| r.participants).unwrap_or(0),
        cs_res.rounds.iter().map(|r| r.participants).sum::<usize>() as f64
            / cs_res.rounds.len() as f64
    );
    Ok(())
}
