//! Model-heterogeneous fleet (the paper's Table 6 "het_b" setting): the
//! `hetero_fleet` registry scenario (docs/SCENARIOS.md) at the small
//! tier — five different VGG-style sub-models across the clients,
//! differential dropout-rate allocation, and the coverage-rate-corrected
//! importance selection (Eq. 21). Compares FedDD against FedCS under the
//! same byte budget and prints the per-client sub-model profile.

use feddd::prelude::*;
use feddd::scenarios::{example_config, Tier};

fn base() -> anyhow::Result<ExpConfig> {
    example_config("hetero_fleet", Tier::Small)
}

fn main() -> anyhow::Result<()> {
    feddd::util::logging::init();

    // Show the sub-model spread of the fleet.
    let cfg = base()?;
    let width = cfg.width_pct as f64 / 100.0;
    println!("== heterogeneous fleet (Table 6 sub-models, width {}%) ==", cfg.width_pct);
    for n in 0..5 {
        let name = cfg.client_model_name(n);
        let spec = feddd::model::ModelSpec::get(&name, width)?;
        println!(
            "  client {n}: {:<10} {:>8} params  {:>6} KiB",
            name,
            spec.param_count(),
            spec.size_bytes() / 1024
        );
    }

    let mut feddd_run = FedRun::new(base()?)?;
    let feddd_res = feddd_run.run()?;

    let mut cs_cfg = base()?;
    cs_cfg.scheme = "fedcs".into();
    let cs_res = FedRun::new(cs_cfg)?.run()?;

    println!("\n== results under identical byte budget ==");
    println!(
        "FedDD : final acc {:.3}  best {:.3}  vtime {:.0}s",
        feddd_res.final_accuracy().unwrap_or(0.0),
        feddd_res.best_accuracy(),
        feddd_res.final_v_time()
    );
    println!(
        "FedCS : final acc {:.3}  best {:.3}  vtime {:.0}s",
        cs_res.final_accuracy().unwrap_or(0.0),
        cs_res.best_accuracy(),
        cs_res.final_v_time()
    );
    println!(
        "\nFedDD engaged all {} clients every round; FedCS averaged {:.1} participants.",
        feddd_res.rounds.last().map(|r| r.participants).unwrap_or(0),
        cs_res.mean_participants()
    );
    Ok(())
}
