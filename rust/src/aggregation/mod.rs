//! Server-side mask-weighted aggregation (paper Eq. 4) and client-side
//! sparse-download merge (Eq. 5/6).
//!
//! ```text
//! W^t = (Σ_n m_n · Ŵ_n ⊙ M_n) / (Σ_n m_n · M_n)        (Eq. 4)
//! ```
//!
//! Positions covered by no client keep the previous global value (the
//! paper's division is undefined there; see DESIGN.md §6). Two backends:
//!
//! * **rust** — vectorized flat loops (`tensor::ops`), the default;
//! * **xla**  — the L1 Pallas `masked_acc` / `masked_fin` artifacts driven
//!   through the PJRT runtime (cross-checked against rust in tests and
//!   benchmarked in `rust/benches/aggregation.rs`).
//!
//! Heterogeneous sub-models are embedded at the leading corner of the
//! global tensors (`model::geometry`) before accumulation, so Eq. 4's
//! per-position counts automatically blend clients of different widths.

use crate::codec::WireUpload;
use crate::model::{embed, ModelSpec};
use crate::runtime::Runtime;
use crate::tensor::{axpy, masked_div, merge_masked, Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggBackend {
    Rust,
    Xla,
}

impl AggBackend {
    pub fn by_name(name: &str) -> anyhow::Result<AggBackend> {
        match name {
            "rust" => Ok(AggBackend::Rust),
            "xla" => Ok(AggBackend::Xla),
            _ => anyhow::bail!("unknown aggregation backend {name:?}"),
        }
    }
}

/// Streaming aggregator for one round.
pub struct Aggregator {
    global_shapes: Vec<Vec<usize>>,
    num: Vec<Tensor>,
    den: Vec<Tensor>,
    backend: AggBackend,
    clients_added: usize,
}

impl Aggregator {
    pub fn new(global: &ModelSpec, backend: AggBackend) -> Aggregator {
        let shapes: Vec<Vec<usize>> =
            global.param_shapes().into_iter().map(|(_, s)| s).collect();
        Aggregator {
            num: shapes.iter().map(|s| Tensor::zeros(s.clone())).collect(),
            den: shapes.iter().map(|s| Tensor::zeros(s.clone())).collect(),
            global_shapes: shapes,
            backend,
            clients_added: 0,
        }
    }

    /// Add one client's masked update.
    ///
    /// `params` — the client's post-training parameters (client shapes);
    /// `mask` — elementwise 0/1 mask (client shapes, from the channel
    /// mask); `m_n` — the client's aggregation weight (sample count).
    /// `runtime` is required for the XLA backend.
    pub fn add_client(
        &mut self,
        params: &[Tensor],
        mask: &[Tensor],
        m_n: f32,
        runtime: Option<&Runtime>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(params.len() == self.num.len(), "param arity");
        anyhow::ensure!(mask.len() == self.num.len(), "mask arity");
        for i in 0..params.len() {
            // masked contribution in client shape, then embed to global.
            let mut contrib = vec![0.0f32; params[i].numel()];
            for ((c, &p), &m) in contrib
                .iter_mut()
                .zip(params[i].data())
                .zip(mask[i].data())
            {
                *c = p * m;
            }
            let contrib_t = Tensor::new(params[i].shape().to_vec(), contrib);
            let (contrib_g, mask_g);
            if params[i].shape() == &self.global_shapes[i][..] {
                contrib_g = contrib_t;
                mask_g = mask[i].clone();
            } else {
                contrib_g = embed(&contrib_t, &self.global_shapes[i]);
                mask_g = embed(&mask[i], &self.global_shapes[i]);
            }
            match self.backend {
                AggBackend::Rust => {
                    axpy(self.num[i].data_mut(), m_n, contrib_g.data());
                    axpy(self.den[i].data_mut(), m_n, mask_g.data());
                }
                AggBackend::Xla => {
                    let rt = runtime
                        .ok_or_else(|| anyhow::anyhow!("xla backend needs a runtime"))?;
                    // kernel computes num += mn*(w*mask); we pass the
                    // already-masked contribution with an all-ones "w"
                    // times mask trick; instead call with w=params, mask.
                    let mut n =
                        std::mem::replace(&mut self.num[i], Tensor::zeros(vec![0]))
                            .into_data();
                    let mut d =
                        std::mem::replace(&mut self.den[i], Tensor::zeros(vec![0]))
                            .into_data();
                    rt.k_masked_acc(&mut n, &mut d, contrib_g.data(), mask_g.data(), m_n)?;
                    self.num[i] = Tensor::new(self.global_shapes[i].clone(), n);
                    self.den[i] = Tensor::new(self.global_shapes[i].clone(), d);
                }
            }
        }
        self.clients_added += 1;
        Ok(())
    }

    pub fn clients_added(&self) -> usize {
        self.clients_added
    }

    /// Fold one client's encoded upload straight into the Eq. 4 num/den
    /// partials — the zero-copy path: no elementwise mask expansion, no
    /// dense contribution buffer, no corner embedding. Per kept unit the
    /// wire values scatter to their global positions with
    /// `num[p] += m_n·v` and `den[p] += m_n`, which is bitwise-identical
    /// to [`Aggregator::add_client`] with the expanded mask: the dense
    /// path adds `m_n·(p·0) = 0.0` at masked-out positions (a bitwise
    /// no-op — partials can never be `-0.0`, see the wire-equivalence
    /// tests) and `m_n·1.0 = m_n` to the denominator at kept ones.
    ///
    /// Wire payloads are scattered, so this path always folds on the CPU
    /// regardless of the aggregation backend; the backend still owns
    /// `finalize`. Client sub-model geometry (hetero fleets) is handled
    /// by the same leading-corner convention as `model::embed`.
    pub fn absorb_wire(&mut self, wire: &WireUpload, m_n: f32) -> anyhow::Result<()> {
        anyhow::ensure!(
            wire.layers.len() * 2 == self.num.len(),
            "wire has {} layers, aggregator {} tensors",
            wire.layers.len(),
            self.num.len()
        );
        for (l, lw) in wire.layers.iter().enumerate() {
            let wi = 2 * l;
            let bi = 2 * l + 1;
            let chunk = lw.group + 1;
            anyhow::ensure!(
                lw.values.len() == lw.units.len() * chunk,
                "layer {l}: {} values for {} units of group {}",
                lw.values.len(),
                lw.units.len(),
                lw.group
            );
            let gshape = &self.global_shapes[wi];
            anyhow::ensure!(
                self.global_shapes[bi].len() == 1 && self.global_shapes[bi][0] >= lw.out_dim,
                "layer {l}: bias geometry mismatch"
            );
            // Weight tensor accumulate (global layout: conv OIHW, fc
            // (in, out)), arranged so the inner loops run over contiguous
            // slices. Every global position is touched at most once per
            // upload (units are distinct), so reordering the unit/row
            // loops is bitwise-free — each position's accumulation chain
            // across uploads is unchanged.
            match gshape.len() {
                4 => {
                    let (out_g, in_g) = (gshape[0], gshape[1]);
                    let k2 = gshape[2] * gshape[3];
                    anyhow::ensure!(
                        lw.out_dim <= out_g && lw.in_dim <= in_g && lw.group == lw.in_dim * k2,
                        "layer {l}: conv geometry mismatch"
                    );
                    let num = self.num[wi].data_mut();
                    let den = self.den[wi].data_mut();
                    for (ui, &k) in lw.units.iter().enumerate() {
                        let k = k as usize;
                        anyhow::ensure!(k < lw.out_dim, "layer {l}: unit {k} out of range");
                        let vals = &lw.values[ui * chunk..ui * chunk + lw.group];
                        if lw.in_dim == in_g {
                            // Homogeneous client: the unit's whole kernel
                            // block is one contiguous OIHW run.
                            let g0 = k * in_g * k2;
                            for (o, &v) in num[g0..g0 + lw.group].iter_mut().zip(vals) {
                                *o += m_n * v;
                            }
                            for o in den[g0..g0 + lw.group].iter_mut() {
                                *o += m_n;
                            }
                        } else {
                            // Hetero sub-model: k2-contiguous run per
                            // retained input channel.
                            for i in 0..lw.in_dim {
                                let g0 = (k * in_g + i) * k2;
                                let sv = &vals[i * k2..(i + 1) * k2];
                                for (o, &v) in num[g0..g0 + k2].iter_mut().zip(sv) {
                                    *o += m_n * v;
                                }
                                for o in den[g0..g0 + k2].iter_mut() {
                                    *o += m_n;
                                }
                            }
                        }
                    }
                }
                2 => {
                    let (in_g, out_g) = (gshape[0], gshape[1]);
                    anyhow::ensure!(
                        lw.out_dim <= out_g && lw.in_dim <= in_g && lw.group == lw.in_dim,
                        "layer {l}: fc geometry mismatch"
                    );
                    for &k in &lw.units {
                        anyhow::ensure!(
                            (k as usize) < lw.out_dim,
                            "layer {l}: unit {k} out of range"
                        );
                    }
                    let num = self.num[wi].data_mut();
                    let den = self.den[wi].data_mut();
                    // Row sweep: visit each global input row once and
                    // write the selected units in ascending order within
                    // that contiguous row, instead of walking one unit's
                    // out_g-strided column at a time.
                    for j in 0..lw.group {
                        let nrow = &mut num[j * out_g..(j + 1) * out_g];
                        let drow = &mut den[j * out_g..(j + 1) * out_g];
                        for (ui, &k) in lw.units.iter().enumerate() {
                            let k = k as usize;
                            nrow[k] += m_n * lw.values[ui * chunk + j];
                            drow[k] += m_n;
                        }
                    }
                }
                r => anyhow::bail!("layer {l}: unsupported weight rank {r}"),
            }
            // Bias scatter (1-D, unit-indexed).
            let num_b = self.num[bi].data_mut();
            let den_b = self.den[bi].data_mut();
            for (ui, &k) in lw.units.iter().enumerate() {
                let k = k as usize;
                num_b[k] += m_n * lw.values[ui * chunk + lw.group];
                den_b[k] += m_n;
            }
        }
        self.clients_added += 1;
        Ok(())
    }

    /// Fold another aggregator's partial sums into this one, scaled by
    /// `staleness_weight` (elementwise `num += w·num`, `den += w·den`).
    /// Both must target the same global geometry.
    ///
    /// This is both the shard-merge primitive of the parallel round engine
    /// (each worker accumulates a disjoint client range; partials merge
    /// with weight 1) and the staleness fold of the semi-asynchronous
    /// engine: a buffered late arrival's partial is absorbed with
    /// `m_n ← m_n · (1+s_n)^{-β}` ([`staleness_weight`]) applied to Eq. 4's
    /// mask-weighted numerator *and* denominator, so the discount rescales
    /// the client's vote without biasing the quotient (DESIGN.md §7).
    pub fn absorb(&mut self, other: &Aggregator, staleness_weight: f32) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.global_shapes == other.global_shapes,
            "shard geometry mismatch"
        );
        anyhow::ensure!(
            staleness_weight.is_finite() && staleness_weight >= 0.0,
            "staleness weight {staleness_weight} must be finite and >= 0"
        );
        for i in 0..self.num.len() {
            axpy(self.num[i].data_mut(), staleness_weight, other.num[i].data());
            axpy(self.den[i].data_mut(), staleness_weight, other.den[i].data());
        }
        self.clients_added += other.clients_added;
        Ok(())
    }

    /// Merge ordered shard partials into one aggregator by pairwise
    /// (tree) reduction: `[s0 s1 s2 s3] → [s0+s1, s2+s3] → …`. The merge
    /// order is a pure function of the shard list, so for a fixed shard
    /// partition the result is bitwise-deterministic regardless of how
    /// many workers produced the shards.
    pub fn merge(mut shards: Vec<Aggregator>) -> anyhow::Result<Aggregator> {
        anyhow::ensure!(!shards.is_empty(), "merge of zero shards");
        while shards.len() > 1 {
            let mut next = Vec::with_capacity(shards.len().div_ceil(2));
            let mut it = shards.into_iter();
            while let Some(mut left) = it.next() {
                if let Some(right) = it.next() {
                    left.absorb(&right, 1.0)?;
                }
                next.push(left);
            }
            shards = next;
        }
        Ok(shards.pop().unwrap())
    }

    /// Finalize Eq. 4; `prev` supplies values for zero-coverage positions.
    pub fn finalize(
        &self,
        prev: &[Tensor],
        runtime: Option<&Runtime>,
    ) -> anyhow::Result<Vec<Tensor>> {
        anyhow::ensure!(prev.len() == self.num.len(), "prev arity");
        let mut out = Vec::with_capacity(self.num.len());
        for i in 0..self.num.len() {
            let mut data = vec![0.0f32; self.num[i].numel()];
            match self.backend {
                AggBackend::Rust => {
                    masked_div(
                        &mut data,
                        self.num[i].data(),
                        self.den[i].data(),
                        prev[i].data(),
                    );
                }
                AggBackend::Xla => {
                    let rt = runtime
                        .ok_or_else(|| anyhow::anyhow!("xla backend needs a runtime"))?;
                    rt.k_masked_fin(
                        self.num[i].data(),
                        self.den[i].data(),
                        prev[i].data(),
                        &mut data,
                    )?;
                }
            }
            out.push(Tensor::new(self.global_shapes[i].clone(), data));
        }
        Ok(out)
    }
}

/// Staleness discount `(1 + s)^{-β}` for a late arrival folded `s` rounds
/// after dispatch (semi-asynchronous mode; DESIGN.md §7).
///
/// Guarantees: exactly `1.0` for fresh updates (`s = 0`) or `β = 0`, so
/// the quorum==N semi-async path reproduces the synchronous aggregation
/// bit for bit; always finite and within `[0, 1]` for any `s` and any
/// finite `β ≥ 0`, so Eq. 4's denominator can never go NaN or negative.
pub fn staleness_weight(staleness: usize, beta: f64) -> f32 {
    if staleness == 0 || beta == 0.0 {
        return 1.0;
    }
    let w = (1.0 + staleness as f64).powf(-beta);
    if w.is_finite() {
        w.clamp(0.0, 1.0) as f32
    } else {
        0.0
    }
}

/// Client-side Eq. 5: `W_n^{t+1} = W^t ⊙ M + Ŵ_n^t ⊙ (1 − M)` where all
/// tensors are client-shaped. `local` is updated in place to the merged
/// result (pass the downloaded global slice as `global_slice`).
pub fn sparse_merge(local: &mut [Tensor], global_slice: &[Tensor], mask: &[Tensor]) {
    for i in 0..local.len() {
        // merge_masked computes w = w⊙m + v⊙(1-m) with w=global, v=local;
        // we want the result in `local`, so copy global in and merge local.
        let mut merged = global_slice[i].data().to_vec();
        merge_masked(&mut merged, local[i].data(), mask[i].data());
        local[i] = Tensor::new(local[i].shape().to_vec(), merged);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{structural_presence, ModelSpec};
    use crate::selection::ChannelMask;
    use crate::util::proptest::{check, close_slice};
    use crate::util::rng::Rng;

    fn perturbed(p: &[Tensor], rng: &mut Rng, s: f32) -> Vec<Tensor> {
        p.iter()
            .map(|t| {
                let d: Vec<f32> =
                    t.data().iter().map(|&x| x + rng.normal_f32(0.0, s)).collect();
                Tensor::new(t.shape().to_vec(), d)
            })
            .collect()
    }

    #[test]
    fn full_masks_reduce_to_fedavg() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(0);
        let prev = spec.init_params(&mut rng);
        let clients: Vec<Vec<Tensor>> =
            (0..4).map(|_| perturbed(&prev, &mut rng, 0.1)).collect();
        let weights = [1.0f32, 2.0, 3.0, 4.0];
        let full = ChannelMask::full(&spec).to_elementwise(&spec);
        let mut agg = Aggregator::new(&spec, AggBackend::Rust);
        for (c, &w) in clients.iter().zip(&weights) {
            agg.add_client(c, &full, w, None).unwrap();
        }
        let out = agg.finalize(&prev, None).unwrap();
        let wsum: f32 = weights.iter().sum();
        for i in 0..out.len() {
            let want: Vec<f32> = (0..out[i].numel())
                .map(|j| {
                    clients
                        .iter()
                        .zip(&weights)
                        .map(|(c, &w)| c[i].data()[j] * w)
                        .sum::<f32>()
                        / wsum
                })
                .collect();
            close_slice(out[i].data(), &want, 1e-5).unwrap();
        }
    }

    #[test]
    fn zero_coverage_positions_keep_prev() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(1);
        let prev = spec.init_params(&mut rng);
        let client = perturbed(&prev, &mut rng, 0.1);
        // mask that selects only unit 0 of each layer
        let mask = ChannelMask {
            per_layer: spec
                .layers
                .iter()
                .map(|l| {
                    let mut v = vec![false; l.out_dim];
                    v[0] = true;
                    v
                })
                .collect(),
        };
        let elems = mask.to_elementwise(&spec);
        let mut agg = Aggregator::new(&spec, AggBackend::Rust);
        agg.add_client(&client, &elems, 5.0, None).unwrap();
        let out = agg.finalize(&prev, None).unwrap();
        for i in 0..out.len() {
            for j in 0..out[i].numel() {
                let want = if elems[i].data()[j] == 1.0 {
                    client[i].data()[j]
                } else {
                    prev[i].data()[j]
                };
                assert!((out[i].data()[j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn aggregation_is_weight_scale_invariant() {
        // Scaling every m_n by a constant must not change the result.
        check("agg scale invariance", 10, |rng| {
            let spec = ModelSpec::get("mlp", 0.25).unwrap();
            let prev = spec.init_params(rng);
            let clients: Vec<Vec<Tensor>> =
                (0..3).map(|_| perturbed(&prev, rng, 0.05)).collect();
            let masks: Vec<Vec<Tensor>> = (0..3)
                .map(|_| {
                    crate::selection::select_mask(
                        crate::selection::Policy::Random,
                        &spec,
                        &prev,
                        &clients[0],
                        None,
                        rng.range_f64(0.0, 0.8),
                        rng,
                    )
                    .to_elementwise(&spec)
                })
                .collect();
            let run = |scale: f32| -> Vec<Tensor> {
                let mut agg = Aggregator::new(&spec, AggBackend::Rust);
                for (i, c) in clients.iter().enumerate() {
                    agg.add_client(c, &masks[i], scale * (i + 1) as f32, None).unwrap();
                }
                agg.finalize(&prev, None).unwrap()
            };
            let a = run(1.0);
            let b = run(7.0);
            for (x, y) in a.iter().zip(&b) {
                close_slice(x.data(), y.data(), 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn hetero_clients_blend_in_corner() {
        let global = ModelSpec::get("het_a_1", 0.25).unwrap();
        let sub = ModelSpec::get("het_a_5", 0.25).unwrap();
        let mut rng = Rng::new(3);
        let prev = global.init_params(&mut rng);
        let sub_params = sub.init_params(&mut rng);
        let full_sub = ChannelMask::full(&sub).to_elementwise(&sub);
        let mut agg = Aggregator::new(&global, AggBackend::Rust);
        agg.add_client(&sub_params, &full_sub, 1.0, None).unwrap();
        let out = agg.finalize(&prev, None).unwrap();
        // inside the sub-model corner: equals sub params; outside: prev.
        let pres = structural_presence(&sub, &global);
        let emb = crate::model::embed_params(&sub_params, &global);
        for i in 0..out.len() {
            for j in 0..out[i].numel() {
                let want = if pres[i].data()[j] == 1.0 {
                    emb[i].data()[j]
                } else {
                    prev[i].data()[j]
                };
                assert!(
                    (out[i].data()[j] - want).abs() < 1e-6,
                    "tensor {i} pos {j}"
                );
            }
        }
    }

    #[test]
    fn shard_merge_matches_single_aggregator() {
        // Random clients/masks/weights, random shard partition: the
        // merged shards must equal one sequential aggregator up to f32
        // reassociation, and be bitwise-identical across repeated merges
        // of the same partition.
        check("shard merge equivalence", 20, |rng| {
            let spec = ModelSpec::get("mlp", 0.25).unwrap();
            let prev = spec.init_params(rng);
            let n_clients = rng.int_range(1, 9);
            let clients: Vec<Vec<Tensor>> =
                (0..n_clients).map(|_| perturbed(&prev, rng, 0.05)).collect();
            let masks: Vec<Vec<Tensor>> = (0..n_clients)
                .map(|_| {
                    crate::selection::select_mask(
                        crate::selection::Policy::Random,
                        &spec,
                        &prev,
                        &clients[0],
                        None,
                        rng.range_f64(0.0, 0.8),
                        rng,
                    )
                    .to_elementwise(&spec)
                })
                .collect();
            let weights: Vec<f32> =
                (0..n_clients).map(|_| rng.range_f64(0.5, 5.0) as f32).collect();

            let sequential = {
                let mut agg = Aggregator::new(&spec, AggBackend::Rust);
                for i in 0..n_clients {
                    agg.add_client(&clients[i], &masks[i], weights[i], None).unwrap();
                }
                agg.finalize(&prev, None).unwrap()
            };

            let shard_len = rng.int_range(1, n_clients);
            let sharded_run = || -> (usize, Vec<Tensor>) {
                let mut shards = Vec::new();
                let mut i = 0;
                while i < n_clients {
                    let end = (i + shard_len).min(n_clients);
                    let mut shard = Aggregator::new(&spec, AggBackend::Rust);
                    for j in i..end {
                        shard.add_client(&clients[j], &masks[j], weights[j], None).unwrap();
                    }
                    shards.push(shard);
                    i = end;
                }
                let merged = Aggregator::merge(shards).unwrap();
                (merged.clients_added(), merged.finalize(&prev, None).unwrap())
            };
            let (added_a, out_a) = sharded_run();
            let (added_b, out_b) = sharded_run();
            if added_a != n_clients {
                return Err(format!("clients_added {added_a} != {n_clients}"));
            }
            if added_b != added_a {
                return Err("clients_added not deterministic".into());
            }
            for (x, y) in out_a.iter().zip(&sequential) {
                close_slice(x.data(), y.data(), 1e-4)?;
            }
            // same partition twice -> bitwise equal
            for (x, y) in out_a.iter().zip(&out_b) {
                if x.data() != y.data() {
                    return Err("shard merge not bitwise-deterministic".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn absorb_rejects_geometry_mismatch() {
        let a = ModelSpec::get("mlp", 0.25).unwrap();
        let b = ModelSpec::get("mlp", 1.0).unwrap();
        let mut agg_a = Aggregator::new(&a, AggBackend::Rust);
        let agg_b = Aggregator::new(&b, AggBackend::Rust);
        assert!(agg_a.absorb(&agg_b, 1.0).is_err());
        assert!(Aggregator::merge(Vec::new()).is_err());
    }

    #[test]
    fn absorb_rejects_bad_staleness_weight() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut a = Aggregator::new(&spec, AggBackend::Rust);
        let b = Aggregator::new(&spec, AggBackend::Rust);
        assert!(a.absorb(&b, f32::NAN).is_err());
        assert!(a.absorb(&b, -0.5).is_err());
        assert!(a.absorb(&b, f32::INFINITY).is_err());
        assert!(a.absorb(&b, 0.0).is_ok());
    }

    #[test]
    fn staleness_weight_bounds() {
        // Fresh or β=0 must be exactly 1 (bitwise sync equivalence).
        assert_eq!(staleness_weight(0, 2.0), 1.0);
        assert_eq!(staleness_weight(5, 0.0), 1.0);
        // Monotone decreasing in staleness.
        assert!(staleness_weight(1, 0.5) > staleness_weight(2, 0.5));
        assert!(staleness_weight(2, 0.5) > staleness_weight(10, 0.5));
        // Extreme inputs stay in [0, 1] and finite.
        for &(s, b) in &[(1usize, 1e6), (usize::MAX / 2, 8.0), (3, 1e-9), (1, f64::MAX)] {
            let w = staleness_weight(s, b);
            assert!(w.is_finite() && (0.0..=1.0).contains(&w), "({s},{b}) -> {w}");
        }
    }

    #[test]
    fn absorb_weight_equals_discounted_m_n() {
        // Absorbing a late client's partial with weight w must equal
        // adding that client directly with m_n·w: the discount acts on
        // num and den alike, exactly as Eq. 4 with m_n ← m_n·(1+s)^-β.
        check("absorb weight = discounted m_n", 10, |rng| {
            let spec = ModelSpec::get("mlp", 0.25).unwrap();
            let prev = spec.init_params(rng);
            let fresh = perturbed(&prev, rng, 0.05);
            let late = perturbed(&prev, rng, 0.05);
            let mask = crate::selection::select_mask(
                crate::selection::Policy::Random,
                &spec,
                &prev,
                &late,
                None,
                rng.range_f64(0.0, 0.8),
                rng,
            )
            .to_elementwise(&spec);
            let full = ChannelMask::full(&spec).to_elementwise(&spec);
            let s = rng.int_range(1, 6);
            let beta = rng.range_f64(0.1, 3.0);
            let w = staleness_weight(s, beta);

            let via_absorb = {
                let mut agg = Aggregator::new(&spec, AggBackend::Rust);
                agg.add_client(&fresh, &full, 3.0, None).unwrap();
                let mut part = Aggregator::new(&spec, AggBackend::Rust);
                part.add_client(&late, &mask, 2.0, None).unwrap();
                agg.absorb(&part, w).unwrap();
                agg.finalize(&prev, None).unwrap()
            };
            let via_m_n = {
                let mut agg = Aggregator::new(&spec, AggBackend::Rust);
                agg.add_client(&fresh, &full, 3.0, None).unwrap();
                agg.add_client(&late, &mask, 2.0 * w, None).unwrap();
                agg.finalize(&prev, None).unwrap()
            };
            for (a, b) in via_absorb.iter().zip(&via_m_n) {
                close_slice(a.data(), b.data(), 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn staleness_fold_never_corrupts_eq4() {
        // Property (semi-async safety): folding any mix of fresh and
        // arbitrarily stale clients under any β ≥ 0 never produces NaN or
        // a negative denominator — every finalized position is finite and
        // uncovered positions still fall back to prev.
        check("staleness fold finite", 15, |rng| {
            let spec = ModelSpec::get("mlp", 0.25).unwrap();
            let prev = spec.init_params(rng);
            let beta = rng.range_f64(0.0, 6.0);
            let mut agg = Aggregator::new(&spec, AggBackend::Rust);
            let n_fresh = rng.int_range(0, 4);
            for _ in 0..n_fresh {
                let c = perturbed(&prev, rng, 0.1);
                let mask = crate::selection::select_mask(
                    crate::selection::Policy::Random,
                    &spec,
                    &prev,
                    &c,
                    None,
                    rng.range_f64(0.0, 0.9),
                    rng,
                )
                .to_elementwise(&spec);
                let m_n = rng.range_f64(0.5, 200.0) as f32;
                agg.add_client(&c, &mask, m_n, None).unwrap();
            }
            for _ in 0..rng.int_range(1, 5) {
                let s = rng.int_range(1, 50);
                let c = perturbed(&prev, rng, 0.1);
                let mask = crate::selection::select_mask(
                    crate::selection::Policy::Random,
                    &spec,
                    &prev,
                    &c,
                    None,
                    rng.range_f64(0.0, 0.9),
                    rng,
                )
                .to_elementwise(&spec);
                let mut part = Aggregator::new(&spec, AggBackend::Rust);
                part.add_client(&c, &mask, rng.range_f64(0.5, 200.0) as f32, None).unwrap();
                let w = staleness_weight(s, beta);
                if !(w.is_finite() && (0.0..=1.0).contains(&w)) {
                    return Err(format!("weight out of range: s={s} beta={beta} w={w}"));
                }
                agg.absorb(&part, w).unwrap();
            }
            let out = agg.finalize(&prev, None).unwrap();
            for (i, t) in out.iter().enumerate() {
                for (j, &x) in t.data().iter().enumerate() {
                    if !x.is_finite() {
                        return Err(format!("non-finite output at [{i}][{j}]: {x}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_merge_eq5() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(4);
        let global = spec.init_params(&mut rng);
        let mut local = perturbed(&global, &mut rng, 0.2);
        let local_copy: Vec<Tensor> = local.clone();
        let mask = ChannelMask::full(&spec).to_elementwise(&spec);
        // full mask -> local becomes global
        sparse_merge(&mut local, &global, &mask);
        for (a, b) in local.iter().zip(&global) {
            assert_eq!(a.data(), b.data());
        }
        // empty mask -> local unchanged
        let zero_mask: Vec<Tensor> = mask
            .iter()
            .map(|t| Tensor::zeros(t.shape().to_vec()))
            .collect();
        let mut local2 = local_copy.clone();
        sparse_merge(&mut local2, &global, &zero_mask);
        for (a, b) in local2.iter().zip(&local_copy) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn absorb_wire_smoke_matches_add_client() {
        // The thorough bitwise sweep lives in tests/wire_equivalence.rs;
        // this is the in-module smoke: one masked client via the wire
        // path equals the dense mask path bit for bit.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(9);
        let prev = spec.init_params(&mut rng);
        let client = perturbed(&prev, &mut rng, 0.1);
        let mask = crate::selection::select_mask(
            crate::selection::Policy::Random,
            &spec,
            &prev,
            &client,
            None,
            0.6,
            &mut rng,
        );
        let mut dense = Aggregator::new(&spec, AggBackend::Rust);
        let elems = mask.to_elementwise(&spec);
        dense.add_client(&client, &elems, 3.0, None).unwrap();
        let mut wire = Aggregator::new(&spec, AggBackend::Rust);
        let up = crate::codec::encode_upload(&mask, &client, &spec);
        wire.absorb_wire(&up, 3.0).unwrap();
        assert_eq!(wire.clients_added(), 1);
        let a = dense.finalize(&prev, None).unwrap();
        let b = wire.finalize(&prev, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data(), y.data());
        }
    }

    #[test]
    fn absorb_wire_rejects_geometry_mismatch() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let other = ModelSpec::get("cnn1", 0.25).unwrap();
        let mut rng = Rng::new(10);
        let params = other.init_params(&mut rng);
        let up = crate::codec::encode_upload(&ChannelMask::full(&other), &params, &other);
        let mut agg = Aggregator::new(&spec, AggBackend::Rust);
        assert!(agg.absorb_wire(&up, 1.0).is_err(), "layer-count mismatch accepted");
        assert_eq!(agg.clients_added(), 0);
    }
}
