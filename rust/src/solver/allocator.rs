//! Dropout-rate allocation (paper Eq. 14–17).
//!
//! Problem (per round, solved by the server):
//!
//! ```text
//! min_{D, t}  t + δ Σ_n re_n D_n
//! s.t.        0 ≤ D_n ≤ D_max
//!             Σ_n U_n (1 - D_n) = A_server Σ_n U_n      (byte budget)
//!             t ≥ t_n^cmp + U_n (1 - D_n) (1/r_u + 1/r_d)  ∀n
//! ```
//!
//! Two solvers:
//! * [`allocate_lp`] — builds the LP and calls the simplex (reference).
//! * [`allocate_fast`] — ternary search over the deadline `t`; for fixed
//!   `t` each client has a dropout lower bound `L_n(t)`, and the byte
//!   budget is filled greedily in increasing penalty-density order
//!   (δ·re_n/U_n). O(N log N) per probe; exact for this LP structure.
//!
//! Property tests assert both agree in objective across random instances.

use super::lp::{Cmp, Lp};

/// Per-client inputs (all in consistent units; we use bytes and seconds).
#[derive(Clone, Debug)]
pub struct AllocInput {
    /// U_n — full local model size in bytes.
    pub u_bytes: f64,
    /// t_n^cmp — local training time for the round (Eq. 7).
    pub t_cmp: f64,
    /// 1/r_u + 1/r_d — seconds per byte over both links (Eq. 9/11).
    pub sec_per_byte: f64,
    /// re_n — data/model-heterogeneity regularizer (Eq. 13).
    pub re: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct AllocParams {
    /// D_max — maximal dropout rate (e.g. 0.8).
    pub d_max: f64,
    /// A_server — required fraction of total parameter bytes (e.g. 0.6).
    pub a_server: f64,
    /// δ — penalty factor trading round time against heterogeneity terms.
    pub delta: f64,
}

#[derive(Clone, Debug)]
pub struct Allocation {
    /// D_n per client.
    pub d: Vec<f64>,
    /// Achieved round deadline max_n(t_cmp + upload/download time).
    pub t_server: f64,
    /// Objective value t + δ Σ re_n D_n.
    pub objective: f64,
}

/// The actual round time induced by a dropout vector.
pub fn round_time(inputs: &[AllocInput], d: &[f64]) -> f64 {
    inputs
        .iter()
        .zip(d)
        .map(|(c, &dn)| c.t_cmp + c.u_bytes * (1.0 - dn) * c.sec_per_byte)
        .fold(0.0, f64::max)
}

fn objective(inputs: &[AllocInput], p: &AllocParams, d: &[f64]) -> f64 {
    round_time(inputs, d)
        + p.delta
            * inputs
                .iter()
                .zip(d)
                .map(|(c, &dn)| c.re * dn)
                .sum::<f64>()
}

/// Feasibility: the budget must be reachable with D ∈ [0, D_max].
pub fn feasible(inputs: &[AllocInput], p: &AllocParams) -> bool {
    let total: f64 = inputs.iter().map(|c| c.u_bytes).sum();
    let dropped = (1.0 - p.a_server) * total;
    dropped >= -1e-9 && dropped <= p.d_max * total + 1e-9
}

/// Reference solver via the general simplex.
pub fn allocate_lp(inputs: &[AllocInput], p: &AllocParams) -> anyhow::Result<Allocation> {
    anyhow::ensure!(feasible(inputs, p), "infeasible: A_server={} D_max={}", p.a_server, p.d_max);
    let n = inputs.len();
    // variables: x[0..n] = D_n, x[n] = t
    let mut c = vec![0.0f64; n + 1];
    for (i, inp) in inputs.iter().enumerate() {
        c[i] = p.delta * inp.re;
    }
    c[n] = 1.0;
    let mut lp = Lp::new(n + 1, c);
    // D_n <= d_max
    for i in 0..n {
        let mut row = vec![0.0; n + 1];
        row[i] = 1.0;
        lp.add_row(row, Cmp::Le, p.d_max);
    }
    // budget equality: Σ U_n D_n = (1 - A) Σ U_n
    let total: f64 = inputs.iter().map(|x| x.u_bytes).sum();
    let mut row = vec![0.0; n + 1];
    for (i, inp) in inputs.iter().enumerate() {
        row[i] = inp.u_bytes;
    }
    lp.add_row(row, Cmp::Eq, (1.0 - p.a_server) * total);
    // deadline rows: a_n D_n + t >= t_cmp_n + a_n  with a_n = U_n * spb
    for (i, inp) in inputs.iter().enumerate() {
        let a = inp.u_bytes * inp.sec_per_byte;
        let mut row = vec![0.0; n + 1];
        row[i] = a;
        row[n] = 1.0;
        lp.add_row(row, Cmp::Ge, inp.t_cmp + a);
    }
    let sol = lp.solve().map_err(|e| anyhow::anyhow!("{e}"))?;
    let d = sol.x[..n].to_vec();
    Ok(Allocation {
        t_server: round_time(inputs, &d),
        objective: objective(inputs, p, &d),
        d,
    })
}

/// Fast structured solver (the production path).
pub fn allocate_fast(inputs: &[AllocInput], p: &AllocParams) -> anyhow::Result<Allocation> {
    anyhow::ensure!(feasible(inputs, p), "infeasible: A_server={} D_max={}", p.a_server, p.d_max);
    let n = inputs.len();
    let budget_drop: f64 =
        (1.0 - p.a_server) * inputs.iter().map(|x| x.u_bytes).sum::<f64>();

    // Order clients by penalty density (cheapest dropout first).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| {
        let di = inputs[i].re / inputs[i].u_bytes;
        let dj = inputs[j].re / inputs[j].u_bytes;
        di.partial_cmp(&dj).unwrap()
    });

    // For a candidate deadline t, the minimal-dropout profile.
    let lower = |t: f64| -> Option<Vec<f64>> {
        let mut l = Vec::with_capacity(n);
        for inp in inputs {
            let a = inp.u_bytes * inp.sec_per_byte;
            let lb = if a <= 0.0 { 0.0 } else { (1.0 - (t - inp.t_cmp) / a).max(0.0) };
            if lb > p.d_max + 1e-12 {
                return None; // this deadline is unreachable even at D_max
            }
            l.push(lb.min(p.d_max));
        }
        Some(l)
    };

    // Given t: start at the lower bounds, greedily add dropout to the
    // cheapest clients until the budget equality holds.
    let profile = |t: f64| -> Option<Vec<f64>> {
        let mut d = lower(t)?;
        let mut dropped: f64 =
            d.iter().zip(inputs).map(|(dn, c)| dn * c.u_bytes).sum();
        if dropped > budget_drop + 1e-6 {
            return None; // deadline too tight: lower bounds exceed budget
        }
        for &i in &order {
            if dropped >= budget_drop - 1e-12 {
                break;
            }
            let room = (p.d_max - d[i]) * inputs[i].u_bytes;
            let take = room.min(budget_drop - dropped);
            d[i] += take / inputs[i].u_bytes;
            dropped += take;
        }
        Some(d)
    };

    // Search range for t.
    let t_lo = inputs
        .iter()
        .map(|c| c.t_cmp + c.u_bytes * (1.0 - p.d_max) * c.sec_per_byte)
        .fold(0.0, f64::max);
    let t_hi = inputs
        .iter()
        .map(|c| c.t_cmp + c.u_bytes * c.sec_per_byte)
        .fold(0.0, f64::max);

    let eval = |t: f64| -> Option<(f64, Vec<f64>)> {
        let d = profile(t)?;
        Some((objective(inputs, p, &d), d))
    };

    // Find the smallest feasible t by bisection. profile() feasibility is
    // monotone in t and t_hi is always feasible (all lower bounds are 0
    // there, and feasible() already admitted the budget), so the
    // invariant "hi feasible, lo infeasible" holds throughout and the
    // bisection limit — not `lo` — is the feasible left endpoint.
    let t_feas = if eval(t_lo).is_some() {
        t_lo
    } else {
        let (mut lo, mut hi) = (t_lo, t_hi);
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if eval(mid).is_some() {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    };
    let t_end = t_hi.max(t_feas);

    // Ternary-search the convex piecewise-linear objective on
    // [t_feas, t_end] …
    let (mut a, mut b) = (t_feas, t_end);
    for _ in 0..200 {
        let m1 = a + (b - a) / 3.0;
        let m2 = b - (b - a) / 3.0;
        let f1 = eval(m1).map(|x| x.0).unwrap_or(f64::INFINITY);
        let f2 = eval(m2).map(|x| x.0).unwrap_or(f64::INFINITY);
        if f1 <= f2 {
            b = m2;
        } else {
            a = m1;
        }
    }
    // … and probe every kink of the value function explicitly. obj(t) is
    // linear between the per-client regime changes, which happen exactly
    // where a client's deadline lower bound L_n(t) leaves a box face:
    // t = t_cmp_n + U_n·(1−D)·spb_n for D ∈ {0, D_max}. Probing all 2N
    // kinks plus both interval ends makes the search exact on the
    // piecewise-linear objective instead of trusting the smooth-function
    // ternary descent alone.
    let mut candidates = vec![a, 0.5 * (a + b), b, t_feas, t_end];
    for inp in inputs {
        let traffic = inp.u_bytes * inp.sec_per_byte;
        candidates.push(inp.t_cmp + traffic);
        candidates.push(inp.t_cmp + traffic * (1.0 - p.d_max));
    }
    let mut best: Option<(f64, Vec<f64>)> = None;
    for t in candidates {
        let t = t.clamp(t_feas, t_end);
        if let Some((obj, d)) = eval(t) {
            if best.as_ref().map(|(o, _)| obj < *o - 1e-12).unwrap_or(true) {
                best = Some((obj, d));
            }
        }
    }
    let (obj, d) = best.ok_or_else(|| anyhow::anyhow!("no feasible deadline"))?;
    Ok(Allocation { t_server: round_time(inputs, &d), objective: obj, d })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, close};
    use crate::util::rng::Rng;

    fn random_instance(rng: &mut Rng, n: usize) -> (Vec<AllocInput>, AllocParams) {
        let inputs: Vec<AllocInput> = (0..n)
            .map(|_| AllocInput {
                u_bytes: rng.range_f64(1e4, 1e6),
                t_cmp: rng.range_f64(0.1, 5.0),
                sec_per_byte: rng.range_f64(1e-6, 1e-4),
                re: rng.range_f64(0.0, 1.0),
            })
            .collect();
        let d_max = rng.range_f64(0.5, 0.9);
        let a_server = rng.range_f64(1.0 - d_max + 0.05, 0.95);
        let p = AllocParams { d_max, a_server, delta: rng.range_f64(0.0, 5.0) };
        (inputs, p)
    }

    #[test]
    fn budget_equality_holds() {
        check("fast allocator meets byte budget", 40, |rng| {
            let n = rng.int_range(2, 30);
            let (inputs, p) = random_instance(rng, n);
            let alloc = allocate_fast(&inputs, &p).map_err(|e| e.to_string())?;
            let total: f64 = inputs.iter().map(|c| c.u_bytes).sum();
            let uploaded: f64 = inputs
                .iter()
                .zip(&alloc.d)
                .map(|(c, &d)| c.u_bytes * (1.0 - d))
                .sum();
            close(uploaded, p.a_server * total, 1e-6)?;
            if alloc.d.iter().any(|&d| !(-1e-9..=p.d_max + 1e-9).contains(&d)) {
                return Err(format!("bounds violated: {:?}", alloc.d));
            }
            Ok(())
        });
    }

    #[test]
    fn fast_matches_simplex_objective() {
        // Tight tolerance over many instances: the kink-probing search is
        // exact on the piecewise-linear value function, so fast and
        // simplex must agree to solver precision, not just roughly.
        check("fast == simplex", 120, |rng| {
            let n = rng.int_range(2, 12);
            let (inputs, p) = random_instance(rng, n);
            let f = allocate_fast(&inputs, &p).map_err(|e| e.to_string())?;
            let l = allocate_lp(&inputs, &p).map_err(|e| e.to_string())?;
            if f.objective > l.objective + 1e-6 * l.objective.abs().max(1.0) {
                return Err(format!(
                    "fast {} worse than simplex {}",
                    f.objective, l.objective
                ));
            }
            close(f.objective, l.objective, 1e-5)
        });
    }

    #[test]
    fn stragglers_get_higher_dropout() {
        // Identical clients except client 0 is much slower -> D_0 highest.
        let mut inputs: Vec<AllocInput> = (0..5)
            .map(|_| AllocInput {
                u_bytes: 1e5,
                t_cmp: 1.0,
                sec_per_byte: 1e-5,
                re: 0.5,
            })
            .collect();
        inputs[0].sec_per_byte = 1e-4;
        let p = AllocParams { d_max: 0.8, a_server: 0.6, delta: 0.1 };
        let alloc = allocate_fast(&inputs, &p).unwrap();
        let d0 = alloc.d[0];
        assert!(
            alloc.d[1..].iter().all(|&d| d <= d0 + 1e-9),
            "{:?}",
            alloc.d
        );
    }

    #[test]
    fn high_re_clients_get_lower_dropout() {
        // All same speed; client 0 has much higher regularizer.
        let inputs: Vec<AllocInput> = (0..4)
            .map(|i| AllocInput {
                u_bytes: 1e5,
                t_cmp: 1.0,
                sec_per_byte: 1e-5,
                re: if i == 0 { 10.0 } else { 0.1 },
            })
            .collect();
        let p = AllocParams { d_max: 0.8, a_server: 0.6, delta: 1.0 };
        let alloc = allocate_fast(&inputs, &p).unwrap();
        assert!(
            alloc.d[0] <= alloc.d[1..].iter().fold(1.0f64, |a, &b| a.min(b)) + 1e-9,
            "{:?}",
            alloc.d
        );
    }

    #[test]
    fn a_server_one_means_no_dropout() {
        let (inputs, _) = random_instance(&mut Rng::new(5), 6);
        let p = AllocParams { d_max: 0.8, a_server: 1.0, delta: 1.0 };
        let alloc = allocate_fast(&inputs, &p).unwrap();
        assert!(alloc.d.iter().all(|&d| d.abs() < 1e-9));
    }

    #[test]
    fn infeasible_budget_rejected() {
        let (inputs, _) = random_instance(&mut Rng::new(6), 4);
        let p = AllocParams { d_max: 0.2, a_server: 0.5, delta: 1.0 };
        assert!(allocate_fast(&inputs, &p).is_err());
        assert!(allocate_lp(&inputs, &p).is_err());
    }

    #[test]
    fn deadline_reported_matches_profile() {
        let (inputs, p) = random_instance(&mut Rng::new(7), 10);
        let alloc = allocate_fast(&inputs, &p).unwrap();
        close(alloc.t_server, round_time(&inputs, &alloc.d), 1e-12).unwrap();
    }

    #[test]
    fn delta_zero_minimizes_pure_time() {
        check("delta=0 -> time no worse than delta>0", 20, |rng| {
            let (inputs, mut p) = random_instance(rng, 8);
            p.delta = 0.0;
            let t0 = allocate_fast(&inputs, &p).map_err(|e| e.to_string())?.t_server;
            p.delta = 5.0;
            let t5 = allocate_fast(&inputs, &p).map_err(|e| e.to_string())?.t_server;
            if t0 <= t5 + 1e-6 {
                Ok(())
            } else {
                Err(format!("t(δ=0)={t0} > t(δ=5)={t5}"))
            }
        });
    }
}
