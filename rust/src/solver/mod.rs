//! The dropout-rate allocation solver (paper §4.1, Eq. 14–17).
//!
//! Two independent implementations, cross-validated by property tests:
//!
//! * [`lp`] — a general dense **two-phase simplex** (the offline stand-in
//!   for the paper's CVXOPT/GUROBI call); exact for this LP class.
//! * [`allocator`] — a **specialized O(N log N)** solver exploiting the
//!   problem structure (ternary search over the round deadline `t`, greedy
//!   budget fill by penalty density) — the production hot path.

pub mod allocator;
pub mod lp;

pub use allocator::{allocate_fast, allocate_lp, AllocInput, AllocParams, Allocation};
pub use lp::{Cmp, Lp, LpError, LpSolution};
