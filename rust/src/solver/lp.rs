//! Dense two-phase primal simplex with Bland's rule.
//!
//! General enough for the Eq. 16/17 LP (≤ / ≥ / = rows, non-negative
//! variables; upper bounds are rows). Problem sizes here are ~100×300, far
//! below anything needing a revised/sparse implementation.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

#[derive(Clone, Debug)]
pub struct Lp {
    /// Number of structural variables (all constrained x >= 0).
    pub n: usize,
    /// Objective coefficients (minimized).
    pub c: Vec<f64>,
    /// Rows: (coefficients over structural vars, comparator, rhs).
    pub rows: Vec<(Vec<f64>, Cmp, f64)>,
}

#[derive(Clone, Debug)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
}

#[derive(Debug)]
pub enum LpError {
    Infeasible,
    Unbounded,
    NumericFailure(&'static str),
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::Infeasible => write!(f, "LP infeasible"),
            LpError::Unbounded => write!(f, "LP unbounded"),
            LpError::NumericFailure(m) => write!(f, "LP numeric failure: {m}"),
        }
    }
}

impl std::error::Error for LpError {}

const EPS: f64 = 1e-9;
const MAX_PIVOTS: usize = 200_000;

impl Lp {
    pub fn new(n: usize, c: Vec<f64>) -> Lp {
        assert_eq!(c.len(), n);
        Lp { n, c, rows: Vec::new() }
    }

    pub fn add_row(&mut self, coeffs: Vec<f64>, cmp: Cmp, rhs: f64) {
        assert_eq!(coeffs.len(), self.n);
        self.rows.push((coeffs, cmp, rhs));
    }

    /// Solve min cᵀx s.t. rows, x ≥ 0.
    pub fn solve(&self) -> Result<LpSolution, LpError> {
        let m = self.rows.len();
        if m == 0 {
            // Unconstrained over x >= 0: optimum at 0 unless some cost is
            // negative (then the LP is unbounded below).
            if self.c.iter().any(|&c| c < 0.0) {
                return Err(LpError::Unbounded);
            }
            return Ok(LpSolution { x: vec![0.0; self.n], objective: 0.0 });
        }
        // Normalize rows to b >= 0.
        let mut rows: Vec<(Vec<f64>, Cmp, f64)> = self
            .rows
            .iter()
            .map(|(a, cmp, b)| {
                if *b < 0.0 {
                    let flipped = match cmp {
                        Cmp::Le => Cmp::Ge,
                        Cmp::Ge => Cmp::Le,
                        Cmp::Eq => Cmp::Eq,
                    };
                    (a.iter().map(|x| -x).collect(), flipped, -b)
                } else {
                    (a.clone(), *cmp, *b)
                }
            })
            .collect();

        // Column layout: [structural | slacks/surplus | artificials | rhs]
        let mut n_slack = 0;
        let mut n_art = 0;
        for (_, cmp, _) in &rows {
            match cmp {
                Cmp::Le => n_slack += 1,
                Cmp::Ge => {
                    n_slack += 1;
                    n_art += 1;
                }
                Cmp::Eq => n_art += 1,
            }
        }
        let ncols = self.n + n_slack + n_art + 1;
        let rhs_col = ncols - 1;
        let mut tab = vec![vec![0.0f64; ncols]; m];
        let mut basis = vec![usize::MAX; m];
        let mut slack_i = self.n;
        let mut art_i = self.n + n_slack;
        let mut art_cols = Vec::new();
        for (r, (a, cmp, b)) in rows.drain(..).enumerate() {
            tab[r][..self.n].copy_from_slice(&a);
            tab[r][rhs_col] = b;
            match cmp {
                Cmp::Le => {
                    tab[r][slack_i] = 1.0;
                    basis[r] = slack_i;
                    slack_i += 1;
                }
                Cmp::Ge => {
                    tab[r][slack_i] = -1.0;
                    slack_i += 1;
                    tab[r][art_i] = 1.0;
                    basis[r] = art_i;
                    art_cols.push(art_i);
                    art_i += 1;
                }
                Cmp::Eq => {
                    tab[r][art_i] = 1.0;
                    basis[r] = art_i;
                    art_cols.push(art_i);
                    art_i += 1;
                }
            }
        }

        // ---- Phase 1: minimize sum of artificials ----
        if n_art > 0 {
            let mut z = vec![0.0f64; ncols];
            for r in 0..m {
                if art_cols.contains(&basis[r]) {
                    for c in 0..ncols {
                        z[c] += tab[r][c];
                    }
                }
            }
            // reduced costs: for artificial objective, cost=1 on artificials
            // z currently holds sum of basic artificial rows.
            simplex_iterate(&mut tab, &mut basis, &mut z, |col| {
                if art_cols.contains(&col) {
                    1.0
                } else {
                    0.0
                }
            })?;
            if z[rhs_col] > 1e-7 {
                return Err(LpError::Infeasible);
            }
            // Drive any artificial still in the basis out (degenerate).
            for r in 0..m {
                if art_cols.contains(&basis[r]) {
                    if let Some(col) = (0..self.n + n_slack)
                        .find(|&c| tab[r][c].abs() > EPS)
                    {
                        pivot(&mut tab, &mut basis, r, col);
                    }
                }
            }
        }

        // ---- Phase 2: original objective ----
        let cost = |col: usize| -> f64 {
            if col < self.n {
                self.c[col]
            } else {
                0.0
            }
        };
        // z row: z[c] = c_B^T B^-1 A_c - c_c form; build from basis.
        let mut z = vec![0.0f64; ncols];
        for r in 0..m {
            let cb = cost(basis[r]);
            if cb != 0.0 {
                for c in 0..ncols {
                    z[c] += cb * tab[r][c];
                }
            }
        }
        // forbid artificial columns re-entering by treating them as +inf cost:
        for &a in &art_cols {
            z[a] = f64::NEG_INFINITY; // reduced cost z[a]-cost(a) very negative -> never entering
        }
        simplex_iterate(&mut tab, &mut basis, &mut z, cost)?;

        let mut x = vec![0.0f64; self.n];
        for r in 0..m {
            if basis[r] < self.n {
                x[basis[r]] = tab[r][rhs_col];
            }
        }
        let objective = x.iter().zip(&self.c).map(|(a, b)| a * b).sum();
        Ok(LpSolution { x, objective })
    }
}

/// Pivot-until-optimal. `z` is maintained as c_B^T B^-1 A (so the reduced
/// cost of column j is z[j] - cost(j); entering columns have positive
/// reduced cost for a minimization tableau in this orientation).
fn simplex_iterate(
    tab: &mut [Vec<f64>],
    basis: &mut [usize],
    z: &mut [f64],
    cost: impl Fn(usize) -> f64,
) -> Result<(), LpError> {
    let m = tab.len();
    let ncols = tab[0].len();
    let rhs_col = ncols - 1;
    for _ in 0..MAX_PIVOTS {
        // Bland: smallest-index column with positive reduced cost.
        let mut entering = None;
        for c in 0..rhs_col {
            let rc = z[c] - cost(c);
            if rc > 1e-9 && z[c].is_finite() {
                entering = Some(c);
                break;
            }
        }
        let Some(col) = entering else { return Ok(()) };
        // Ratio test (Bland tie-break on basis index).
        let mut leave: Option<(usize, f64)> = None;
        for r in 0..m {
            if tab[r][col] > EPS {
                let ratio = tab[r][rhs_col] / tab[r][col];
                match leave {
                    None => leave = Some((r, ratio)),
                    Some((lr, lratio)) => {
                        if ratio < lratio - EPS
                            || (ratio < lratio + EPS && basis[r] < basis[lr])
                        {
                            leave = Some((r, ratio));
                        }
                    }
                }
            }
        }
        let Some((row, _)) = leave else {
            return Err(LpError::Unbounded);
        };
        pivot_with_z(tab, basis, z, row, col, &cost);
    }
    Err(LpError::NumericFailure("pivot limit"))
}

fn pivot(tab: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize) {
    let ncols = tab[0].len();
    let piv = tab[row][col];
    for c in 0..ncols {
        tab[row][c] /= piv;
    }
    for r in 0..tab.len() {
        if r != row && tab[r][col].abs() > 0.0 {
            let f = tab[r][col];
            for c in 0..ncols {
                tab[r][c] -= f * tab[row][c];
            }
        }
    }
    basis[row] = col;
}

fn pivot_with_z(
    tab: &mut [Vec<f64>],
    basis: &mut [usize],
    z: &mut [f64],
    row: usize,
    col: usize,
    cost: &impl Fn(usize) -> f64,
) {
    pivot(tab, basis, row, col);
    // Rebuild z from scratch (m is small; keeps numerics clean).
    let ncols = tab[0].len();
    let frozen: Vec<bool> = z.iter().map(|v| v.is_infinite()).collect();
    for zc in z.iter_mut() {
        if zc.is_finite() {
            *zc = 0.0;
        } else {
            *zc = f64::NEG_INFINITY;
        }
    }
    for r in 0..tab.len() {
        let cb = cost(basis[r]);
        if cb != 0.0 {
            for c in 0..ncols {
                if !frozen[c] {
                    z[c] += cb * tab[r][c];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn simple_min_le() {
        // min -x - y s.t. x + y <= 4, x <= 2  -> x=2, y=2, obj=-4
        let mut lp = Lp::new(2, vec![-1.0, -1.0]);
        lp.add_row(vec![1.0, 1.0], Cmp::Le, 4.0);
        lp.add_row(vec![1.0, 0.0], Cmp::Le, 2.0);
        let s = lp.solve().unwrap();
        assert_close(s.objective, -4.0);
        assert_close(s.x[0] + s.x[1], 4.0);
    }

    #[test]
    fn equality_and_ge() {
        // min x + 2y s.t. x + y = 3, x >= 1  -> x=3,y=0 obj=3
        let mut lp = Lp::new(2, vec![1.0, 2.0]);
        lp.add_row(vec![1.0, 1.0], Cmp::Eq, 3.0);
        lp.add_row(vec![1.0, 0.0], Cmp::Ge, 1.0);
        let s = lp.solve().unwrap();
        assert_close(s.objective, 3.0);
        assert_close(s.x[0], 3.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new(1, vec![1.0]);
        lp.add_row(vec![1.0], Cmp::Le, 1.0);
        lp.add_row(vec![1.0], Cmp::Ge, 2.0);
        assert!(matches!(lp.solve(), Err(LpError::Infeasible)));
    }

    #[test]
    fn unbounded_detected() {
        // min -x, x >= 0, no upper bound
        let lp = Lp::new(1, vec![-1.0]);
        assert!(matches!(lp.solve(), Err(LpError::Unbounded)));
    }

    #[test]
    fn negative_rhs_normalization() {
        // min x s.t. -x <= -2  (i.e. x >= 2)
        let mut lp = Lp::new(1, vec![1.0]);
        lp.add_row(vec![-1.0], Cmp::Le, -2.0);
        let s = lp.solve().unwrap();
        assert_close(s.x[0], 2.0);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Beale-like degeneracy smoke: solved without hitting pivot limit.
        let mut lp = Lp::new(4, vec![-0.75, 150.0, -0.02, 6.0]);
        lp.add_row(vec![0.25, -60.0, -0.04, 9.0], Cmp::Le, 0.0);
        lp.add_row(vec![0.5, -90.0, -0.02, 3.0], Cmp::Le, 0.0);
        lp.add_row(vec![0.0, 0.0, 1.0, 0.0], Cmp::Le, 1.0);
        let s = lp.solve().unwrap();
        assert_close(s.objective, -0.05);
    }

    #[test]
    fn random_lps_satisfy_kkt_feasibility() {
        use crate::util::proptest::check;
        use crate::util::rng::Rng;
        check("lp solutions are feasible", 40, |rng: &mut Rng| {
            let n = rng.int_range(2, 6);
            let m = rng.int_range(1, 5);
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 2.0)).collect();
            let mut lp = Lp::new(n, c);
            for _ in 0..m {
                let a: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 1.0)).collect();
                lp.add_row(a, Cmp::Ge, rng.range_f64(0.5, 3.0));
            }
            let s = lp.solve().map_err(|e| format!("{e}"))?;
            for (a, _, b) in &lp.rows {
                let lhs: f64 = a.iter().zip(&s.x).map(|(x, y)| x * y).sum();
                if lhs < b - 1e-6 {
                    return Err(format!("row violated: {lhs} < {b}"));
                }
            }
            if s.x.iter().any(|&x| x < -1e-9) {
                return Err("negative variable".into());
            }
            Ok(())
        });
    }
}
