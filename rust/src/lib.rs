//! # feddd
//!
//! Full-system reproduction of **FedDD: Toward Communication-efficient
//! Federated Learning with Differential Parameter Dropout** (IEEE TMC 2023)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the FL coordinator: the dropout-rate allocation
//!   LP (Eq. 16/17), uploaded-parameter selection (Eq. 21), mask-weighted
//!   aggregation (Eq. 4), the synchronous round engine with virtual-time
//!   accounting (Eq. 7–12), plus the FedAvg / FedCS / Oort baselines and
//!   the complete simulation substrate (synthetic datasets, partitioners,
//!   device/network simulator).
//! * **L2** — JAX model fwd/bwd (`python/compile/model.py`), AOT-lowered to
//!   HLO text once at build time (`make artifacts`).
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the dense
//!   layers, masked aggregation and importance scoring, lowered into the
//!   same HLO modules (`interpret=True`).
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and the coordinator
//! drives them from Rust.
//!
//! See `DESIGN.md` for the experiment index mapping every paper figure and
//! table to a module and a `feddd figure <id>` command.

pub mod aggregation;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod selection;
pub mod simnet;
pub mod solver;
pub mod tensor;
pub mod util;

/// Commonly used items.
pub mod prelude {
    pub use crate::config::ExpConfig;
    pub use crate::coordinator::{run_experiment, FedDdServer, FedRun, RoundOutcome};
    pub use crate::data::{FedDataset, Partition};
    pub use crate::metrics::RunResult;
    pub use crate::model::{ModelId, ModelRegistry};
    pub use crate::simnet::Fleet;
    pub use crate::tensor::Tensor;
    pub use crate::util::rng::Rng;
}
