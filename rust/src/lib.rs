//! # feddd
//!
//! Full-system reproduction of **FedDD: Toward Communication-efficient
//! Federated Learning with Differential Parameter Dropout** (IEEE TMC 2023)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the FL coordinator: the dropout-rate allocation
//!   LP (Eq. 16/17), uploaded-parameter selection (Eq. 21), mask-weighted
//!   aggregation (Eq. 4), the synchronous round engine with virtual-time
//!   accounting (Eq. 7–12), plus the FedAvg / FedCS / Oort baselines and
//!   the complete simulation substrate (synthetic datasets, partitioners,
//!   device/network simulator).
//! * **L2** — JAX model fwd/bwd (`python/compile/model.py`), AOT-lowered to
//!   HLO text once at build time (`make artifacts`).
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the dense
//!   layers, masked aggregation and importance scoring, lowered into the
//!   same HLO modules (`interpret=True`).
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and the coordinator
//! drives them from Rust. Manifests with `"exec": "native"` instead route
//! through a pure-Rust FC executor (`runtime::native`), which needs no
//! libxla and powers tests/benches on plain CPU hosts.
//!
//! # Parallel round execution (`workers`)
//!
//! FedDD's round body is per-client independent, so the engine fans local
//! training, Algorithm-2 mask selection and the Eq. 4 masked accumulation
//! out over a **persistent** pool of `ExpConfig::workers` threads —
//! spawned once per run, with per-worker scratch arenas (materialization,
//! batch and executor buffers) reused across micro-batches and rounds;
//! total OS thread spawns per run are O(workers), never O(micro-batches)
//! (`util::threadpool`, DESIGN.md §Worker-Pool). Aggregation is
//! *sharded*: each worker task accumulates a contiguous,
//! worker-count-independent chunk of participants into private
//! `num`/`den` partials which are merged pairwise in fixed order, so
//! every run is bitwise-identical to the sequential (`workers = 1`) run —
//! see `coordinator::engine`, `rust/tests/parallel_round.rs` and the
//! pooled-engine battery `rust/tests/pool_determinism.rs`.
//!
//! # Sparse upload wire codec (`codec`)
//!
//! Uploads are not estimated, they are *encoded*: `codec::encode_upload`
//! lays each layer's kept units out as dense / bitmap / COO (auto-picking
//! the smallest), the simnet charges `t_up` from the realized
//! `WireUpload::wire_len()`, and `Aggregator::absorb_wire` folds the
//! bitmap/COO payloads straight into the Eq. 4 partials without ever
//! materializing dense mask tensors — bitwise-identical to the dense
//! path (`rust/tests/wire_equivalence.rs`). See DESIGN.md §8.
//!
//! # Semi-asynchronous rounds (`round_mode`)
//!
//! With `round_mode = "semi_async"` the barrier is replaced by an
//! event-driven scheduler: dispatched uploads become arrival events in a
//! virtual-time min-heap, the server closes a round at an arrival quorum
//! or deadline, and stragglers' uploads are buffered and folded into a
//! later round's Eq. 4 with a staleness discount `(1+s)^{-β}` — see
//! `coordinator::engine`, `simnet`, and DESIGN.md §7. The default
//! `round_mode = "sync"` stays bitwise-identical to the classic engine.
//!
//! # Client-state virtualization (fleet scale)
//!
//! Client models are never stored densely: each client holds an `Arc`
//! into a ring of shared global snapshots plus, when diverged, the
//! sparse residual of the channels its Eq. 5 downloads never overwrote
//! (`coordinator::state`, DESIGN.md §Fleet-Virtualization). Dense
//! parameters exist only inside the worker stage, so 10k–50k-client
//! fleets fit in memory (`n_clients` is the fleet-size knob; see the
//! `fleet` preset and `rust/benches/fleet.rs`), bitwise-identical to the
//! dense representation (`rust/tests/fleet_virtualization.rs`).
//!
//! # Scenario matrix (`scenarios`)
//!
//! `feddd matrix` runs a registry of documented evaluation scenarios
//! (geo testbed, class imbalance, heterogeneous fleet, diurnal /
//! flash-crowd availability traces, mid-round churn) crossed with
//! schemes × seeds at smoke/small/medium tiers, emits per-cell JSON +
//! Markdown reports under `reports/`, and compares two reports
//! regression-only (`--compare`, mirrored by `ci/matrix_diff.py`). The
//! catalogue lives in `docs/SCENARIOS.md`; see [`scenarios`] and
//! DESIGN.md §Scenario-Matrix. Dropout-family baselines for context:
//! Federated Dropout (Caldas et al., arXiv:1812.07210) and Adaptive
//! Federated Dropout (Bouacida et al., arXiv:2011.04050).
//!
//! # Serve mode (`transport`)
//!
//! The round engine is transport-agnostic: drivers consume uploads
//! through the `coordinator::ingest` trait seam, with the in-process
//! `LocalTransport` as the default and [`transport`] as the socket-backed
//! implementation (`std::net` TCP, no new dependencies). `feddd serve`
//! binds the coordinator, `feddd agent` connects with a slot range,
//! rebuilds a bitwise replica of the run from the CONFIG frame, and
//! trains its slots on dispatch; a loopback serve reproduces the
//! in-process run's losses, accuracies and wire bytes exactly
//! (`rust/tests/serve_loopback.rs`, DESIGN.md §Serve).
//!
//! See `DESIGN.md` for the experiment index mapping every paper figure and
//! table to a module and a `feddd figure <id>` command.

pub mod aggregation;
pub mod baselines;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod scenarios;
pub mod selection;
pub mod simnet;
pub mod solver;
pub mod tensor;
pub mod transport;
pub mod util;

/// Commonly used items.
pub mod prelude {
    pub use crate::codec::{encode_upload, EncodingMix, WireUpload};
    pub use crate::config::ExpConfig;
    pub use crate::coordinator::{run_experiment, FedDdServer, FedRun, RoundOutcome};
    pub use crate::data::{FedDataset, Partition};
    pub use crate::metrics::RunResult;
    pub use crate::model::{ModelId, ModelRegistry};
    pub use crate::simnet::Fleet;
    pub use crate::tensor::Tensor;
    pub use crate::util::rng::Rng;
}
