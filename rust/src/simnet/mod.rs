//! System-heterogeneity simulator: client device + channel models and the
//! virtual-time accounting of Eq. 7–12.
//!
//! The paper's time axis is fully analytic (CPU cycles/sample over CPU
//! frequency; Shannon-capacity up/down links), so a virtual clock driven
//! by these formulas reproduces the T2A comparisons without the physical
//! testbed (DESIGN.md §3 substitution table).

use crate::util::rng::Rng;

/// Per-client device + channel profile.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    /// CPU cycles to process one sample (paper: [1,10] Megacycles).
    pub cycles_per_sample: f64,
    /// CPU frequency in Hz (paper: [1,10] GHz).
    pub cpu_hz: f64,
    /// Uplink rate r_n^u in bits/s (paper Table 4: [1,5]×10^4).
    pub up_bps: f64,
    /// Downlink rate r_n^d in bits/s (paper Table 4: [4,20]×10^4).
    pub down_bps: f64,
}

impl DeviceProfile {
    /// Computation latency for `samples` local samples (Eq. 7 generalized
    /// over the samples actually processed in the round).
    pub fn t_cmp(&self, samples: usize) -> f64 {
        self.cycles_per_sample * samples as f64 / self.cpu_hz
    }

    /// Upload time for `bytes` (Eq. 9).
    pub fn t_up(&self, bytes: f64) -> f64 {
        bytes * 8.0 / self.up_bps
    }

    /// Download time for `bytes` (Eq. 11).
    pub fn t_down(&self, bytes: f64) -> f64 {
        bytes * 8.0 / self.down_bps
    }

    /// Seconds per uploaded+downloaded byte (the allocator's `1/r_u+1/r_d`
    /// folded to bytes).
    pub fn sec_per_byte(&self) -> f64 {
        8.0 / self.up_bps + 8.0 / self.down_bps
    }
}

/// Shannon-capacity channel (Eq. 8/10): r = B log2(1 + p·h/N0).
pub fn shannon_rate_bps(bandwidth_hz: f64, tx_power: f64, gain: f64, noise: f64) -> f64 {
    bandwidth_hz * (1.0 + tx_power * gain / noise).log2()
}

/// A fleet of client profiles.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub profiles: Vec<DeviceProfile>,
}

impl Fleet {
    /// Table 4 simulation distribution: uniform draws per client.
    pub fn simulated(n: usize, rng: &mut Rng) -> Fleet {
        let profiles = (0..n)
            .map(|_| DeviceProfile {
                cycles_per_sample: rng.range_f64(1e6, 10e6),
                cpu_hz: rng.range_f64(1e9, 10e9),
                up_bps: rng.range_f64(1e4, 5e4),
                down_bps: rng.range_f64(4e4, 20e4),
            })
            .collect();
        Fleet { profiles }
    }

    /// Table 5 geo-distributed testbed: 10 clients whose compute/network
    /// spread mirrors the paper's VM fleet (GPU class → compute speed;
    /// distance from the Ulanqab parameter server → link rate).
    pub fn testbed(rng: &mut Rng) -> Fleet {
        // (relative compute speed, relative link quality)
        // P100 ≈ 1.6× T4; 8-vCPU ≈ 1.3× 4-vCPU; farther city → slower link.
        let spec: [(f64, f64); 10] = [
            (1.6 * 1.3, 0.55), // c0 P100, Guangzhou (far)
            (1.3, 0.80),       // c1 T4 8v, Nanjing
            (1.3, 0.80),       // c2 T4 8v, Nanjing
            (1.0, 0.95),       // c3 T4 4v, Beijing (near)
            (1.0, 0.95),       // c4
            (1.0, 1.00),       // c5 Zhangjiakou (nearest)
            (1.0, 1.00),       // c6
            (1.0, 0.55),       // c7 Guangzhou
            (1.0, 0.55),       // c8
            (1.6 * 1.3, 0.70), // c9 P100, Shanghai
        ];
        let profiles = spec
            .iter()
            .map(|&(speed, link)| DeviceProfile {
                cycles_per_sample: 3e6 * rng.range_f64(0.95, 1.05),
                cpu_hz: 3e9 * speed,
                up_bps: 3e4 * link * rng.range_f64(0.95, 1.05),
                down_bps: 12e4 * link * rng.range_f64(0.95, 1.05),
            })
            .collect();
        Fleet { profiles }
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }
}

/// One client's round timing (Eq. 12 inner term).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTiming {
    pub t_down: f64,
    pub t_cmp: f64,
    pub t_up: f64,
}

impl RoundTiming {
    pub fn total(&self) -> f64 {
        self.t_down + self.t_cmp + self.t_up
    }
}

/// The synchronous-round virtual clock: t_server = max_n(total_n).
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now: f64,
    rounds: usize,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance by one synchronous round; returns the round's duration.
    pub fn advance_round(&mut self, timings: &[RoundTiming]) -> f64 {
        let dur = timings.iter().map(|t| t.total()).fold(0.0, f64::max);
        self.now += dur;
        self.rounds += 1;
        dur
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_formulas() {
        let p = DeviceProfile {
            cycles_per_sample: 2e6,
            cpu_hz: 1e9,
            up_bps: 1e4,
            down_bps: 4e4,
        };
        assert!((p.t_cmp(100) - 0.2).abs() < 1e-12); // 2e8 cycles / 1e9 Hz
        assert!((p.t_up(1e4) - 8.0).abs() < 1e-12); // 8e4 bits / 1e4 bps
        assert!((p.t_down(1e4) - 2.0).abs() < 1e-12);
        assert!((p.sec_per_byte() - (8e-4 + 2e-4)).abs() < 1e-12);
    }

    #[test]
    fn shannon_rate_monotone_in_power() {
        let r1 = shannon_rate_bps(1e4, 0.1, 1.0, 1e-3);
        let r2 = shannon_rate_bps(1e4, 0.2, 1.0, 1e-3);
        assert!(r2 > r1);
    }

    #[test]
    fn fleet_within_table4_ranges() {
        let mut rng = Rng::new(0);
        let fleet = Fleet::simulated(100, &mut rng);
        assert_eq!(fleet.len(), 100);
        for p in &fleet.profiles {
            assert!((1e4..=5e4).contains(&p.up_bps));
            assert!((4e4..=20e4).contains(&p.down_bps));
            assert!((1e9..=10e9).contains(&p.cpu_hz));
            assert!((1e6..=10e6).contains(&p.cycles_per_sample));
        }
    }

    #[test]
    fn testbed_has_ten_heterogeneous_clients() {
        let mut rng = Rng::new(1);
        let fleet = Fleet::testbed(&mut rng);
        assert_eq!(fleet.len(), 10);
        let ups: Vec<f64> = fleet.profiles.iter().map(|p| p.up_bps).collect();
        let spread = ups.iter().cloned().fold(f64::MIN, f64::max)
            / ups.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.5, "geo spread too small: {spread}");
    }

    #[test]
    fn clock_takes_round_max() {
        let mut clk = VirtualClock::new();
        let dur = clk.advance_round(&[
            RoundTiming { t_down: 1.0, t_cmp: 1.0, t_up: 1.0 },
            RoundTiming { t_down: 0.0, t_cmp: 5.0, t_up: 0.0 },
        ]);
        assert_eq!(dur, 5.0);
        assert_eq!(clk.now(), 5.0);
        clk.advance_round(&[RoundTiming { t_down: 0.5, t_cmp: 0.0, t_up: 0.0 }]);
        assert_eq!(clk.now(), 5.5);
        assert_eq!(clk.rounds(), 2);
    }
}
