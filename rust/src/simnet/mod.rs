//! System-heterogeneity simulator: client device + channel models, the
//! virtual-time accounting of Eq. 7–12, and the arrival-event model that
//! drives the semi-asynchronous round engine (DESIGN.md §7).
//!
//! The paper's time axis is fully analytic (CPU cycles/sample over CPU
//! frequency; Shannon-capacity up/down links), so a virtual clock driven
//! by these formulas reproduces the T2A comparisons without the physical
//! testbed (DESIGN.md §3 substitution table).
//!
//! Two clock regimes coexist:
//!
//! * [`VirtualClock::advance_round`] — the synchronous barrier,
//!   `t_server += max_n(total_n)`;
//! * [`EventQueue`] + [`ClientClocks`] — the semi-asynchronous timeline:
//!   every dispatched upload becomes an [`ArrivalEvent`] in a min-heap,
//!   each client's own clock advances to its arrival time independently
//!   of the global round boundary, and the server closes a round at a
//!   quorum or deadline ([`VirtualClock::advance_to`]).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::rng::Rng;

/// Per-client device + channel profile.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    /// CPU cycles to process one sample (paper: [1,10] Megacycles).
    pub cycles_per_sample: f64,
    /// CPU frequency in Hz (paper: [1,10] GHz).
    pub cpu_hz: f64,
    /// Uplink rate r_n^u in bits/s (paper Table 4: [1,5]×10^4).
    pub up_bps: f64,
    /// Downlink rate r_n^d in bits/s (paper Table 4: [4,20]×10^4).
    pub down_bps: f64,
}

impl DeviceProfile {
    /// Computation latency for `samples` local samples (Eq. 7 generalized
    /// over the samples actually processed in the round).
    pub fn t_cmp(&self, samples: usize) -> f64 {
        self.cycles_per_sample * samples as f64 / self.cpu_hz
    }

    /// Upload time for `bytes` (Eq. 9). The engine passes the *realized*
    /// encoded upload size (`codec::WireUpload::wire_len`), so the Eq. 9
    /// delay reflects measured wire bytes — index overhead included —
    /// rather than the `upload_bytes` estimate.
    pub fn t_up(&self, bytes: f64) -> f64 {
        bytes * 8.0 / self.up_bps
    }

    /// Download time for `bytes` (Eq. 11).
    pub fn t_down(&self, bytes: f64) -> f64 {
        bytes * 8.0 / self.down_bps
    }

    /// Seconds per uploaded+downloaded byte (the allocator's `1/r_u+1/r_d`
    /// folded to bytes).
    pub fn sec_per_byte(&self) -> f64 {
        8.0 / self.up_bps + 8.0 / self.down_bps
    }
}

/// Shannon-capacity channel (Eq. 8/10): r = B log2(1 + p·h/N0).
pub fn shannon_rate_bps(bandwidth_hz: f64, tx_power: f64, gain: f64, noise: f64) -> f64 {
    bandwidth_hz * (1.0 + tx_power * gain / noise).log2()
}

/// Bytes the Eq. 11 downlink is charged for one dispatch (DESIGN.md §6):
///
/// * **full broadcast** (Eq. 6, and always a client's first dispatch) —
///   the dense model, `U_n` bytes;
/// * **sparse download** (Eq. 5) — the masked *values only*,
///   `mask.payload_bytes`. The server echoes the client's own mask
///   `M_n`, which the client already holds, so no wire headers and no
///   bitmap/COO index bytes travel down. Charging the uplink's
///   `wire_len()` here (as the engine once did) double-bills the framing
///   the client itself produced.
pub fn downlink_bytes(full_broadcast: bool, model_bytes: usize, payload_bytes: usize) -> usize {
    if full_broadcast {
        model_bytes
    } else {
        payload_bytes
    }
}

/// Client-availability trace (DESIGN.md §Scenario-Matrix): which clients
/// the coordinator can reach at a given virtual instant. Availability is a
/// **pure function** of `(client, virtual time)` — no RNG stream is
/// consumed and no mutable state exists — so a traced run stays
/// bitwise-identical for every worker count and across replays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AvailabilityTrace {
    /// Every client reachable at all times (the default).
    None,
    /// A rolling half of the fleet is offline: client `n` of `N` is online
    /// iff `fract(now/period + n/N) < 0.5`, i.e. each client keeps a
    /// day/night cycle of length `period`, phase-shifted so exactly half
    /// the phases fall in the online window at any instant.
    Diurnal,
    /// Flash crowd: only a ~10% vanguard (`10·n < N`, always including
    /// client 0) is online before `period`; at `now >= period` the whole
    /// fleet arrives at once.
    FlashCrowd,
    /// Every client reachable, but in-flight uploads may drop mid-round —
    /// see [`churn_drops`]. Dispatch-side availability is unrestricted.
    Churn,
}

impl AvailabilityTrace {
    pub fn by_name(name: &str) -> anyhow::Result<AvailabilityTrace> {
        match name {
            "none" => Ok(AvailabilityTrace::None),
            "diurnal" => Ok(AvailabilityTrace::Diurnal),
            "flash_crowd" => Ok(AvailabilityTrace::FlashCrowd),
            "churn" => Ok(AvailabilityTrace::Churn),
            _ => anyhow::bail!("unknown trace {name:?} (none|diurnal|flash_crowd|churn)"),
        }
    }

    /// Can the coordinator reach client `n` (of `n_clients`) at virtual
    /// time `now`, under a trace of period `period` seconds?
    pub fn is_available(self, n: usize, n_clients: usize, now: f64, period: f64) -> bool {
        match self {
            AvailabilityTrace::None | AvailabilityTrace::Churn => true,
            AvailabilityTrace::Diurnal => {
                let phase = (now / period + n as f64 / n_clients.max(1) as f64).fract();
                phase < 0.5
            }
            AvailabilityTrace::FlashCrowd => now >= period || n * 10 < n_clients.max(1),
        }
    }
}

/// Does the upload client `n` dispatched in `dispatch_round` churn
/// (connection drops before the server receives it)? A pure splitmix-style
/// hash of `(seed, client, dispatch round)` mapped to `[0, 1)` and compared
/// against `rate` — deterministic, engine-RNG-free, worker-count invariant.
pub fn churn_drops(seed: u64, n: usize, dispatch_round: usize, rate: f64) -> bool {
    if rate <= 0.0 {
        return false;
    }
    let mut z = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((n as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add((dispatch_round as u64).wrapping_mul(0x94D0_49BB_1331_11EB));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    ((z >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < rate
}

/// A fleet of client profiles.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub profiles: Vec<DeviceProfile>,
}

impl Fleet {
    /// Table 4 simulation distribution: uniform draws per client.
    pub fn simulated(n: usize, rng: &mut Rng) -> Fleet {
        let profiles = (0..n)
            .map(|_| DeviceProfile {
                cycles_per_sample: rng.range_f64(1e6, 10e6),
                cpu_hz: rng.range_f64(1e9, 10e9),
                up_bps: rng.range_f64(1e4, 5e4),
                down_bps: rng.range_f64(4e4, 20e4),
            })
            .collect();
        Fleet { profiles }
    }

    /// Table 5 geo-distributed testbed: 10 clients whose compute/network
    /// spread mirrors the paper's VM fleet (GPU class → compute speed;
    /// distance from the Ulanqab parameter server → link rate).
    pub fn testbed(rng: &mut Rng) -> Fleet {
        // (relative compute speed, relative link quality)
        // P100 ≈ 1.6× T4; 8-vCPU ≈ 1.3× 4-vCPU; farther city → slower link.
        let spec: [(f64, f64); 10] = [
            (1.6 * 1.3, 0.55), // c0 P100, Guangzhou (far)
            (1.3, 0.80),       // c1 T4 8v, Nanjing
            (1.3, 0.80),       // c2 T4 8v, Nanjing
            (1.0, 0.95),       // c3 T4 4v, Beijing (near)
            (1.0, 0.95),       // c4
            (1.0, 1.00),       // c5 Zhangjiakou (nearest)
            (1.0, 1.00),       // c6
            (1.0, 0.55),       // c7 Guangzhou
            (1.0, 0.55),       // c8
            (1.6 * 1.3, 0.70), // c9 P100, Shanghai
        ];
        let profiles = spec
            .iter()
            .map(|&(speed, link)| DeviceProfile {
                cycles_per_sample: 3e6 * rng.range_f64(0.95, 1.05),
                cpu_hz: 3e9 * speed,
                up_bps: 3e4 * link * rng.range_f64(0.95, 1.05),
                down_bps: 12e4 * link * rng.range_f64(0.95, 1.05),
            })
            .collect();
        Fleet { profiles }
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }
}

/// One client's round timing (Eq. 12 inner term).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTiming {
    pub t_down: f64,
    pub t_cmp: f64,
    pub t_up: f64,
}

impl RoundTiming {
    pub fn total(&self) -> f64 {
        self.t_down + self.t_cmp + self.t_up
    }
}

/// The synchronous-round virtual clock: t_server = max_n(total_n).
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now: f64,
    rounds: usize,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance by one synchronous round; returns the round's duration.
    pub fn advance_round(&mut self, timings: &[RoundTiming]) -> f64 {
        self.advance_round_by(timings.iter().map(|t| t.total()).fold(0.0, f64::max))
    }

    /// Advance by a precomputed synchronous round duration. `f64::max` is
    /// order-independent, so the engine folds the fleet maximum
    /// incrementally as micro-batches complete instead of buffering an
    /// O(fleet) timing vector; counts one round and returns the duration.
    pub fn advance_round_by(&mut self, dur: f64) -> f64 {
        self.now += dur;
        self.rounds += 1;
        dur
    }

    /// Advance to an absolute close time (semi-asynchronous round); counts
    /// one round and returns its duration. Time never moves backwards.
    pub fn advance_to(&mut self, t: f64) -> f64 {
        let dur = (t - self.now).max(0.0);
        self.now += dur;
        self.rounds += 1;
        dur
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }
}

/// One client upload arriving at the server in the semi-asynchronous
/// virtual timeline.
#[derive(Clone, Copy, Debug)]
pub struct ArrivalEvent {
    /// Absolute virtual time the upload reaches the server
    /// (dispatch time + t_down + t_cmp + t_up).
    pub finish: f64,
    /// Client index.
    pub client: usize,
    /// Round in which the upload was dispatched; the server folds it with
    /// staleness `current_round − dispatch_round`.
    pub dispatch_round: usize,
}

impl Ord for ArrivalEvent {
    /// Total order: earliest `finish` first; exact arrival-time ties break
    /// by ascending client index (then dispatch round), so the heap pops
    /// deterministically on every platform.
    fn cmp(&self, other: &Self) -> Ordering {
        self.finish
            .total_cmp(&other.finish)
            .then_with(|| self.client.cmp(&other.client))
            .then_with(|| self.dispatch_round.cmp(&other.dispatch_round))
    }
}

impl PartialOrd for ArrivalEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for ArrivalEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for ArrivalEvent {}

/// Min-heap of pending [`ArrivalEvent`]s — the semi-asynchronous server's
/// view of every in-flight upload, across round boundaries.
#[derive(Clone, Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<ArrivalEvent>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, ev: ArrivalEvent) {
        self.heap.push(std::cmp::Reverse(ev));
    }

    /// The earliest pending arrival, if any.
    pub fn peek(&self) -> Option<&ArrivalEvent> {
        self.heap.peek().map(|r| &r.0)
    }

    pub fn pop(&mut self) -> Option<ArrivalEvent> {
        self.heap.pop().map(|r| r.0)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Arrival time of the `k`-th earliest pending event (1-based): the
    /// round-close time under an arrival quorum of `k`. Selects over the
    /// finish times only — no event copies, no heap clone.
    pub fn kth_finish(&self, k: usize) -> Option<f64> {
        if k == 0 || k > self.heap.len() {
            return None;
        }
        let mut finishes: Vec<f64> = self.heap.iter().map(|r| r.0.finish).collect();
        let (_, kth, _) = finishes.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
        Some(*kth)
    }

    /// Heap bytes of the pending-event buffer — the in-flight tail's
    /// contribution to the engine's `sim_state_bytes` audit.
    pub fn mem_bytes(&self) -> usize {
        self.heap.len() * std::mem::size_of::<std::cmp::Reverse<ArrivalEvent>>()
    }

    /// Pop every event with `finish <= t`, in (time, client) order.
    pub fn pop_until(&mut self, t: f64) -> Vec<ArrivalEvent> {
        let mut out = Vec::new();
        while let Some(ev) = self.peek() {
            if ev.finish <= t {
                out.push(self.pop().unwrap());
            } else {
                break;
            }
        }
        out
    }
}

/// Per-client virtual clocks. Each client's timeline runs independently of
/// the global round barrier: a dispatch pins the client until its upload's
/// arrival time, even if the server closes one or more rounds in between.
#[derive(Clone, Debug, Default)]
pub struct ClientClocks {
    free_at: Vec<f64>,
}

impl ClientClocks {
    pub fn new(n: usize) -> ClientClocks {
        ClientClocks { free_at: vec![0.0; n] }
    }

    /// Is client `n` still computing/uploading at virtual time `now`?
    pub fn is_busy(&self, n: usize, now: f64) -> bool {
        self.free_at[n] > now
    }

    /// Record a dispatch whose upload arrives at absolute time `finish`.
    pub fn dispatch(&mut self, n: usize, finish: f64) {
        self.free_at[n] = finish;
    }

    /// The client's own clock: when its current work (if any) arrives.
    pub fn free_at(&self, n: usize) -> f64 {
        self.free_at[n]
    }

    /// Heap bytes of the per-client clock array (`sim_state_bytes` term).
    pub fn mem_bytes(&self) -> usize {
        self.free_at.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_formulas() {
        let p = DeviceProfile {
            cycles_per_sample: 2e6,
            cpu_hz: 1e9,
            up_bps: 1e4,
            down_bps: 4e4,
        };
        assert!((p.t_cmp(100) - 0.2).abs() < 1e-12); // 2e8 cycles / 1e9 Hz
        assert!((p.t_up(1e4) - 8.0).abs() < 1e-12); // 8e4 bits / 1e4 bps
        assert!((p.t_down(1e4) - 2.0).abs() < 1e-12);
        assert!((p.sec_per_byte() - (8e-4 + 2e-4)).abs() < 1e-12);
    }

    #[test]
    fn downlink_charges_values_only_for_sparse_rounds() {
        // Eq. 5 sends the masked values; the mask itself is the client's
        // own upload echoed back, so index/framing bytes never download.
        let model = 400_000;
        let payload = 120_000;
        assert_eq!(downlink_bytes(true, model, payload), model);
        assert_eq!(downlink_bytes(false, model, payload), payload);
        // the sparse charge is independent of any wire framing overhead
        assert!(downlink_bytes(false, model, payload) < model);
    }

    #[test]
    fn shannon_rate_monotone_in_power() {
        let r1 = shannon_rate_bps(1e4, 0.1, 1.0, 1e-3);
        let r2 = shannon_rate_bps(1e4, 0.2, 1.0, 1e-3);
        assert!(r2 > r1);
    }

    #[test]
    fn fleet_within_table4_ranges() {
        let mut rng = Rng::new(0);
        let fleet = Fleet::simulated(100, &mut rng);
        assert_eq!(fleet.len(), 100);
        for p in &fleet.profiles {
            assert!((1e4..=5e4).contains(&p.up_bps));
            assert!((4e4..=20e4).contains(&p.down_bps));
            assert!((1e9..=10e9).contains(&p.cpu_hz));
            assert!((1e6..=10e6).contains(&p.cycles_per_sample));
        }
    }

    #[test]
    fn testbed_has_ten_heterogeneous_clients() {
        let mut rng = Rng::new(1);
        let fleet = Fleet::testbed(&mut rng);
        assert_eq!(fleet.len(), 10);
        let ups: Vec<f64> = fleet.profiles.iter().map(|p| p.up_bps).collect();
        let spread = ups.iter().cloned().fold(f64::MIN, f64::max)
            / ups.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.5, "geo spread too small: {spread}");
    }

    #[test]
    fn heap_orders_by_time_then_client_index() {
        // Equal arrival times must pop by ascending client index — the
        // deterministic tie-break the semi-async fold order relies on.
        let mut q = EventQueue::new();
        for &(finish, client) in &[(2.0, 7), (1.0, 9), (1.0, 3), (2.0, 1), (1.0, 5)] {
            q.push(ArrivalEvent { finish, client, dispatch_round: 1 });
        }
        let order: Vec<(f64, usize)> = std::iter::from_fn(|| q.pop())
            .map(|e| (e.finish, e.client))
            .collect();
        assert_eq!(order, vec![(1.0, 3), (1.0, 5), (1.0, 9), (2.0, 1), (2.0, 7)]);
    }

    #[test]
    fn kth_finish_and_pop_until() {
        let mut q = EventQueue::new();
        for (i, f) in [5.0, 1.0, 3.0, 4.0, 2.0].iter().enumerate() {
            q.push(ArrivalEvent { finish: *f, client: i, dispatch_round: 2 });
        }
        assert_eq!(q.kth_finish(1), Some(1.0));
        assert_eq!(q.kth_finish(3), Some(3.0));
        assert_eq!(q.kth_finish(5), Some(5.0));
        assert_eq!(q.kth_finish(0), None);
        assert_eq!(q.kth_finish(6), None);
        let popped = q.pop_until(3.0);
        assert_eq!(popped.len(), 3);
        assert!(popped.windows(2).all(|w| w[0].finish <= w[1].finish));
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek().unwrap().finish, 4.0);
        // events strictly after t stay queued
        assert!(q.pop_until(3.9).is_empty());
    }

    #[test]
    fn sim_state_accounting_tracks_in_flight_tail() {
        let mut q = EventQueue::new();
        assert_eq!(q.mem_bytes(), 0);
        q.push(ArrivalEvent { finish: 1.0, client: 0, dispatch_round: 1 });
        q.push(ArrivalEvent { finish: 2.0, client: 1, dispatch_round: 1 });
        assert_eq!(q.mem_bytes(), 2 * std::mem::size_of::<ArrivalEvent>());
        q.pop_until(1.5);
        assert_eq!(q.mem_bytes(), std::mem::size_of::<ArrivalEvent>());
        let clocks = ClientClocks::new(100);
        assert_eq!(clocks.mem_bytes(), 100 * std::mem::size_of::<f64>());
    }

    #[test]
    fn diurnal_trace_keeps_a_rolling_half_online() {
        let t = AvailabilityTrace::Diurnal;
        let (n_clients, period) = (8usize, 600.0);
        for &now in &[0.0, 150.0, 300.0, 450.0, 599.0, 601.0, 1234.5] {
            let online = (0..n_clients)
                .filter(|&n| t.is_available(n, n_clients, now, period))
                .count();
            assert_eq!(online, 4, "exactly half the phases sit in the window at t={now}");
        }
        // a full period later every client is back in the same state
        for n in 0..n_clients {
            assert_eq!(
                t.is_available(n, n_clients, 123.0, period),
                t.is_available(n, n_clients, 123.0 + period, period)
            );
        }
        // each client is offline at some instant (the trace is not a no-op)
        for n in 0..n_clients {
            assert!((0..12).any(|k| !t.is_available(n, n_clients, k as f64 * 50.0, period)));
        }
    }

    #[test]
    fn flash_crowd_vanguard_then_everyone() {
        let t = AvailabilityTrace::FlashCrowd;
        let (n_clients, period) = (20usize, 600.0);
        let before: Vec<usize> =
            (0..n_clients).filter(|&n| t.is_available(n, n_clients, 10.0, period)).collect();
        assert_eq!(before, vec![0, 1], "~10% vanguard online before the crowd");
        let after = (0..n_clients).filter(|&n| t.is_available(n, n_clients, 600.0, period)).count();
        assert_eq!(after, n_clients, "whole fleet online at the arrival instant");
        // client 0 is always in the vanguard, even in tiny fleets
        assert!(t.is_available(0, 3, 0.0, period));
    }

    #[test]
    fn none_and_churn_traces_never_gate_dispatch() {
        for t in [AvailabilityTrace::None, AvailabilityTrace::Churn] {
            for n in 0..5 {
                assert!(t.is_available(n, 5, 1e6, 600.0));
            }
        }
    }

    #[test]
    fn trace_names_round_trip() {
        for name in ["none", "diurnal", "flash_crowd", "churn"] {
            AvailabilityTrace::by_name(name).unwrap();
        }
        assert!(AvailabilityTrace::by_name("weekend").is_err());
    }

    #[test]
    fn churn_drops_is_deterministic_and_rate_bounded() {
        // pure function: same inputs, same verdict
        for n in 0..50 {
            for r in 1..4 {
                assert_eq!(churn_drops(17, n, r, 0.3), churn_drops(17, n, r, 0.3));
            }
        }
        // rate 0 never drops
        assert!((0..200).all(|n| !churn_drops(17, n, 1, 0.0)));
        // the empirical drop fraction tracks the rate over many draws
        let hits = (0..2000).filter(|&n| churn_drops(17, n, 1, 0.25)).count();
        assert!((300..700).contains(&hits), "drop fraction off: {hits}/2000 at rate 0.25");
        // distinct seeds decorrelate the pattern
        let a: Vec<bool> = (0..64).map(|n| churn_drops(17, n, 1, 0.5)).collect();
        let b: Vec<bool> = (0..64).map(|n| churn_drops(18, n, 1, 0.5)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn client_clocks_advance_independently() {
        let mut clocks = ClientClocks::new(3);
        assert!(!clocks.is_busy(0, 0.0));
        clocks.dispatch(0, 10.0);
        clocks.dispatch(1, 4.0);
        // at t=5 client 0 is still in flight, client 1 has arrived
        assert!(clocks.is_busy(0, 5.0));
        assert!(!clocks.is_busy(1, 5.0));
        assert!(!clocks.is_busy(2, 5.0));
        assert_eq!(clocks.free_at(0), 10.0);
        // a client is free exactly at its arrival instant
        assert!(!clocks.is_busy(0, 10.0));
    }

    #[test]
    fn advance_to_is_monotone_and_counts_rounds() {
        let mut clk = VirtualClock::new();
        assert_eq!(clk.advance_to(3.0), 3.0);
        assert_eq!(clk.now(), 3.0);
        // moving "backwards" clamps to zero duration
        assert_eq!(clk.advance_to(2.0), 0.0);
        assert_eq!(clk.now(), 3.0);
        assert_eq!(clk.rounds(), 2);
    }

    #[test]
    fn clock_takes_round_max() {
        let mut clk = VirtualClock::new();
        let dur = clk.advance_round(&[
            RoundTiming { t_down: 1.0, t_cmp: 1.0, t_up: 1.0 },
            RoundTiming { t_down: 0.0, t_cmp: 5.0, t_up: 0.0 },
        ]);
        assert_eq!(dur, 5.0);
        assert_eq!(clk.now(), 5.0);
        clk.advance_round(&[RoundTiming { t_down: 0.5, t_cmp: 0.0, t_up: 0.0 }]);
        assert_eq!(clk.now(), 5.5);
        assert_eq!(clk.rounds(), 2);
    }
}
