//! Model registry — the rust mirror of `python/compile/model.py`
//! (Tables 2, 3, 6 of the paper). The two registries must agree exactly;
//! `rust/tests/integration.rs` pins both against the artifact manifest.
//!
//! Besides shapes, this module owns the *channel/neuron geometry* that
//! FedDD's structured masks operate on: each layer has `out_dim` units
//! (conv channels or FC neurons), and unit `k` owns its incoming weights
//! plus its bias (structured-pruning style grouping, §4.2 of the paper).

mod geometry;

pub use geometry::*;

use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv { kernel: usize, padding: Padding },
    Fc,
}

#[derive(Clone, Debug)]
pub struct Layer {
    pub kind: LayerKind,
    /// Conv: input channels (or, for FC, the input dimension).
    pub in_dim: usize,
    /// Units of this layer: conv output channels / FC output neurons.
    pub out_dim: usize,
}

/// Identifies a model variant: family name + width percent (e.g.
/// `("cnn2", 100)` ⇔ artifact tag `cnn2_w100`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ModelId {
    pub name: String,
    pub width_pct: u32,
}

impl ModelId {
    pub fn new(name: &str, width_pct: u32) -> ModelId {
        ModelId { name: name.to_string(), width_pct }
    }

    pub fn tag(&self) -> String {
        format!("{}_w{}", self.name, self.width_pct)
    }

    pub fn width(&self) -> f64 {
        self.width_pct as f64 / 100.0
    }
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub id: ModelId,
    /// `[784]` for the MLP, `[C, H, W]` for CNNs.
    pub input_shape: Vec<usize>,
    pub layers: Vec<Layer>,
}

fn round4(ch: usize, mult: f64) -> usize {
    if mult == 1.0 {
        return ch; // paper-exact at full width
    }
    let s = ((ch as f64 * mult).round() as usize).max(1);
    (s.div_ceil(4) * 4).max(4)
}

const NUM_CLASSES: usize = 10;

// Channel plans from Tables 3 and 6.
const HET_A: [(&[usize], &[usize]); 5] = [
    (&[64, 128, 256, 512, 512], &[100, 100]),
    (&[64, 128, 256, 256, 512], &[100, 100]),
    (&[64, 128, 256, 256, 512], &[80, 100]),
    (&[32, 128, 256, 256, 512], &[80, 100]),
    (&[32, 128, 128, 256, 512], &[80, 100]),
];
const HET_B: [(&[usize], &[usize]); 5] = [
    (&[64, 128, 256, 512, 512], &[100, 100]),
    (&[64, 128, 256, 256, 256], &[100, 100]),
    (&[64, 128, 256, 256, 256], &[80, 80]),
    (&[32, 96, 256, 256, 256], &[80, 80]),
    (&[32, 96, 128, 128, 256], &[80, 80]),
];

impl ModelSpec {
    /// Build a spec by family name ("mlp", "cnn1", "cnn2", "het_a_3", …)
    /// and width multiplier.
    pub fn get(name: &str, width: f64) -> anyhow::Result<ModelSpec> {
        let id = ModelId::new(name, (width * 100.0).round() as u32);
        let spec = match name {
            "mlp" => {
                let h1 = round4(100, width);
                let h2 = round4(64, width);
                ModelSpec {
                    id,
                    input_shape: vec![784],
                    layers: vec![
                        fc(784, h1),
                        fc(h1, h2),
                        fc(h2, NUM_CLASSES),
                    ],
                }
            }
            "cnn1" => {
                let c1 = round4(10, width);
                let c2 = round4(20, width);
                // 28 -conv5(VALID)-> 24 -pool-> 12 -conv5-> 8 -pool-> 4
                let fc_in = c2 * 4 * 4;
                let h = round4(50, width);
                ModelSpec {
                    id,
                    input_shape: vec![1, 28, 28],
                    layers: vec![
                        conv(1, c1, 5, Padding::Valid),
                        conv(c1, c2, 5, Padding::Valid),
                        fc(fc_in, h),
                        fc(h, NUM_CLASSES),
                    ],
                }
            }
            "cnn2" => {
                let c: Vec<usize> =
                    [16, 32, 64].iter().map(|&x| round4(x, width)).collect();
                let fc_in = c[2] * 4 * 4; // 32 -> 16 -> 8 -> 4
                let h1 = round4(500, width);
                let h2 = round4(100, width);
                ModelSpec {
                    id,
                    input_shape: vec![3, 32, 32],
                    layers: vec![
                        conv(3, c[0], 3, Padding::Same),
                        conv(c[0], c[1], 3, Padding::Same),
                        conv(c[1], c[2], 3, Padding::Same),
                        fc(fc_in, h1),
                        fc(h1, h2),
                        fc(h2, NUM_CLASSES),
                    ],
                }
            }
            _ => {
                let (fam, idx) = name
                    .rsplit_once('_')
                    .ok_or_else(|| anyhow::anyhow!("unknown model {name:?}"))?;
                let i: usize = idx.parse()?;
                anyhow::ensure!((1..=5).contains(&i), "sub-model index {i}");
                let (convs, fcs) = match fam {
                    "het_a" => HET_A[i - 1],
                    "het_b" => HET_B[i - 1],
                    _ => anyhow::bail!("unknown model {name:?}"),
                };
                let chans: Vec<usize> =
                    convs.iter().map(|&c| round4(c, width)).collect();
                let hidden: Vec<usize> =
                    fcs.iter().map(|&h| round4(h, width)).collect();
                let mut layers = Vec::new();
                let mut in_ch = 3;
                for &c in &chans {
                    layers.push(conv(in_ch, c, 3, Padding::Same));
                    in_ch = c;
                }
                // 32 -> 16 -> 8 -> 4 -> 2 -> 1 spatial after five pools
                let mut dims = vec![chans[chans.len() - 1]];
                dims.extend(&hidden);
                dims.push(NUM_CLASSES);
                for w in dims.windows(2) {
                    layers.push(fc(w[0], w[1]));
                }
                ModelSpec { id, input_shape: vec![3, 32, 32], layers }
            }
        };
        Ok(spec)
    }

    /// Ordered (name, shape) for every parameter tensor — conv weights
    /// OIHW, FC weights (in, out) — identical to the python registry.
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            match layer.kind {
                LayerKind::Conv { kernel, .. } => {
                    out.push((
                        format!("conv{i}_w"),
                        vec![layer.out_dim, layer.in_dim, kernel, kernel],
                    ));
                    out.push((format!("conv{i}_b"), vec![layer.out_dim]));
                }
                LayerKind::Fc => {
                    out.push((format!("fc{i}_w"), vec![layer.in_dim, layer.out_dim]));
                    out.push((format!("fc{i}_b"), vec![layer.out_dim]));
                }
            }
        }
        out
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Bytes of the full model at f32 (the paper's `U_n`).
    pub fn size_bytes(&self) -> usize {
        self.param_count() * 4
    }

    /// Units (channels / neurons) per layer — `N_l` in Algorithm 2.
    pub fn unit_counts(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.out_dim).collect()
    }

    /// Initialization, deterministic from `rng`: He-normal convs, damped
    /// FC weights (×0.5) and an extra ×0.2 on the classifier layer. The
    /// damping keeps deep stacks (the 8-layer VGG sub-models) inside the
    /// plain-SGD stable region — validated by an init×lr sweep recorded
    /// in EXPERIMENTS.md; with pure He init the paper's hetero models
    /// start at exploded logits and oscillate at chance.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<Tensor> {
        let shapes = self.param_shapes();
        let last_w = shapes.len() - 2; // [..., fcN_w, fcN_b]
        shapes
            .into_iter()
            .enumerate()
            .map(|(i, (name, shape))| {
                let n: usize = shape.iter().product();
                if name.ends_with("_b") {
                    Tensor::zeros(shape)
                } else {
                    let fan_in: usize = if shape.len() == 4 {
                        shape[1] * shape[2] * shape[3]
                    } else {
                        shape[0]
                    };
                    let mut std = (2.0 / fan_in as f64).sqrt() as f32;
                    if shape.len() == 2 {
                        std *= 0.5; // FC damping
                    }
                    if i == last_w {
                        std *= 0.2; // classifier damping
                    }
                    let data =
                        (0..n).map(|_| rng.normal_f32(0.0, std)).collect();
                    Tensor::new(shape, data)
                }
            })
            .collect()
    }
}

fn conv(in_dim: usize, out_dim: usize, kernel: usize, padding: Padding) -> Layer {
    Layer { kind: LayerKind::Conv { kernel, padding }, in_dim, out_dim }
}

fn fc(in_dim: usize, out_dim: usize) -> Layer {
    Layer { kind: LayerKind::Fc, in_dim, out_dim }
}

/// All model family names.
pub fn all_model_names() -> Vec<String> {
    let mut v = vec!["mlp".to_string(), "cnn1".to_string(), "cnn2".to_string()];
    for fam in ["het_a", "het_b"] {
        for i in 1..=5 {
            v.push(format!("{fam}_{i}"));
        }
    }
    v
}

/// Registry caching specs by id.
#[derive(Default)]
pub struct ModelRegistry {
    cache: std::collections::HashMap<ModelId, ModelSpec>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn spec(&mut self, id: &ModelId) -> anyhow::Result<&ModelSpec> {
        if !self.cache.contains_key(id) {
            let spec = ModelSpec::get(&id.name, id.width())?;
            self.cache.insert(id.clone(), spec);
        }
        Ok(&self.cache[id])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_matches_table2() {
        let s = ModelSpec::get("mlp", 1.0).unwrap();
        let shapes = s.param_shapes();
        assert_eq!(shapes[0].1, vec![784, 100]);
        assert_eq!(shapes[2].1, vec![100, 64]);
        assert_eq!(shapes[4].1, vec![64, 10]);
        assert_eq!(s.param_count(), 784 * 100 + 100 + 100 * 64 + 64 + 64 * 10 + 10);
    }

    #[test]
    fn cnn1_matches_table2() {
        let s = ModelSpec::get("cnn1", 1.0).unwrap();
        let shapes: Vec<_> = s.param_shapes();
        assert_eq!(shapes[0].1, vec![10, 1, 5, 5]);
        assert_eq!(shapes[2].1, vec![20, 10, 5, 5]);
        assert_eq!(shapes[4].1, vec![320, 50]);
    }

    #[test]
    fn cnn2_matches_table2() {
        let s = ModelSpec::get("cnn2", 1.0).unwrap();
        let shapes = s.param_shapes();
        assert_eq!(shapes[0].1, vec![16, 3, 3, 3]);
        assert_eq!(shapes[6].1, vec![1024, 500]);
        assert_eq!(shapes[10].1, vec![100, 10]);
    }

    #[test]
    fn het_a_full_model_channels() {
        let s = ModelSpec::get("het_a_1", 1.0).unwrap();
        let convs: Vec<usize> = s
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
            .map(|l| l.out_dim)
            .collect();
        assert_eq!(convs, vec![64, 128, 256, 512, 512]);
    }

    #[test]
    fn het_b_submodels_shrink() {
        let counts: Vec<usize> = (1..=5)
            .map(|i| ModelSpec::get(&format!("het_b_{i}"), 1.0).unwrap().param_count())
            .collect();
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(counts, sorted, "{counts:?}");
    }

    #[test]
    fn submodel_nesting_layerwise() {
        let full = ModelSpec::get("het_a_1", 1.0).unwrap();
        for i in 2..=5 {
            let sub = ModelSpec::get(&format!("het_a_{i}"), 1.0).unwrap();
            for (a, b) in sub.layers.iter().zip(&full.layers) {
                assert!(a.out_dim <= b.out_dim);
                assert!(a.in_dim <= b.in_dim);
            }
        }
    }

    #[test]
    fn width_scaling_matches_python_formula() {
        let s = ModelSpec::get("cnn2", 0.25).unwrap();
        assert_eq!(s.layers[0].out_dim, 4); // 16*0.25
        assert_eq!(s.layers[3].out_dim, 128); // round(500*.25)=125 -> 128
        assert_eq!(s.layers[4].out_dim, 28); // round(100*.25)=25 -> 28
        let shapes = s.param_shapes();
        // round(500*0.25)=125 -> 128; round(100*0.25)=25 -> 28
        assert_eq!(shapes[6].1[1], 128);
        assert_eq!(shapes[8].1[1], 28);
    }

    #[test]
    fn init_params_finite_and_shaped() {
        let s = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(0);
        let p = s.init_params(&mut rng);
        assert_eq!(p.len(), 6);
        assert!(p.iter().all(|t| t.is_finite()));
        assert_eq!(p[1].data().iter().filter(|&&x| x != 0.0).count(), 0); // bias zero
    }

    #[test]
    fn model_id_tags() {
        assert_eq!(ModelId::new("cnn2", 100).tag(), "cnn2_w100");
        assert_eq!(ModelId::new("het_a_3", 25).tag(), "het_a_3_w25");
    }
}
