//! Structured-mask geometry: the mapping between channel/neuron units and
//! elementwise parameter positions, plus HeteroFL-style alignment of
//! sub-model parameters inside the global (full) model.
//!
//! Conventions (matching `python/compile/model.py`):
//! * conv weight OIHW `[out, in, kh, kw]` — unit `k` owns the contiguous
//!   block `k*(in*kh*kw) .. (k+1)*(in*kh*kw)` plus `bias[k]`;
//! * fc weight `(in, out)` — unit `k` owns the strided column `[:, k]`
//!   plus `bias[k]`;
//! * a sub-model occupies the *leading corner* of every global tensor
//!   (channel `c` of the sub-model is channel `c` of the global model),
//!   the standard HeteroFL alignment the paper builds on [18].

use super::{LayerKind, ModelSpec};
use crate::tensor::Tensor;

/// For layer `l` of `spec`, expand a per-unit 0/1 selection into
/// elementwise masks `(w_mask, b_mask)` shaped like that layer's params.
pub fn expand_unit_mask(spec: &ModelSpec, l: usize, selected: &[bool]) -> (Tensor, Tensor) {
    let layer = &spec.layers[l];
    assert_eq!(selected.len(), layer.out_dim);
    match layer.kind {
        LayerKind::Conv { kernel, .. } => {
            let row = layer.in_dim * kernel * kernel;
            let mut w = vec![0.0f32; layer.out_dim * row];
            for (k, &sel) in selected.iter().enumerate() {
                if sel {
                    w[k * row..(k + 1) * row].fill(1.0);
                }
            }
            let b: Vec<f32> =
                selected.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect();
            (
                Tensor::new(vec![layer.out_dim, layer.in_dim, kernel, kernel], w),
                Tensor::new(vec![layer.out_dim], b),
            )
        }
        LayerKind::Fc => {
            let (n_in, n_out) = (layer.in_dim, layer.out_dim);
            let mut w = vec![0.0f32; n_in * n_out];
            for j in 0..n_in {
                let row = &mut w[j * n_out..(j + 1) * n_out];
                for (k, &sel) in selected.iter().enumerate() {
                    if sel {
                        row[k] = 1.0;
                    }
                }
            }
            let b: Vec<f32> =
                selected.iter().map(|&s| if s { 1.0 } else { 0.0 }).collect();
            (
                Tensor::new(vec![n_in, n_out], w),
                Tensor::new(vec![n_out], b),
            )
        }
    }
}

/// Embed a client-shaped tensor into a global-shaped zero tensor at the
/// leading corner. Supports 1-D, 2-D (in,out) and 4-D OIHW.
pub fn embed(client: &Tensor, global_shape: &[usize]) -> Tensor {
    let cs = client.shape();
    assert_eq!(cs.len(), global_shape.len());
    assert!(cs.iter().zip(global_shape).all(|(c, g)| c <= g), "{cs:?} !<= {global_shape:?}");
    let mut out = Tensor::zeros(global_shape.to_vec());
    copy_corner(client.data(), cs, out.data_mut(), global_shape);
    out
}

/// Extract the leading corner of a global-shaped tensor into client shape.
pub fn extract(global: &Tensor, client_shape: &[usize]) -> Tensor {
    let gs = global.shape();
    assert_eq!(gs.len(), client_shape.len());
    assert!(client_shape.iter().zip(gs).all(|(c, g)| c <= g));
    let mut data = vec![0.0f32; client_shape.iter().product()];
    gather_corner(global.data(), gs, &mut data, client_shape);
    Tensor::new(client_shape.to_vec(), data)
}

/// scatter (small -> leading corner of big).
fn copy_corner(small: &[f32], ss: &[usize], big: &mut [f32], bs: &[usize]) {
    match ss.len() {
        1 => big[..ss[0]].copy_from_slice(&small[..ss[0]]),
        2 => {
            let (si, so) = (ss[0], ss[1]);
            let bo = bs[1];
            for j in 0..si {
                big[j * bo..j * bo + so].copy_from_slice(&small[j * so..(j + 1) * so]);
            }
        }
        4 => {
            let (so, si) = (ss[0], ss[1]);
            let (bi, k2) = (bs[1], ss[2] * ss[3]);
            for o in 0..so {
                for i in 0..si {
                    let brow = (o * bi + i) * k2;
                    let srow = (o * si + i) * k2;
                    big[brow..brow + k2].copy_from_slice(&small[srow..srow + k2]);
                }
            }
        }
        d => panic!("embed: unsupported rank {d}"),
    }
}

/// gather (corner of big -> small).
fn gather_corner(big: &[f32], bs: &[usize], small: &mut [f32], ss: &[usize]) {
    match ss.len() {
        1 => small[..ss[0]].copy_from_slice(&big[..ss[0]]),
        2 => {
            let (si, so) = (ss[0], ss[1]);
            let bo = bs[1];
            for j in 0..si {
                small[j * so..(j + 1) * so].copy_from_slice(&big[j * bo..j * bo + so]);
            }
        }
        4 => {
            let (so, si) = (ss[0], ss[1]);
            let (bi, k2) = (bs[1], ss[2] * ss[3]);
            for o in 0..so {
                for i in 0..si {
                    let brow = (o * bi + i) * k2;
                    let srow = (o * si + i) * k2;
                    small[srow..srow + k2].copy_from_slice(&big[brow..brow + k2]);
                }
            }
        }
        d => panic!("extract: unsupported rank {d}"),
    }
}

/// Embed a whole parameter set into global shapes.
pub fn embed_params(client: &[Tensor], global: &ModelSpec) -> Vec<Tensor> {
    global
        .param_shapes()
        .iter()
        .zip(client)
        .map(|((_, gshape), ct)| embed(ct, gshape))
        .collect()
}

/// Extract a client's parameter set from global parameters.
pub fn extract_params(global_params: &[Tensor], client: &ModelSpec) -> Vec<Tensor> {
    client
        .param_shapes()
        .iter()
        .zip(global_params)
        .map(|((_, cshape), gt)| extract(gt, cshape))
        .collect()
}

/// [`extract_params`] into a reusable buffer (the per-worker scratch
/// arena): bitwise the same result, but tensors whose shape already
/// matches keep their allocation. Every retained element is **fully
/// overwritten** — `gather_corner` writes the whole client-shaped tensor
/// — so arbitrary (even sentinel-poisoned) previous contents can never
/// leak into the extracted values.
pub fn extract_params_into(global_params: &[Tensor], client: &ModelSpec, out: &mut Vec<Tensor>) {
    let shapes = client.param_shapes();
    out.truncate(shapes.len());
    for (i, ((_, cshape), gt)) in shapes.iter().zip(global_params).enumerate() {
        match out.get_mut(i) {
            Some(t) if t.shape() == cshape.as_slice() => {
                let gs = gt.shape();
                assert_eq!(gs.len(), cshape.len());
                assert!(cshape.iter().zip(gs).all(|(c, g)| c <= g));
                gather_corner(gt.data(), gs, t.data_mut(), cshape);
            }
            Some(t) => *t = extract(gt, cshape),
            None => out.push(extract(gt, cshape)),
        }
    }
}

/// Elementwise structural-presence masks (1 where the client's sub-model
/// has a parameter) on global shapes.
pub fn structural_presence(client: &ModelSpec, global: &ModelSpec) -> Vec<Tensor> {
    client
        .param_shapes()
        .iter()
        .map(|(_, cshape)| Tensor::full(cshape.clone(), 1.0))
        .zip(global.param_shapes())
        .map(|(ones, (_, gshape))| embed(&ones, &gshape))
        .collect()
}

/// Coverage rate CR(k) per (layer, global unit): the fraction of clients
/// whose sub-model possesses unit `k` (Eq. 21). Computed by the server
/// after round 1, then broadcast.
pub fn coverage_rates(client_specs: &[&ModelSpec], global: &ModelSpec) -> Vec<Vec<f32>> {
    let n = client_specs.len() as f32;
    global
        .layers
        .iter()
        .enumerate()
        .map(|(l, layer)| {
            (0..layer.out_dim)
                .map(|k| {
                    let covering = client_specs
                        .iter()
                        .filter(|s| s.layers[l].out_dim > k)
                        .count();
                    covering as f32 / n
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    #[test]
    fn expand_fc_mask_columns() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut sel = vec![false; 100];
        sel[3] = true;
        let (w, b) = expand_unit_mask(&spec, 0, &sel);
        assert_eq!(w.shape(), &[784, 100]);
        // column 3 set for every input row
        assert_eq!(w.data()[3], 1.0);
        assert_eq!(w.data()[100 + 3], 1.0);
        assert_eq!(w.data()[0], 0.0);
        assert_eq!(w.data().iter().sum::<f32>(), 784.0);
        assert_eq!(b.data().iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn expand_conv_mask_rows() {
        let spec = ModelSpec::get("cnn1", 1.0).unwrap();
        let mut sel = vec![false; 10];
        sel[0] = true;
        sel[9] = true;
        let (w, b) = expand_unit_mask(&spec, 0, &sel);
        assert_eq!(w.shape(), &[10, 1, 5, 5]);
        assert_eq!(w.data().iter().sum::<f32>(), 50.0); // 2 units × 25
        assert_eq!(b.data(), &[1., 0., 0., 0., 0., 0., 0., 0., 0., 1.]);
    }

    #[test]
    fn embed_extract_roundtrip_2d() {
        let small = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let big = embed(&small, &[4, 5]);
        assert_eq!(big.data()[0..3], [1., 2., 3.]);
        assert_eq!(big.data()[5..8], [4., 5., 6.]);
        assert_eq!(big.data().iter().sum::<f32>(), 21.0);
        let back = extract(&big, &[2, 3]);
        assert_eq!(back.data(), small.data());
    }

    #[test]
    fn embed_extract_roundtrip_4d() {
        let small = Tensor::new(vec![2, 2, 1, 1], vec![1., 2., 3., 4.]);
        let big = embed(&small, &[3, 3, 1, 1]);
        let back = extract(&big, &[2, 2, 1, 1]);
        assert_eq!(back.data(), small.data());
        assert_eq!(big.data().iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn hetero_embed_full_roundtrip() {
        let global = ModelSpec::get("het_a_1", 0.25).unwrap();
        let sub = ModelSpec::get("het_a_5", 0.25).unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let cp = sub.init_params(&mut rng);
        let gp = embed_params(&cp, &global);
        assert_eq!(gp.len(), cp.len());
        for (g, (_, gs)) in gp.iter().zip(global.param_shapes()) {
            assert_eq!(g.shape(), &gs[..]);
        }
        let back = extract_params(&gp, &sub);
        for (a, b) in back.iter().zip(&cp) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn extract_params_into_matches_extract_params_from_dirty_buffers() {
        // The scratch-arena path: whatever the reused buffer held before
        // (matching shapes full of sentinels, mismatched shapes, wrong
        // arity), the result must be bitwise extract_params.
        let global = ModelSpec::get("het_a_1", 0.25).unwrap();
        let sub = ModelSpec::get("het_a_4", 0.25).unwrap();
        let mut rng = crate::util::rng::Rng::new(2);
        let gp = global.init_params(&mut rng);
        let want = extract_params(&gp, &sub);

        // (a) empty buffer grows
        let mut out: Vec<Tensor> = Vec::new();
        extract_params_into(&gp, &sub, &mut out);
        assert_eq!(out.len(), want.len());
        for (a, b) in want.iter().zip(&out) {
            assert_eq!(a.data(), b.data());
        }
        // (b) shape-matching poisoned buffer is reused in place
        for t in out.iter_mut() {
            t.data_mut().fill(f32::NAN);
        }
        let ptrs: Vec<_> = out.iter().map(|t| t.data().as_ptr()).collect();
        extract_params_into(&gp, &sub, &mut out);
        for ((a, b), p) in want.iter().zip(&out).zip(&ptrs) {
            assert_eq!(a.data(), b.data());
            assert_eq!(b.data().as_ptr(), *p, "matching shape must reuse the allocation");
        }
        // (c) wrong shapes / surplus arity are rebuilt / truncated
        let mut dirty: Vec<Tensor> = (0..want.len() + 3)
            .map(|i| Tensor::full(vec![i + 1], f32::NAN))
            .collect();
        extract_params_into(&gp, &sub, &mut dirty);
        assert_eq!(dirty.len(), want.len());
        for (a, b) in want.iter().zip(&dirty) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn presence_mask_counts() {
        let global = ModelSpec::get("het_a_1", 0.25).unwrap();
        let sub = ModelSpec::get("het_a_4", 0.25).unwrap();
        let pres = structural_presence(&sub, &global);
        let total: f32 = pres.iter().map(|t| t.data().iter().sum::<f32>()).sum();
        assert_eq!(total as usize, sub.param_count());
    }

    #[test]
    fn coverage_rates_full_and_partial() {
        let g = ModelSpec::get("het_a_1", 0.25).unwrap();
        let s5 = ModelSpec::get("het_a_5", 0.25).unwrap();
        let specs = vec![&g, &s5];
        let cr = coverage_rates(&specs, &g);
        // layer 0: het_a_1 has 16 units (64*0.25), het_a_5 has 8 (32*0.25)
        assert_eq!(cr[0][0], 1.0);
        assert_eq!(cr[0][g.layers[0].out_dim - 1], 0.5);
        // last fc layer (classes) covered by everyone
        assert!(cr.last().unwrap().iter().all(|&x| x == 1.0));
    }
}
