//! Uploaded-parameter selection (paper §4.2, Algorithm 2).
//!
//! Given a client's assigned dropout rate `D_n`, every layer keeps its top
//! `round(N_l · (1 − D_n))` channels/neurons (at least one — an empty
//! layer would upload nothing and stall that layer's aggregation) ranked
//! by a per-unit score:
//!
//! * `importance` — the paper's index `Ĩ_n^k = ‖ΔW·(W+ΔW)/W‖_(k) / CR(k)`
//!   (Eq. 21; the elementwise part mirrors the Pallas `importance` kernel,
//!   the group norm is an L2 over the unit's parameter group);
//! * `max`     — ‖Ŵ‖_(k): largest post-update amplitude (baseline);
//! * `delta`   — ‖ΔW‖_(k): largest change (Aji & Heafield [24]);
//! * `random`  — uniform random units (baseline);
//! * `ordered` — the first units in index order (FjORD-style ordered
//!   dropout [25]).

use crate::model::{expand_unit_mask, LayerKind, ModelSpec};
use crate::tensor::{importance_scores, Tensor};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Importance,
    Random,
    Max,
    Delta,
    Ordered,
}

impl Policy {
    pub fn by_name(name: &str) -> anyhow::Result<Policy> {
        Ok(match name {
            "importance" => Policy::Importance,
            "random" => Policy::Random,
            "max" => Policy::Max,
            "delta" => Policy::Delta,
            "ordered" => Policy::Ordered,
            _ => anyhow::bail!("unknown selection policy {name:?}"),
        })
    }
}

/// Per-layer unit selection for one client/round (`M_n^t` in channel
/// space; expand to elementwise with [`ChannelMask::to_elementwise`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ChannelMask {
    pub per_layer: Vec<Vec<bool>>,
}

impl ChannelMask {
    pub fn full(spec: &ModelSpec) -> ChannelMask {
        ChannelMask {
            per_layer: spec.layers.iter().map(|l| vec![true; l.out_dim]).collect(),
        }
    }

    pub fn selected_per_layer(&self) -> Vec<usize> {
        self.per_layer
            .iter()
            .map(|l| l.iter().filter(|&&b| b).count())
            .collect()
    }

    /// Expand to elementwise 0/1 masks shaped like the client's params.
    pub fn to_elementwise(&self, spec: &ModelSpec) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(spec.layers.len() * 2);
        for (l, sel) in self.per_layer.iter().enumerate() {
            let (w, b) = expand_unit_mask(spec, l, sel);
            out.push(w);
            out.push(b);
        }
        out
    }

    /// Masked value payload in bytes: the f32 elements under the mask,
    /// with no wire framing. This is the budget-accounting quantity
    /// (A_server budgets are value bytes) and the Eq. 5 sparse-download
    /// charge (the server echoes full-precision values); the uplink is
    /// charged for the *realized* `codec::WireUpload::wire_len()`
    /// instead, and an upload's realized payload under a lossy value
    /// plane is `WireUpload::payload_bytes` ([`payload_bytes_with`]).
    pub fn payload_bytes(&self, spec: &ModelSpec) -> usize {
        self.payload_bytes_with(spec, 4)
    }

    /// [`payload_bytes`] with an explicit serialized width per value
    /// (`codec::PlaneMode::bound_width()`): the masked-value payload
    /// under a forced fp16 (2 B) or int8 (1 B) plane.
    pub fn payload_bytes_with(&self, spec: &ModelSpec, bytes_per_value: usize) -> usize {
        let mut total = 0usize;
        for (layer, sel) in spec.layers.iter().zip(&self.per_layer) {
            let group = crate::codec::unit_group(layer);
            let n_sel = sel.iter().filter(|&&b| b).count();
            total += n_sel * (group + 1); // + bias element
        }
        total * bytes_per_value
    }

    /// Documented **upper bound** on the auto-picked encoded upload size
    /// (`codec::upload_bound`): headers + masked values + the cheaper
    /// per-layer index overhead, counted even when a layer is fully kept
    /// (where the realized dense layout pays no index overhead at all).
    /// Not used on any timing path — `encode_upload` debug-asserts
    /// `wire_len() <= upload_bytes()` for the auto mode and the simnet
    /// charges `wire_len()`. Forced `codec=bitmap|coo` runs can exceed
    /// the bound by construction. f32 values assumed — see
    /// [`upload_bytes_with`] for the plane-width variant.
    pub fn upload_bytes(&self, spec: &ModelSpec) -> usize {
        crate::codec::upload_bound(self, spec)
    }

    /// [`upload_bytes`] with an explicit serialized width per value:
    /// the bound under a forced fp16/int8 plane
    /// (`codec::upload_bound_with`). Keeps Eq. 9 `t_up` budgeting honest
    /// when a run forces a narrow plane.
    pub fn upload_bytes_with(&self, spec: &ModelSpec, bytes_per_value: usize) -> usize {
        crate::codec::upload_bound_with(self, spec, bytes_per_value)
    }
}

/// Per-unit scores for one layer of `spec` under `policy` — the public
/// face of the per-layer scoring used by Algorithm 2. Server-side
/// consumers (the AFD activation-score map in `baselines::afd`) call
/// this on the *global* before/after parameters to score the round's
/// update without re-deriving the group-norm conventions.
pub fn unit_scores(
    spec: &ModelSpec,
    l: usize,
    policy: Policy,
    w_before: &[Tensor],
    w_after: &[Tensor],
    rng: &mut Rng,
) -> Vec<f64> {
    layer_unit_scores(spec, l, policy, w_before, w_after, rng)
}

/// Per-unit scores for one layer.
fn layer_unit_scores(
    spec: &ModelSpec,
    l: usize,
    policy: Policy,
    w_before: &[Tensor],
    w_after: &[Tensor],
    rng: &mut Rng,
) -> Vec<f64> {
    let layer = &spec.layers[l];
    let n = layer.out_dim;
    let wi = 2 * l; // weight tensor index (params are [w,b] per layer)
    let bi = 2 * l + 1;
    match policy {
        Policy::Random => (0..n).map(|_| rng.f64()).collect(),
        Policy::Ordered => (0..n).map(|k| (n - k) as f64).collect(),
        Policy::Max => group_norms(layer, w_after[wi].data(), w_after[bi].data()),
        Policy::Delta => {
            let dw: Vec<f32> = w_after[wi]
                .data()
                .iter()
                .zip(w_before[wi].data())
                .map(|(a, b)| a - b)
                .collect();
            let db: Vec<f32> = w_after[bi]
                .data()
                .iter()
                .zip(w_before[bi].data())
                .map(|(a, b)| a - b)
                .collect();
            group_norms(layer, &dw, &db)
        }
        Policy::Importance => {
            // elementwise |dw * (w+dw) / w| on both tensors, then group L2.
            let score_of = |after: &Tensor, before: &Tensor| -> Vec<f32> {
                let dw: Vec<f32> = after
                    .data()
                    .iter()
                    .zip(before.data())
                    .map(|(a, b)| a - b)
                    .collect();
                let mut s = vec![0.0f32; dw.len()];
                importance_scores(&mut s, before.data(), &dw);
                s
            };
            let sw = score_of(&w_after[wi], &w_before[wi]);
            let sb = score_of(&w_after[bi], &w_before[bi]);
            group_norms(layer, &sw, &sb)
        }
    }
}

/// L2 norm per unit group over (weight tensor, bias tensor) values.
fn group_norms(layer: &crate::model::Layer, w: &[f32], b: &[f32]) -> Vec<f64> {
    let n = layer.out_dim;
    let mut acc = vec![0.0f64; n];
    match layer.kind {
        LayerKind::Conv { kernel, .. } => {
            let group = layer.in_dim * kernel * kernel;
            for k in 0..n {
                let s: f64 = w[k * group..(k + 1) * group]
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum();
                acc[k] = s;
            }
        }
        LayerKind::Fc => {
            for (j, row) in w.chunks_exact(n).enumerate() {
                let _ = j;
                for k in 0..n {
                    acc[k] += (row[k] as f64) * (row[k] as f64);
                }
            }
        }
    }
    for k in 0..n {
        acc[k] += (b[k] as f64) * (b[k] as f64);
        acc[k] = acc[k].sqrt();
    }
    acc
}

/// Number of units layer `l` keeps under dropout rate `d`, clamped to
/// `[1, n_units]` (f64 rounding must never select more units than exist).
pub fn keep_count(n_units: usize, d: f64) -> usize {
    if n_units == 0 {
        return 0;
    }
    let kept = ((n_units as f64) * (1.0 - d)).round().max(1.0) as usize;
    kept.min(n_units)
}

/// Keep the `keep` highest-scoring units: the one total order every mask
/// in the repository selects by.
///
/// Score descending under [`f64::total_cmp`], ties broken by ascending
/// unit index; non-finite scores (a diverged update) sort as lowest
/// priority instead of panicking the coordinator. Explicit tie-breaking
/// (rather than relying on sort stability) keeps masks reproducible
/// across platforms, sort implementations and worker counts.
pub fn rank_and_keep(scores: &[f64], keep: usize) -> Vec<bool> {
    let sane = |x: f64| if x.is_finite() { x } else { f64::MIN };
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| sane(scores[b]).total_cmp(&sane(scores[a])).then(a.cmp(&b)));
    let mut sel = vec![false; scores.len()];
    for &k in order.iter().take(keep) {
        sel[k] = true;
    }
    sel
}

/// Server-chosen uniform random mask at dropout rate `d` (Caldas-style
/// federated dropout, `scheme = fed_dropout`): every layer keeps
/// `keep_count` uniformly random units.
///
/// Draws exactly one `rng.f64()` per unit in layer order — the same
/// stream [`select_mask`] consumes under [`Policy::Random`], so a
/// same-seeded `Rng` produces the identical mask through either entry
/// point (asserted by `random_mask_matches_select_mask` below).
pub fn random_mask(spec: &ModelSpec, d: f64, rng: &mut Rng) -> ChannelMask {
    assert!((0.0..=1.0).contains(&d), "dropout rate {d}");
    let per_layer = spec
        .layers
        .iter()
        .map(|layer| {
            let scores: Vec<f64> = (0..layer.out_dim).map(|_| rng.f64()).collect();
            rank_and_keep(&scores, keep_count(layer.out_dim, d))
        })
        .collect();
    ChannelMask { per_layer }
}

/// Server-chosen mask from a per-(layer, unit) score map at dropout rate
/// `d` (the AFD activation-score path, `scheme = afd`): every layer keeps
/// its `keep_count` highest-scoring units under [`rank_and_keep`]'s total
/// order.
///
/// `scores` is indexed by the *global* model's layers/units; a narrower
/// hetero client scores its units through the leading-corner prefix
/// (`scores[l][..out_dim]`), mirroring how coverage rates index client
/// units. Errors (rather than panics) on a score map that does not cover
/// the spec — the caller may sit downstream of external state.
pub fn mask_from_scores(
    spec: &ModelSpec,
    scores: &[Vec<f64>],
    d: f64,
) -> anyhow::Result<ChannelMask> {
    anyhow::ensure!((0.0..=1.0).contains(&d), "dropout rate {d} outside [0, 1]");
    anyhow::ensure!(
        scores.len() == spec.layers.len(),
        "score map covers {} layers, model has {}",
        scores.len(),
        spec.layers.len()
    );
    let mut per_layer = Vec::with_capacity(spec.layers.len());
    for (l, layer) in spec.layers.iter().enumerate() {
        anyhow::ensure!(
            scores[l].len() >= layer.out_dim,
            "layer {l}: score map has {} units, spec needs {}",
            scores[l].len(),
            layer.out_dim
        );
        let keep = keep_count(layer.out_dim, d);
        per_layer.push(rank_and_keep(&scores[l][..layer.out_dim], keep));
    }
    Ok(ChannelMask { per_layer })
}

/// Select the uploaded channel mask for one client (Algorithm 2).
///
/// `cr` — coverage rates per (layer, global unit), indexed by the client's
/// own unit indices (leading-corner alignment); pass `None` under
/// model-homogeneous settings (CR ≡ 1).
pub fn select_mask(
    policy: Policy,
    spec: &ModelSpec,
    w_before: &[Tensor],
    w_after: &[Tensor],
    cr: Option<&[Vec<f32>]>,
    d: f64,
    rng: &mut Rng,
) -> ChannelMask {
    assert!((0.0..=1.0).contains(&d), "dropout rate {d}");
    let mut per_layer = Vec::with_capacity(spec.layers.len());
    for (l, layer) in spec.layers.iter().enumerate() {
        let mut scores = layer_unit_scores(spec, l, policy, w_before, w_after, rng);
        if policy == Policy::Importance {
            if let Some(cr) = cr {
                for (k, s) in scores.iter_mut().enumerate() {
                    let c = cr[l][k].max(1e-6) as f64;
                    *s /= c;
                }
            }
        }
        let keep = keep_count(layer.out_dim, d);
        per_layer.push(rank_and_keep(&scores, keep));
    }
    ChannelMask { per_layer }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::util::proptest::check;

    fn mlp_params(seed: u64) -> (ModelSpec, Vec<Tensor>, Vec<Tensor>) {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(seed);
        let before = spec.init_params(&mut rng);
        let after: Vec<Tensor> = before
            .iter()
            .map(|t| {
                let d: Vec<f32> =
                    t.data().iter().map(|&x| x + rng.normal_f32(0.0, 0.01)).collect();
                Tensor::new(t.shape().to_vec(), d)
            })
            .collect();
        (spec, before, after)
    }

    #[test]
    fn keep_count_rounds_and_floors() {
        assert_eq!(keep_count(10, 0.6), 4);
        assert_eq!(keep_count(10, 0.0), 10);
        assert_eq!(keep_count(10, 0.99), 1); // at least one unit
        assert_eq!(keep_count(3, 0.5), 2);
        assert_eq!(keep_count(0, 0.5), 0); // degenerate layer stays empty
        // clamped to the unit count even at d = 0
        for n in 1..50 {
            assert!(keep_count(n, 0.0) == n);
        }
    }

    #[test]
    fn equal_scores_break_ties_by_unit_index() {
        // after == before ⇒ every Delta score is exactly 0 ⇒ pure ties:
        // the kept set must be the lowest-indexed units.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(11);
        let before = spec.init_params(&mut rng);
        let after = before.clone();
        let m = select_mask(Policy::Delta, &spec, &before, &after, None, 0.5, &mut rng);
        for (l, sel) in m.per_layer.iter().enumerate() {
            let keep = keep_count(spec.layers[l].out_dim, 0.5);
            assert!(sel[..keep].iter().all(|&b| b), "layer {l}: {sel:?}");
            assert!(sel[keep..].iter().all(|&b| !b), "layer {l}: {sel:?}");
        }
    }

    #[test]
    fn selection_is_reproducible_for_fixed_inputs() {
        let (spec, before, after) = mlp_params(7);
        for policy in [Policy::Importance, Policy::Max, Policy::Delta, Policy::Ordered] {
            let a = select_mask(policy, &spec, &before, &after, None, 0.4, &mut Rng::new(1));
            let b = select_mask(policy, &spec, &before, &after, None, 0.4, &mut Rng::new(1));
            assert_eq!(a, b, "{policy:?}");
        }
    }

    #[test]
    fn mask_counts_match_keep() {
        let (spec, before, after) = mlp_params(0);
        let mut rng = Rng::new(1);
        for policy in [
            Policy::Importance,
            Policy::Random,
            Policy::Max,
            Policy::Delta,
            Policy::Ordered,
        ] {
            let m = select_mask(policy, &spec, &before, &after, None, 0.6, &mut rng);
            let counts = m.selected_per_layer();
            let want: Vec<usize> = spec
                .unit_counts()
                .iter()
                .map(|&n| keep_count(n, 0.6))
                .collect();
            assert_eq!(counts, want, "{policy:?}");
        }
    }

    #[test]
    fn zero_dropout_selects_everything() {
        let (spec, before, after) = mlp_params(1);
        let mut rng = Rng::new(2);
        let m = select_mask(Policy::Importance, &spec, &before, &after, None, 0.0, &mut rng);
        assert_eq!(m, ChannelMask::full(&spec));
        assert_eq!(m.payload_bytes(&spec), spec.size_bytes());
        // the wire-size bound stays a bound even at zero dropout
        assert!(m.upload_bytes(&spec) >= spec.size_bytes());
    }

    #[test]
    fn ordered_takes_prefix() {
        let (spec, before, after) = mlp_params(2);
        let mut rng = Rng::new(3);
        let m = select_mask(Policy::Ordered, &spec, &before, &after, None, 0.5, &mut rng);
        for (l, sel) in m.per_layer.iter().enumerate() {
            let keep = keep_count(spec.layers[l].out_dim, 0.5);
            assert!(sel[..keep].iter().all(|&b| b), "layer {l}");
            assert!(sel[keep..].iter().all(|&b| !b), "layer {l}");
        }
    }

    #[test]
    fn elementwise_mask_matches_payload_bytes() {
        check("mask expansion counts", 10, |rng| {
            let spec = ModelSpec::get("cnn1", 1.0).unwrap();
            let before = spec.init_params(rng);
            let after = spec.init_params(rng);
            let d = rng.range_f64(0.0, 0.9);
            let m = select_mask(Policy::Random, &spec, &before, &after, None, d, rng);
            let elems = m.to_elementwise(&spec);
            let ones: usize = elems
                .iter()
                .map(|t| t.data().iter().filter(|&&x| x == 1.0).count())
                .sum();
            if ones * 4 != m.payload_bytes(&spec) {
                return Err(format!("{} != {}", ones * 4, m.payload_bytes(&spec)));
            }
            // the documented wire bound sits above the raw payload
            if m.upload_bytes(&spec) < m.payload_bytes(&spec) {
                return Err("upload_bytes bound below payload".into());
            }
            // plane widths thread through the accounting linearly
            if m.payload_bytes_with(&spec, 2) * 2 != m.payload_bytes(&spec) {
                return Err("f16 payload width mismatch".into());
            }
            if m.payload_bytes_with(&spec, 1) * 4 != m.payload_bytes(&spec) {
                return Err("i8 payload width mismatch".into());
            }
            if m.upload_bytes_with(&spec, 1) >= m.upload_bytes(&spec)
                && m.payload_bytes(&spec) > 0
            {
                return Err("i8 upload bound not below f32 bound".into());
            }
            Ok(())
        });
    }

    #[test]
    fn importance_prefers_changed_units() {
        // Unit 5 of layer 0 gets a huge update; it must be selected.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(4);
        let before = spec.init_params(&mut rng);
        let mut after = before.clone();
        {
            let (in_dim, out_dim) = (784, spec.layers[0].out_dim);
            let w = after[0].data_mut();
            for j in 0..in_dim {
                w[j * out_dim + 5] += 10.0;
            }
        }
        let m = select_mask(Policy::Importance, &spec, &before, &after, None, 0.9, &mut rng);
        assert!(m.per_layer[0][5], "heavily-updated unit must be kept");
    }

    #[test]
    fn coverage_rate_boosts_rare_units() {
        // Equal scores; CR low on the tail units -> tail preferred.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let n0 = spec.layers[0].out_dim;
        let mut rng = Rng::new(5);
        let before = spec.init_params(&mut rng);
        // after == before + uniform small change => near-equal importances
        let after: Vec<Tensor> = before
            .iter()
            .map(|t| {
                let d: Vec<f32> = t.data().iter().map(|&x| x + 0.01).collect();
                Tensor::new(t.shape().to_vec(), d)
            })
            .collect();
        let mut cr = vec![
            vec![1.0f32; n0],
            vec![1.0f32; spec.layers[1].out_dim],
            vec![1.0f32; spec.layers[2].out_dim],
        ];
        for k in n0 / 2..n0 {
            cr[0][k] = 0.2; // rare among clients
        }
        let m = select_mask(
            Policy::Importance,
            &spec,
            &before,
            &after,
            Some(&cr),
            0.5,
            &mut rng,
        );
        let rare_kept = m.per_layer[0][n0 / 2..].iter().filter(|&&b| b).count();
        let common_kept = m.per_layer[0][..n0 / 2].iter().filter(|&&b| b).count();
        assert!(rare_kept > common_kept, "rare {rare_kept} vs common {common_kept}");
    }

    #[test]
    fn rank_and_keep_orders_and_sanitizes() {
        // Highest scores win; ties go to the lowest unit index.
        assert_eq!(rank_and_keep(&[0.1, 0.9, 0.5, 0.9], 2), vec![false, true, false, true]);
        assert_eq!(rank_and_keep(&[1.0, 1.0, 1.0], 2), vec![true, true, false]);
        // Non-finite scores sort last instead of panicking.
        assert_eq!(
            rank_and_keep(&[f64::NAN, 0.5, f64::INFINITY, 0.7], 2),
            vec![false, true, false, true]
        );
        assert_eq!(rank_and_keep(&[], 0), Vec::<bool>::new());
    }

    #[test]
    fn random_mask_matches_select_mask() {
        // Same-seeded RNGs: the server-chosen dispatch mask must equal
        // the client-side Policy::Random selection draw for draw — the
        // contract that lets fed_dropout ride the existing Random
        // machinery without a second sampling convention.
        let (spec, before, after) = mlp_params(3);
        for d in [0.0, 0.3, 0.6, 0.9] {
            let a = random_mask(&spec, d, &mut Rng::new(41));
            let b = select_mask(Policy::Random, &spec, &before, &after, None, d, &mut Rng::new(41));
            assert_eq!(a, b, "d={d}");
        }
    }

    #[test]
    fn mask_from_scores_keeps_top_units_per_layer() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        // Descending scores per layer => the kept set is the prefix.
        let scores: Vec<Vec<f64>> = spec
            .layers
            .iter()
            .map(|l| (0..l.out_dim).map(|k| (l.out_dim - k) as f64).collect())
            .collect();
        let m = mask_from_scores(&spec, &scores, 0.5).unwrap();
        for (l, sel) in m.per_layer.iter().enumerate() {
            let keep = keep_count(spec.layers[l].out_dim, 0.5);
            assert!(sel[..keep].iter().all(|&b| b), "layer {l}");
            assert!(sel[keep..].iter().all(|&b| !b), "layer {l}");
        }
        // Rate 0 keeps everything.
        assert_eq!(mask_from_scores(&spec, &scores, 0.0).unwrap(), ChannelMask::full(&spec));
    }

    #[test]
    fn mask_from_scores_takes_hetero_prefix_and_rejects_short_maps() {
        // A wider score map (the global model's units) indexes a narrow
        // client through the leading corner.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let wide: Vec<Vec<f64>> = spec
            .layers
            .iter()
            .map(|l| (0..l.out_dim + 8).map(|k| k as f64).collect())
            .collect();
        let m = mask_from_scores(&spec, &wide, 0.5).unwrap();
        for (l, sel) in m.per_layer.iter().enumerate() {
            // ascending scores => the kept set is the *suffix* of the prefix
            let keep = keep_count(spec.layers[l].out_dim, 0.5);
            let kept: usize = sel.iter().filter(|&&b| b).count();
            assert_eq!(kept, keep, "layer {l}");
            assert!(sel[spec.layers[l].out_dim - keep..].iter().all(|&b| b), "layer {l}");
        }
        // A map that does not cover the spec is an error, not a panic.
        let short = vec![vec![1.0f64; 4]; spec.layers.len()];
        assert!(mask_from_scores(&spec, &short, 0.5).is_err());
        assert!(mask_from_scores(&spec, &wide[..1], 0.5).is_err());
        assert!(mask_from_scores(&spec, &wide, 1.5).is_err());
    }
}
