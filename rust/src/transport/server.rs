//! Serve-mode server: the accept/handshake loop, per-connection reader
//! threads feeding a bounded ingest queue, and [`ServeCoordinator`] — the
//! socket-backed [`UploadSource`] the round engine drives exactly like
//! the in-process transport (DESIGN.md §Serve).
//!
//! # Backpressure
//!
//! Decoded uploads cross from the reader threads to the round driver
//! through one `std::sync::mpsc::sync_channel(ingest_queue)`. A slow
//! server blocks the reader on `send`, the kernel socket buffers fill,
//! and the agent's `write` blocks in turn — at no point does the server
//! buffer more than `ingest_queue` decoded uploads plus one socket
//! buffer per connection.
//!
//! # Adversarial connections
//!
//! The handshake runs under a HELLO read timeout with the frame cap
//! pinned low; garbage bytes, oversized length prefixes, half-written
//! frames and silent peers all get the connection dropped while the
//! accept loop keeps serving real agents. After the handshake, a
//! malformed or stalled upload kills only that agent's reader, which
//! reports a `Closed` event — the round driver fails the round with a
//! diagnostic instead of hanging.

use std::collections::{BTreeMap, BTreeSet};
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::config::ExpConfig;
use crate::coordinator::{CloseNote, RoundCall, UploadEnvelope, UploadSink, UploadSource};

use super::frame::{
    encode_tensor_section, read_frame, read_frame_or_idle, write_frame, AckFrame, ConfigFrame,
    DispatchFrame, Hello, UploadFrame, FT_ACK, FT_CONFIG, FT_DISPATCH, FT_DONE, FT_HELLO,
    FT_UPLOAD, MAX_FRAME_BYTES,
};

/// Server-side knobs. The config-file knobs (`listen`, `max_conns`,
/// `ingest_queue`) come through [`ServeOpts::from_config`]; the timeouts
/// have serve defaults and are overridden directly by tests.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// `host:port` to bind; port 0 asks the OS for an ephemeral port
    /// (read the result from [`BoundServer::local_addr`]).
    pub listen: String,
    /// Cap on connection *attempts* during accept — a garbage-spamming
    /// peer exhausts this and fails the serve instead of looping forever.
    pub max_conns: usize,
    /// Bound of the decoded-upload queue between readers and the driver.
    pub ingest_queue: usize,
    /// How long `accept_agents` waits for full slot coverage.
    pub accept_timeout: Duration,
    /// HELLO deadline for a fresh connection; a peer that sends nothing
    /// (or half a frame) within it is dropped.
    pub hello_timeout: Duration,
    /// Per-read timeout on accepted agent sockets. Idle-between-frames
    /// is fine (the reader just re-arms); a timeout *mid-frame* closes
    /// the connection as stalled.
    pub read_timeout: Duration,
    /// How long one round may wait for its outstanding uploads.
    pub round_timeout: Duration,
    /// Per-frame size cap after the handshake.
    pub max_frame: usize,
}

impl ServeOpts {
    pub fn from_config(cfg: &ExpConfig) -> ServeOpts {
        ServeOpts {
            listen: cfg.listen.clone(),
            max_conns: cfg.max_conns,
            ingest_queue: cfg.ingest_queue,
            accept_timeout: Duration::from_secs(120),
            hello_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(30),
            round_timeout: Duration::from_secs(300),
            max_frame: MAX_FRAME_BYTES,
        }
    }
}

/// A bound-but-not-yet-serving listener: bind first (so the resolved
/// ephemeral port can be published), then [`BoundServer::accept_agents`].
pub struct BoundServer {
    listener: TcpListener,
    pub local_addr: SocketAddr,
}

/// One accepted agent: the blocking write half (dispatches + acks) and
/// the slot range it hosts. The read half lives on the reader thread.
struct AgentConn {
    stream: TcpStream,
    slots: Range<usize>,
}

/// What a reader thread feeds the round driver.
enum Event {
    Upload { agent: usize, round: u32, env: UploadEnvelope },
    Closed { agent: usize, why: String },
}

impl BoundServer {
    pub fn bind(opts: &ServeOpts) -> anyhow::Result<BoundServer> {
        let listener = TcpListener::bind(&opts.listen)
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", opts.listen))?;
        let local_addr = listener.local_addr()?;
        Ok(BoundServer { listener, local_addr })
    }

    /// Accept agent connections until every slot `0..n_clients` is
    /// claimed exactly once, handshake each (HELLO in, CONFIG out), then
    /// spawn the reader threads and return the engine-facing transport.
    ///
    /// Connections that fail the handshake — wrong magic or version,
    /// overlapping or out-of-range slot claims, garbage, silence — are
    /// dropped and accepting continues; only exceeding `max_conns`
    /// attempts or the accept deadline fails the serve.
    pub fn accept_agents(
        self,
        opts: &ServeOpts,
        cfg: &ExpConfig,
    ) -> anyhow::Result<ServeCoordinator> {
        anyhow::ensure!(
            cfg.snapshot_ring_cap == 0,
            "serve mode requires snapshot_ring_cap = 0 (uncapped): remote replicas rebase \
             from close notes and must never run the eviction pass"
        );
        anyhow::ensure!(
            crate::baselines::scheme_by_name(&cfg.scheme)?.agent_masks(cfg).is_some(),
            "scheme {:?} keeps server-resident dispatch-mask state and cannot run in serve mode",
            cfg.scheme
        );
        let n = cfg.n_clients;
        let cfg_json = cfg.to_json().to_string_compact();
        self.listener.set_nonblocking(true)?;
        let deadline = Instant::now() + opts.accept_timeout;
        let mut covered = vec![false; n];
        let mut agents: Vec<AgentConn> = Vec::new();
        let mut attempts = 0usize;
        while covered.iter().any(|c| !c) {
            anyhow::ensure!(
                Instant::now() < deadline,
                "accept timed out with slots {:?}... still unclaimed",
                uncovered_preview(&covered)
            );
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    attempts += 1;
                    anyhow::ensure!(
                        attempts <= opts.max_conns,
                        "{attempts} connection attempts exceed max_conns = {}",
                        opts.max_conns
                    );
                    match handshake(stream, opts, n, &covered, &cfg_json) {
                        Ok(conn) => {
                            for s in conn.slots.clone() {
                                covered[s] = true;
                            }
                            log::info!(
                                "agent {peer} hosts slots {}..{}",
                                conn.slots.start,
                                conn.slots.end
                            );
                            agents.push(conn);
                        }
                        Err(e) => log::warn!("rejected connection from {peer}: {e:#}"),
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }

        // Slots covered: arm the ingest side. Readers share one bounded
        // channel; their blocking `send` is the backpressure contract.
        let (tx, rx) = mpsc::sync_channel::<Event>(opts.ingest_queue.max(1));
        let mut readers = Vec::with_capacity(agents.len());
        for (i, conn) in agents.iter().enumerate() {
            let mut stream = conn.stream.try_clone()?;
            stream.set_read_timeout(Some(opts.read_timeout))?;
            conn.stream.set_write_timeout(Some(opts.round_timeout))?;
            let tx = tx.clone();
            let max_frame = opts.max_frame;
            readers.push(
                thread::Builder::new()
                    .name(format!("feddd-ingest-{i}"))
                    .spawn(move || reader_loop(i, &mut stream, max_frame, &tx))?,
            );
        }
        drop(tx);
        Ok(ServeCoordinator {
            agents,
            rx: Some(rx),
            readers,
            round_timeout: opts.round_timeout,
            shut: false,
        })
    }
}

/// First eight unclaimed slots, for the accept-timeout diagnostic.
fn uncovered_preview(covered: &[bool]) -> Vec<usize> {
    covered
        .iter()
        .enumerate()
        .filter(|&(_, &c)| !c)
        .map(|(i, _)| i)
        .take(8)
        .collect()
}

/// HELLO in (64-byte frame cap, `hello_timeout` read timeout), slot
/// range validated against the fleet and prior claims, CONFIG out.
fn handshake(
    stream: TcpStream,
    opts: &ServeOpts,
    n_clients: usize,
    covered: &[bool],
    cfg_json: &str,
) -> anyhow::Result<AgentConn> {
    let mut stream = stream;
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(opts.hello_timeout))?;
    stream.set_nodelay(true).ok();
    let (ty, payload) = read_frame(&mut stream, 64)?;
    anyhow::ensure!(ty == FT_HELLO, "expected HELLO, got frame type {ty}");
    let hello = Hello::decode(&payload)?;
    let start = hello.slot_start as usize;
    anyhow::ensure!(start < n_clients, "slot_start {start} out of range (fleet has {n_clients})");
    let count =
        if hello.slot_count == 0 { n_clients - start } else { hello.slot_count as usize };
    anyhow::ensure!(
        start + count <= n_clients,
        "slot range {start}+{count} exceeds fleet size {n_clients}"
    );
    for (s, claimed) in covered.iter().enumerate().take(start + count).skip(start) {
        anyhow::ensure!(!claimed, "slot {s} already claimed by another agent");
    }
    write_frame(
        &mut stream,
        FT_CONFIG,
        &ConfigFrame::encode_parts(start as u32, count as u32, cfg_json),
    )?;
    Ok(AgentConn { stream, slots: start..start + count })
}

/// Reader-thread body: decode uploads off one agent socket into the
/// shared bounded queue until the connection dies or the run shuts down.
fn reader_loop(agent: usize, stream: &mut TcpStream, max_frame: usize, tx: &mpsc::SyncSender<Event>) {
    loop {
        match read_frame_or_idle(stream, max_frame) {
            // Timeout with no frame started: the agent is just idle
            // (training, or waiting on the next dispatch). Re-arm.
            Ok(None) => {}
            Ok(Some((FT_UPLOAD, payload))) => match UploadFrame::decode(&payload) {
                Ok(up) => {
                    let (round, env) = up.into_envelope();
                    // Blocking send on the bounded channel *is* the
                    // backpressure; Err means the run shut down.
                    if tx.send(Event::Upload { agent, round, env }).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    let _ = tx.send(Event::Closed { agent, why: format!("bad upload: {e:#}") });
                    return;
                }
            },
            Ok(Some((ty, _))) => {
                let _ = tx.send(Event::Closed { agent, why: format!("unexpected frame type {ty}") });
                return;
            }
            Err(e) => {
                let _ = tx.send(Event::Closed { agent, why: format!("{e:#}") });
                return;
            }
        }
    }
}

/// The socket transport the round engine drives: each `round_uploads`
/// call dispatches the round to every agent, then collects, validates,
/// acks and re-orders uploads so the sink sees the subset in ascending
/// slot order — the same delivery contract `LocalTransport` honors,
/// which is what keeps a loopback serve bitwise-identical to an
/// in-process run.
pub struct ServeCoordinator {
    agents: Vec<AgentConn>,
    /// `None` once shut down (dropping it unblocks queued reader sends).
    rx: Option<mpsc::Receiver<Event>>,
    readers: Vec<thread::JoinHandle<()>>,
    round_timeout: Duration,
    shut: bool,
}

impl UploadSource for ServeCoordinator {
    fn round_uploads(
        &mut self,
        mut call: RoundCall<'_>,
        sink: &mut dyn UploadSink,
    ) -> anyhow::Result<()> {
        let rx = self.rx.as_ref().ok_or_else(|| anyhow::anyhow!("transport already shut down"))?;
        let agents = &mut self.agents;
        let round = call.round as u32;

        // ---- dispatch: one frame per agent, every round ----
        // Even an agent with no dispatched slot this round gets the
        // frame: its replica still needs the close notes and the fresh
        // global to stay in lockstep.
        let tensor_section = encode_tensor_section(call.global);
        for conn in agents.iter_mut() {
            let notes: Vec<CloseNote> =
                call.notes.iter().filter(|n| conn.slots.contains(&n.slot)).copied().collect();
            let entries: Vec<(u32, f64)> = call
                .subset
                .iter()
                .filter(|&&s| conn.slots.contains(&s))
                .map(|&s| (s as u32, call.dropout[s]))
                .collect();
            let payload = DispatchFrame::encode_parts(
                round,
                call.full_broadcast,
                &notes,
                &tensor_section,
                &entries,
            );
            write_frame(&mut conn.stream, FT_DISPATCH, &payload).map_err(|e| {
                anyhow::anyhow!("dispatch to agent of slots {:?}: {e:#}", conn.slots)
            })?;
        }

        // ---- collect: park out-of-order arrivals, deliver ascending ----
        let subset = call.subset;
        let expected: BTreeSet<usize> = subset.iter().copied().collect();
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        let mut parked: BTreeMap<usize, UploadEnvelope> = BTreeMap::new();
        let mut next = 0usize;
        while next < subset.len() {
            let ev = rx.recv_timeout(self.round_timeout).map_err(|e| {
                anyhow::anyhow!(
                    "round {}: gave up waiting for slot {} ({} of {} uploads in): {e}",
                    call.round,
                    subset[next],
                    next,
                    subset.len()
                )
            })?;
            match ev {
                Event::Closed { agent, why } => {
                    anyhow::bail!(
                        "agent {agent} (slots {:?}) lost mid-round {}: {why}",
                        agents[agent].slots,
                        call.round
                    );
                }
                Event::Upload { agent, round: r, env } => {
                    let slot = env.slot;
                    anyhow::ensure!(
                        r == round,
                        "agent {agent} uploaded for round {r} during round {}",
                        call.round
                    );
                    anyhow::ensure!(
                        agents[agent].slots.contains(&slot),
                        "agent {agent} uploaded for slot {slot} outside its range {:?}",
                        agents[agent].slots
                    );
                    anyhow::ensure!(
                        expected.contains(&slot),
                        "upload for slot {slot}, which round {} never dispatched",
                        call.round
                    );
                    anyhow::ensure!(seen.insert(slot), "duplicate upload for slot {slot}");
                    // Replica cross-check: m_n is a pure function of the
                    // shared config, so a mismatch means the agent is
                    // running a different experiment.
                    anyhow::ensure!(
                        env.m_n == call.clients[slot].m_n() as f32,
                        "replica mismatch: agent reports m_n = {} for slot {slot}, server \
                         derives {}",
                        env.m_n,
                        call.clients[slot].m_n()
                    );
                    // The server replica never trains; mirror the two
                    // fields `train_local` would have written so the next
                    // round's Oort utility and Eq. 13 allocation read the
                    // same values as an in-process run.
                    call.clients[slot].last_loss = env.loss;
                    call.clients[slot].participations += 1;
                    write_frame(
                        &mut agents[agent].stream,
                        FT_ACK,
                        &AckFrame::encode_parts(round, slot as u32),
                    )?;
                    parked.insert(slot, env);
                    while next < subset.len() {
                        let Some(env) = parked.remove(&subset[next]) else { break };
                        sink.deliver(env)?;
                        next += 1;
                    }
                }
            }
        }
        Ok(())
    }

    fn shutdown(&mut self) -> anyhow::Result<()> {
        if self.shut {
            return Ok(());
        }
        self.shut = true;
        for conn in &mut self.agents {
            let _ = write_frame(&mut conn.stream, FT_DONE, &[]);
        }
        // Unblock the readers: queued sends fail once the receiver drops,
        // and blocking reads error out once the sockets shut down.
        drop(self.rx.take());
        for conn in &self.agents {
            let _ = conn.stream.shutdown(Shutdown::Both);
        }
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
        Ok(())
    }
}

impl Drop for ServeCoordinator {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}
