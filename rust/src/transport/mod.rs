//! Serve mode: the round engine over a real socket (DESIGN.md §Serve).
//!
//! The engine's transport seam is `coordinator::ingest` — the round
//! drivers consume uploads through the `UploadSource`/`UploadSink` trait
//! pair and never know where an envelope came from. This module is the
//! socket-backed implementation of that seam, dependency-light on
//! `std::net` TCP:
//!
//! * [`frame`]-level: length-prefixed, checksummed binary frames
//!   (HELLO / CONFIG / DISPATCH / UPLOAD / ACK / DONE) with every length
//!   bounds-checked before allocation.
//! * [`ServeCoordinator`] (server): accepts agents until the fleet's
//!   slot range is exactly covered, then per round sends one DISPATCH to
//!   every agent and re-orders the incoming uploads into the ascending
//!   delivery order the ingest contract requires. Reader threads feed a
//!   *bounded* queue — a slow server blocks agents through TCP instead
//!   of buffering unboundedly.
//! * [`run_agent`] (client): rebuilds a bitwise replica of the server's
//!   run from the CONFIG frame, trains its dispatched slots with the
//!   exact staging code the in-process transport uses, and keeps each
//!   upload's Eq. 5 residual local until its close note arrives.
//!
//! Both ends deterministically derive everything else — fleet, data
//! partition, RNG streams — from the shared config, which is what makes
//! a loopback serve bitwise-identical to `run_experiment` on one
//! process (`rust/tests/serve_loopback.rs`).

pub mod frame;

mod agent;
mod server;

pub use agent::{run_agent, AgentOpts, AgentReport};
pub use server::{BoundServer, ServeCoordinator, ServeOpts};
