//! Serve-mode agent: connect, receive the experiment config, build a
//! bitwise replica of the server's run, then train dispatched slots and
//! stream the uploads back (DESIGN.md §Serve).
//!
//! The agent never advances rounds itself — the server's DISPATCH frames
//! are the clock. Each one carries the fresh global, the previous
//! round's close notes for this agent's slots (rebased through
//! `FedRun::install_dispatch_base`) and the dispatch list (staged through
//! `FedRun::stage_for_dispatch`, the exact code the in-process transport
//! runs). Residuals never cross the wire: they wait in the agent's
//! [`AgentPending`] ledger until their close note arrives.

use std::collections::BTreeMap;
use std::net::TcpStream;

use crate::codec::recycle_wire_upload;
use crate::config::ExpConfig;
use crate::coordinator::{AgentPending, FedRun, UploadEnvelope, UploadSink};
use crate::util::json;

use super::frame::{
    read_frame, write_frame, AckFrame, ConfigFrame, DispatchFrame, Hello, UploadFrame, FT_ACK,
    FT_CONFIG, FT_DISPATCH, FT_DONE, FT_HELLO, FT_UPLOAD, MAX_FRAME_BYTES,
};

/// Client-side knobs for [`run_agent`].
#[derive(Clone, Debug)]
pub struct AgentOpts {
    /// Server `host:port`.
    pub connect: String,
    /// First slot this agent volunteers to host.
    pub slot_start: usize,
    /// Slots to host; `None` claims everything from `slot_start` through
    /// the end of the fleet.
    pub slot_count: Option<usize>,
    /// `ExpConfig::set` overrides applied to the received config before
    /// the replica is built. Only host-local knobs (`workers`,
    /// `artifacts_dir`) are safe: anything that changes the experiment
    /// desynchronizes the replica, and the server's m_n cross-check will
    /// refuse the uploads.
    pub overrides: Vec<(String, String)>,
}

/// What [`run_agent`] did, for logs and the CLI summary line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AgentReport {
    pub slot_start: usize,
    pub slot_count: usize,
    /// DISPATCH frames processed (one per server round).
    pub rounds: usize,
    /// Uploads sent.
    pub uploads: usize,
    /// Total UPLOAD frame payload bytes written.
    pub upload_bytes: usize,
    /// Server receipts seen (trails `uploads` only if the run ends with
    /// acks still in flight).
    pub acks: usize,
}

/// Streams staged envelopes straight onto the socket, keeping each
/// slot's residual in the pending ledger for the close note to come.
struct AgentSink<'a> {
    stream: &'a mut TcpStream,
    round: u32,
    pendings: &'a mut BTreeMap<usize, AgentPending>,
    uploads: usize,
    upload_bytes: usize,
}

impl UploadSink for AgentSink<'_> {
    fn deliver(&mut self, env: UploadEnvelope) -> anyhow::Result<()> {
        let payload = UploadFrame::encode(self.round, &env);
        write_frame(self.stream, FT_UPLOAD, &payload)?;
        self.uploads += 1;
        self.upload_bytes += payload.len();
        self.pendings.insert(
            env.slot,
            AgentPending { residual: env.residual, full_broadcast: env.full_broadcast },
        );
        recycle_wire_upload(env.wire);
        Ok(())
    }
}

/// Run one agent to completion: handshake, replicate, then serve
/// dispatches until the server says DONE.
pub fn run_agent(opts: &AgentOpts) -> anyhow::Result<AgentReport> {
    let mut stream = TcpStream::connect(&opts.connect)
        .map_err(|e| anyhow::anyhow!("connect {}: {e}", opts.connect))?;
    stream.set_nodelay(true).ok();
    let hello = Hello {
        slot_start: opts.slot_start as u32,
        slot_count: opts.slot_count.unwrap_or(0) as u32,
    };
    write_frame(&mut stream, FT_HELLO, &hello.encode())?;

    let (ty, payload) = read_frame(&mut stream, MAX_FRAME_BYTES)?;
    anyhow::ensure!(ty == FT_CONFIG, "expected CONFIG, got frame type {ty}");
    let cf = ConfigFrame::decode(&payload)?;
    let parsed = json::parse(&cf.cfg_json).map_err(|e| anyhow::anyhow!("config json: {e}"))?;
    let mut cfg = ExpConfig::from_json(&parsed)?;
    for (k, v) in &opts.overrides {
        cfg.set(k, v)?;
    }
    anyhow::ensure!(
        cfg.snapshot_ring_cap == 0,
        "serve mode requires snapshot_ring_cap = 0 (uncapped), got {}",
        cfg.snapshot_ring_cap
    );
    cfg.validate()?;
    anyhow::ensure!(
        crate::baselines::scheme_by_name(&cfg.scheme)?.agent_masks(&cfg).is_some(),
        "scheme {:?} keeps server-resident dispatch-mask state and cannot run in serve mode",
        cfg.scheme
    );
    let n_clients = cfg.n_clients;
    let slot_start = cf.slot_start as usize;
    let slot_count = cf.slot_count as usize;
    anyhow::ensure!(
        slot_count >= 1 && slot_start + slot_count <= n_clients,
        "assigned slots {slot_start}+{slot_count} do not fit a fleet of {n_clients}"
    );
    log::info!(
        "agent: replicating a fleet of {n_clients} to host slots {slot_start}..{}",
        slot_start + slot_count
    );
    let mut run = FedRun::new(cfg)?;
    let mut pendings: BTreeMap<usize, AgentPending> = BTreeMap::new();
    let mut report =
        AgentReport { slot_start, slot_count, ..AgentReport::default() };

    loop {
        let (ty, payload) = read_frame(&mut stream, MAX_FRAME_BYTES)?;
        match ty {
            FT_DISPATCH => {
                let d = DispatchFrame::decode(&payload)?;
                let round = d.round as usize;
                run.install_dispatch_base(round, d.global, &d.notes, &mut pendings)?;
                let mut dropout = vec![0.0f64; n_clients];
                let mut subset = Vec::with_capacity(d.entries.len());
                for &(slot, rate) in &d.entries {
                    let slot = slot as usize;
                    anyhow::ensure!(
                        slot >= slot_start && slot < slot_start + slot_count,
                        "dispatched slot {slot} outside this agent's range"
                    );
                    dropout[slot] = rate;
                    subset.push(slot);
                }
                let mut sink = AgentSink {
                    stream: &mut stream,
                    round: d.round,
                    pendings: &mut pendings,
                    uploads: 0,
                    upload_bytes: 0,
                };
                run.stage_for_dispatch(round, d.full_broadcast, &subset, &dropout, &mut sink)?;
                report.uploads += sink.uploads;
                report.upload_bytes += sink.upload_bytes;
                report.rounds += 1;
            }
            FT_ACK => {
                AckFrame::decode(&payload)?;
                report.acks += 1;
            }
            FT_DONE => break,
            other => anyhow::bail!("unexpected frame type {other} from server"),
        }
    }
    run.shutdown_transport()?;
    Ok(report)
}
