//! Length-prefixed binary framing for serve mode (DESIGN.md §Serve).
//!
//! Every frame is `type: u8, len: u32 LE, payload: [u8; len]`. The length
//! is bounds-checked against a caller-supplied cap *before* any buffer is
//! allocated, so an adversarial prefix (say `u32::MAX`) is rejected
//! without reserving four gigabytes. Payload layouts are little-endian
//! throughout and decoded through [`ByteReader`], which range-checks
//! every read and refuses trailing bytes — a truncated or padded frame is
//! an error, never a silent misparse.
//!
//! Control plane, in connection order:
//!
//! 1. [`Hello`] (agent → server): protocol magic + version + the slot
//!    range this agent volunteers to host.
//! 2. [`ConfigFrame`] (server → agent): the resolved slot range plus the
//!    full experiment config as compact JSON — the agent rebuilds a
//!    bitwise replica of the server's run from it.
//! 3. [`DispatchFrame`] (server → agent, once per round, to *every*
//!    agent): round number, broadcast flag, the previous round's close
//!    notes for this agent's slots, the current global parameters, and
//!    the `(slot, dropout)` dispatch list.
//! 4. [`UploadFrame`] (agent → server): one trained upload — round
//!    metadata, Eq. 7–9 timing terms, and the checksummed
//!    [`WireUpload`] byte image.
//! 5. [`AckFrame`] (server → agent): receipt for one upload.
//! 6. `DONE` (server → agent, empty payload): the run is over.

use std::io::{self, Read, Write};

use crate::codec::WireUpload;
use crate::coordinator::{CloseNote, UploadEnvelope};
use crate::simnet::RoundTiming;
use crate::tensor::Tensor;

/// Protocol magic opening every HELLO payload.
pub const MAGIC: [u8; 4] = *b"FDTP";
/// Protocol version; bumped on any frame-layout change.
pub const VERSION: u16 = 1;

/// Frame type tags.
pub const FT_HELLO: u8 = 1;
pub const FT_CONFIG: u8 = 2;
pub const FT_DISPATCH: u8 = 3;
pub const FT_UPLOAD: u8 = 4;
pub const FT_ACK: u8 = 5;
pub const FT_DONE: u8 = 6;

/// Default per-frame size cap (guards the length-prefix allocation).
pub const MAX_FRAME_BYTES: usize = 256 << 20;

/// Write one `type + length + payload` frame and flush it.
pub fn write_frame(w: &mut dyn Write, ty: u8, payload: &[u8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        payload.len() <= u32::MAX as usize,
        "frame payload of {} bytes exceeds the u32 length prefix",
        payload.len()
    );
    let mut head = [0u8; 5];
    head[0] = ty;
    head[1..5].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, rejecting any length prefix above `max_len` before
/// allocating. Blocks until a full frame arrives (or the stream's read
/// timeout, if any, fires — a mid-frame timeout is an error).
pub fn read_frame(r: &mut dyn Read, max_len: usize) -> anyhow::Result<(u8, Vec<u8>)> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    read_after_header(r, head, max_len)
}

/// [`read_frame`] against a stream with a read timeout: `Ok(None)` when
/// the timeout fires *between* frames (no header byte read yet — a
/// legitimately idle peer), an error when it fires mid-frame (a stalled,
/// half-written peer) or on EOF.
pub fn read_frame_or_idle(
    r: &mut dyn Read,
    max_len: usize,
) -> anyhow::Result<Option<(u8, Vec<u8>)>> {
    let mut head = [0u8; 5];
    let mut got = 0usize;
    while got < head.len() {
        match r.read(&mut head[got..]) {
            Ok(0) => {
                anyhow::ensure!(got == 0, "peer closed mid-frame header ({got}/5 bytes)");
                anyhow::bail!("peer closed the connection");
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if got == 0 {
                    return Ok(None);
                }
                anyhow::bail!("read timed out mid-frame header ({got}/5 bytes)");
            }
            Err(e) => return Err(e.into()),
        }
    }
    read_after_header(r, head, max_len).map(Some)
}

fn read_after_header(
    r: &mut dyn Read,
    head: [u8; 5],
    max_len: usize,
) -> anyhow::Result<(u8, Vec<u8>)> {
    let ty = head[0];
    let len = u32::from_le_bytes([head[1], head[2], head[3], head[4]]) as usize;
    anyhow::ensure!(
        len <= max_len,
        "frame type {ty} declares {len} bytes, above the {max_len}-byte cap"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok((ty, payload))
}

/// Little-endian payload builder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Range-checked little-endian payload reader.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.buf.len() - self.pos >= n,
            "frame underrun: need {n} bytes at offset {} of {}",
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        self.take(n)
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    /// Every byte must have been consumed — padding is a protocol error.
    pub fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.remaining() == 0, "{} trailing bytes in frame", self.remaining());
        Ok(())
    }
}

/// HELLO: the agent volunteers a slot range. `slot_count == 0` claims
/// "from `slot_start` through the last client of the fleet".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub slot_start: u32,
    pub slot_count: u32,
}

impl Hello {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.bytes(&MAGIC);
        w.u16(VERSION);
        w.u32(self.slot_start);
        w.u32(self.slot_count);
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> anyhow::Result<Hello> {
        let mut r = ByteReader::new(payload);
        let magic = r.bytes(4)?;
        anyhow::ensure!(magic == MAGIC, "bad hello magic {magic:02x?}");
        let version = r.u16()?;
        anyhow::ensure!(version == VERSION, "protocol version {version}, expected {VERSION}");
        let h = Hello { slot_start: r.u32()?, slot_count: r.u32()? };
        r.done()?;
        Ok(h)
    }
}

/// CONFIG: the server's resolved slot assignment plus the experiment
/// config the agent must replicate, as compact JSON.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigFrame {
    pub slot_start: u32,
    pub slot_count: u32,
    pub cfg_json: String,
}

impl ConfigFrame {
    pub fn encode_parts(slot_start: u32, slot_count: u32, cfg_json: &str) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(slot_start);
        w.u32(slot_count);
        w.bytes(cfg_json.as_bytes());
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> anyhow::Result<ConfigFrame> {
        let mut r = ByteReader::new(payload);
        let slot_start = r.u32()?;
        let slot_count = r.u32()?;
        let rest = r.bytes(r.remaining())?;
        let cfg_json = String::from_utf8(rest.to_vec())
            .map_err(|e| anyhow::anyhow!("config frame is not utf-8: {e}"))?;
        Ok(ConfigFrame { slot_start, slot_count, cfg_json })
    }
}

/// Serialize the global-parameter section of a DISPATCH frame once; the
/// server splices the same bytes into every agent's frame instead of
/// re-encoding the model per connection.
pub fn encode_tensor_section(tensors: &[Tensor]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u32(tensors.len() as u32);
    for t in tensors {
        let shape = t.shape();
        w.u8(shape.len() as u8);
        for &d in shape {
            w.u32(d as u32);
        }
        for &v in t.data() {
            w.f32(v);
        }
    }
    w.finish()
}

fn decode_tensor_section(r: &mut ByteReader<'_>) -> anyhow::Result<Vec<Tensor>> {
    let count = r.u32()? as usize;
    anyhow::ensure!(count <= 1024, "dispatch declares {count} tensors");
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let ndim = r.u8()? as usize;
        anyhow::ensure!((1..=8).contains(&ndim), "tensor rank {ndim} out of range");
        let mut shape = Vec::with_capacity(ndim);
        let mut numel = 1usize;
        for _ in 0..ndim {
            let d = r.u32()? as usize;
            numel = numel
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("tensor shape product overflows"))?;
            shape.push(d);
        }
        anyhow::ensure!(
            numel.checked_mul(4).is_some_and(|b| b <= r.remaining()),
            "tensor of {numel} elements overruns the frame ({} bytes left)",
            r.remaining()
        );
        let raw = r.bytes(numel * 4)?;
        let mut data = Vec::with_capacity(numel);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        out.push(Tensor::new(shape, data));
    }
    Ok(out)
}

/// DISPATCH: everything an agent needs to run one round for its slots.
/// Sent to *every* agent every round, even when its dispatch list is
/// empty — the close notes and the fresh global must still land so the
/// replica rebases in lockstep with the server.
#[derive(Debug)]
pub struct DispatchFrame {
    pub round: u32,
    pub full_broadcast: bool,
    /// Close notes from the previous round, filtered to this agent's
    /// slots, ascending.
    pub notes: Vec<CloseNote>,
    /// The server's current global parameters (the round's download base).
    pub global: Vec<Tensor>,
    /// `(slot, dropout rate)` for each dispatched slot of this agent,
    /// ascending by slot.
    pub entries: Vec<(u32, f64)>,
}

impl DispatchFrame {
    pub fn encode_parts(
        round: u32,
        full_broadcast: bool,
        notes: &[CloseNote],
        tensor_section: &[u8],
        entries: &[(u32, f64)],
    ) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(round);
        w.u8(u8::from(full_broadcast));
        w.u32(notes.len() as u32);
        for n in notes {
            w.u32(n.slot as u32);
            w.u8(u8::from(n.churned));
        }
        w.bytes(tensor_section);
        w.u32(entries.len() as u32);
        for &(slot, d) in entries {
            w.u32(slot);
            w.f64(d);
        }
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> anyhow::Result<DispatchFrame> {
        let mut r = ByteReader::new(payload);
        let round = r.u32()?;
        let full_broadcast = r.u8()? != 0;
        let n_notes = r.u32()? as usize;
        anyhow::ensure!(
            n_notes * 5 <= r.remaining(),
            "dispatch declares {n_notes} close notes in a {}-byte tail",
            r.remaining()
        );
        let mut notes = Vec::with_capacity(n_notes);
        for _ in 0..n_notes {
            let slot = r.u32()? as usize;
            let churned = r.u8()? != 0;
            notes.push(CloseNote { slot, churned });
        }
        let global = decode_tensor_section(&mut r)?;
        let n_entries = r.u32()? as usize;
        anyhow::ensure!(
            n_entries * 12 <= r.remaining(),
            "dispatch declares {n_entries} entries in a {}-byte tail",
            r.remaining()
        );
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let slot = r.u32()?;
            let d = r.f64()?;
            entries.push((slot, d));
        }
        r.done()?;
        Ok(DispatchFrame { round, full_broadcast, notes, global, entries })
    }
}

/// UPLOAD: one trained client update — the envelope metadata plus the
/// checksummed [`WireUpload`] byte image. The Eq. 5 residual never
/// crosses the wire: it stays on the agent (see
/// [`crate::coordinator::AgentPending`]), and the server folds the
/// upload with `residual: None`.
#[derive(Debug)]
pub struct UploadFrame {
    pub round: u32,
    pub slot: u32,
    pub loss: f64,
    pub uploaded: u64,
    pub m_n: f32,
    pub full_broadcast: bool,
    pub timing: RoundTiming,
    pub wire: WireUpload,
}

impl UploadFrame {
    pub fn encode(round: u32, env: &UploadEnvelope) -> Vec<u8> {
        let blob = env.wire.to_bytes();
        let mut w = ByteWriter::new();
        w.u32(round);
        w.u32(env.slot as u32);
        w.f64(env.loss);
        w.u64(env.uploaded as u64);
        w.f32(env.m_n);
        w.u8(u8::from(env.full_broadcast));
        w.f64(env.timing.t_down);
        w.f64(env.timing.t_cmp);
        w.f64(env.timing.t_up);
        w.u32(blob.len() as u32);
        w.bytes(&blob);
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> anyhow::Result<UploadFrame> {
        let mut r = ByteReader::new(payload);
        let round = r.u32()?;
        let slot = r.u32()?;
        let loss = r.f64()?;
        let uploaded = r.u64()?;
        let m_n = r.f32()?;
        let full_broadcast = r.u8()? != 0;
        let timing = RoundTiming { t_down: r.f64()?, t_cmp: r.f64()?, t_up: r.f64()? };
        let blob_len = r.u32()? as usize;
        let wire = WireUpload::from_bytes(r.bytes(blob_len)?)?;
        r.done()?;
        Ok(UploadFrame { round, slot, loss, uploaded, m_n, full_broadcast, timing, wire })
    }

    /// The round tag plus the ingest-layer envelope this frame carries
    /// (`residual: None` — it never left the agent).
    pub fn into_envelope(self) -> (u32, UploadEnvelope) {
        let env = UploadEnvelope {
            slot: self.slot as usize,
            loss: self.loss,
            uploaded: self.uploaded as usize,
            m_n: self.m_n,
            wire: self.wire,
            residual: None,
            full_broadcast: self.full_broadcast,
            timing: self.timing,
        };
        (self.round, env)
    }
}

/// ACK: the server's receipt for one upload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AckFrame {
    pub round: u32,
    pub slot: u32,
}

impl AckFrame {
    pub fn encode_parts(round: u32, slot: u32) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(round);
        w.u32(slot);
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> anyhow::Result<AckFrame> {
        let mut r = ByteReader::new(payload);
        let a = AckFrame { round: r.u32()?, slot: r.u32()? };
        r.done()?;
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode_upload;
    use crate::model::ModelSpec;
    use crate::selection::ChannelMask;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_over_a_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FT_HELLO, &[1, 2, 3]).unwrap();
        write_frame(&mut buf, FT_DONE, &[]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r, 64).unwrap(), (FT_HELLO, vec![1, 2, 3]));
        assert_eq!(read_frame(&mut r, 64).unwrap(), (FT_DONE, vec![]));
        // EOF after the last frame.
        assert!(read_frame(&mut r, 64).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocating() {
        // type + u32::MAX length, no payload: must fail on the cap check,
        // not by attempting a 4 GiB read.
        let mut bytes = vec![FT_UPLOAD];
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(bytes), MAX_FRAME_BYTES).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn truncated_frame_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FT_ACK, &[0u8; 8]).unwrap();
        buf.truncate(buf.len() - 3); // lose part of the payload
        assert!(read_frame(&mut Cursor::new(buf), 64).is_err());
        // And a mid-header cut:
        assert!(read_frame(&mut Cursor::new(vec![FT_ACK, 1]), 64).is_err());
    }

    #[test]
    fn hello_roundtrip_and_garbage_rejection() {
        let h = Hello { slot_start: 3, slot_count: 9 };
        assert_eq!(Hello::decode(&h.encode()).unwrap(), h);
        assert!(Hello::decode(b"GET / HTTP/1.1").is_err());
        assert!(Hello::decode(&[]).is_err());
        // Right magic, wrong version.
        let mut w = ByteWriter::new();
        w.bytes(&MAGIC);
        w.u16(VERSION + 1);
        w.u32(0);
        w.u32(0);
        assert!(Hello::decode(&w.finish()).is_err());
        // Trailing bytes are refused.
        let mut padded = h.encode();
        padded.push(0);
        assert!(Hello::decode(&padded).is_err());
    }

    #[test]
    fn config_roundtrip() {
        let payload = ConfigFrame::encode_parts(2, 5, "{\"seed\":17}");
        let c = ConfigFrame::decode(&payload).unwrap();
        assert_eq!(
            c,
            ConfigFrame { slot_start: 2, slot_count: 5, cfg_json: "{\"seed\":17}".into() }
        );
    }

    #[test]
    fn dispatch_roundtrip() {
        let notes = vec![
            CloseNote { slot: 1, churned: false },
            CloseNote { slot: 4, churned: true },
        ];
        let global = vec![
            Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 4.25, -0.5]),
            Tensor::new(vec![3], vec![0.1, 0.2, 0.3]),
        ];
        let entries = vec![(1u32, 0.25f64), (4, 0.0)];
        let section = encode_tensor_section(&global);
        let payload = DispatchFrame::encode_parts(7, true, &notes, &section, &entries);
        let d = DispatchFrame::decode(&payload).unwrap();
        assert_eq!(d.round, 7);
        assert!(d.full_broadcast);
        assert_eq!(d.notes, notes);
        assert_eq!(d.entries, entries);
        assert_eq!(d.global.len(), 2);
        for (got, want) in d.global.iter().zip(&global) {
            assert_eq!(got.shape(), want.shape());
            assert_eq!(got.data(), want.data());
        }
    }

    #[test]
    fn dispatch_with_corrupt_tensor_section_is_rejected() {
        let payload = DispatchFrame::encode_parts(1, false, &[], &encode_tensor_section(&[]), &[]);
        assert!(DispatchFrame::decode(&payload).is_ok());
        // A tensor section declaring data it does not carry:
        let mut w = ByteWriter::new();
        w.u32(1); // round
        w.u8(0); // full_broadcast
        w.u32(0); // notes
        w.u32(1); // one tensor ...
        w.u8(1); // ... of rank 1 ...
        w.u32(1_000_000); // ... with a million elements it never ships
        assert!(DispatchFrame::decode(&w.finish()).is_err());
    }

    #[test]
    fn upload_roundtrip_carries_the_wire_image() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let params = spec.init_params(&mut Rng::new(11));
        let wire = encode_upload(&ChannelMask::full(&spec), &params, &spec);
        let env = UploadEnvelope {
            slot: 6,
            loss: 1.25,
            uploaded: wire.payload_bytes(),
            m_n: 100.0,
            wire,
            residual: None,
            full_broadcast: true,
            timing: RoundTiming { t_down: 0.5, t_cmp: 1.5, t_up: 2.0 },
        };
        let payload = UploadFrame::encode(9, &env);
        let up = UploadFrame::decode(&payload).unwrap();
        let (round, back) = up.into_envelope();
        assert_eq!(round, 9);
        assert_eq!(back.slot, 6);
        assert_eq!(back.loss, 1.25);
        assert_eq!(back.uploaded, env.uploaded);
        assert_eq!(back.m_n, 100.0);
        assert!(back.full_broadcast);
        assert!(back.residual.is_none());
        assert_eq!(back.timing.total(), env.timing.total());
        assert_eq!(back.wire.to_bytes(), env.wire.to_bytes());
        // A flipped payload byte breaks the wire checksum.
        let mut bad = payload.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        assert!(UploadFrame::decode(&bad).is_err());
    }

    #[test]
    fn ack_roundtrip() {
        let a = AckFrame::decode(&AckFrame::encode_parts(3, 12)).unwrap();
        assert_eq!(a, AckFrame { round: 3, slot: 12 });
    }
}
