//! Figure/table regeneration harness — one entry per figure of the
//! paper's evaluation section (see DESIGN.md §5 for the full index).
//!
//! `feddd figure <id> [--preset smoke|table4] [--out results/] [...]`
//! runs the experiment matrix behind that figure and writes
//! `results/<id>.json` plus a human-readable summary to stdout. Absolute
//! numbers come from the synthetic substrate (DESIGN.md §3); the *shape*
//! of each comparison (who wins, by what factor, where crossovers fall)
//! is the reproduction target.

use std::path::Path;

use crate::config::ExpConfig;
use crate::coordinator::run_experiment;
use crate::metrics::RunResult;
use crate::util::json::{self, Json};

/// All known figure ids.
pub const FIGURES: &[&str] = &[
    "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
    "fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17", "fig18",
    "fig19", "fig20", "fig21", "ablation_alloc",
];

/// The paper's dataset → model pairing (Table 2).
pub fn model_for_dataset(ds: &str) -> &'static str {
    match ds {
        "mnist" => "mlp",
        "fmnist" => "cnn1",
        _ => "cnn2",
    }
}

/// Stable learning rate per dataset (deeper models need smaller steps on
/// the synthetic substrate; divergence shows as NaN losses).
pub fn lr_for_dataset(ds: &str) -> f32 {
    match ds {
        "mnist" => 0.05,
        "fmnist" => 0.05,
        _ => 0.02,
    }
}

fn series_json(label: &str, r: &RunResult) -> Json {
    let mix = r.encoding_mix();
    Json::obj(vec![
        ("label", Json::s(label)),
        ("result", r.to_json()),
        (
            "final_accuracy",
            Json::Num(r.final_accuracy().unwrap_or(0.0)),
        ),
        // realized communication volume: encoded wire bytes (headers +
        // indices + values) vs the raw masked payload, plus the layer
        // encoding mix — per-round columns live in result.rounds.
        ("total_uploaded_bytes", Json::Num(r.total_uploaded() as f64)),
        ("total_wire_bytes", Json::Num(r.total_wire_bytes() as f64)),
        ("enc_dense", Json::Num(mix.dense as f64)),
        ("enc_bitmap", Json::Num(mix.bitmap as f64)),
        ("enc_coo", Json::Num(mix.coo as f64)),
    ])
}

fn write_out(out_dir: &Path, id: &str, body: Json) -> anyhow::Result<()> {
    let path = out_dir.join(format!("{id}.json"));
    json::to_file(&path, &body)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn schemes() -> [&'static str; 4] {
    ["fedavg", "fedcs", "oort", "feddd"]
}

/// Run one figure. `base` carries the preset + CLI overrides.
pub fn run_figure(id: &str, base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    match id {
        "fig2" => fig2(base, out_dir),
        "fig3" => fig3(base, out_dir),
        "fig4" => accuracy_grid("fig4", base, "iid", false, out_dir),
        "fig5" => accuracy_grid("fig5", base, "noniid_a", false, out_dir),
        "fig6" => accuracy_grid("fig6", base, "noniid_b", false, out_dir),
        "fig7" => t2a_grid("fig7", base, false, out_dir),
        "fig8" => fig8(base, out_dir),
        "fig9" => accuracy_hetero("fig9", base, out_dir),
        "fig10" => t2a_grid("fig10", base, true, out_dir),
        "fig11" => selection_grid("fig11", base, "mnist", out_dir),
        "fig12" => selection_grid("fig12", base, "fmnist", out_dir),
        "fig13" => selection_grid("fig13", base, "cifar10", out_dir),
        "fig14" => fig14(base, out_dir),
        "fig15" => fig15(base, out_dir),
        "fig16" => budget_sweep("fig16", base, false, out_dir),
        "fig17" => budget_sweep("fig17", base, true, out_dir),
        "fig18" => fig18(base, out_dir),
        "fig19" => h_sweep("fig19", base, false, out_dir),
        "fig20" => h_sweep("fig20", base, true, out_dir),
        "fig21" => fig21(base, out_dir),
        "ablation_alloc" => ablation_alloc(base, out_dir),
        _ => anyhow::bail!("unknown figure {id:?} (known: {FIGURES:?})"),
    }
}

// ---------------------------------------------------------------------
// Fig. 2 — test accuracy of a class vs its proportion in the train set
// (motivates the min(C·dis, 1) shape of the contribution term).
// ---------------------------------------------------------------------
fn fig2(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let proportions = [0.01, 0.02, 0.05, 0.08, 0.1, 0.15, 0.2, 0.3, 0.5];
    let mut series = Vec::new();
    for ds_name in ["mnist", "fmnist", "cifar10"] {
        let mut points = Vec::new();
        for &p in &proportions {
            // single "client" trained centrally; class 0 has proportion p.
            let mut cfg = base.clone();
            cfg.dataset = ds_name.into();
            cfg.model = model_for_dataset(ds_name).into();
            cfg.lr = lr_for_dataset(ds_name);
            cfg.scheme = "fedavg".into();
            cfg.partition = "iid".into();
            cfg.n_clients = 1;
            cfg.rounds = base.rounds.min(20);
            cfg.local_steps = 8;
            cfg.train_per_client = base.train_per_client * 4;
            cfg.h = 1;
            // class 0 scaled so its share is ~p of the total.
            let others = 9.0f64;
            cfg.rare_classes = vec![0];
            cfg.rare_ratio = (p * others / (1.0 - p)).min(1.0_f64);
            let r = run_experiment(cfg)?;
            let class0 = r
                .evals
                .last()
                .map(|e| e.per_class_accuracy[0])
                .unwrap_or(0.0);
            println!("fig2 {ds_name} p={p:.2} class0_acc={class0:.3}");
            points.push(Json::obj(vec![
                ("proportion", Json::Num(p)),
                ("class0_accuracy", Json::Num(class0)),
            ]));
        }
        series.push(Json::obj(vec![
            ("dataset", Json::s(ds_name)),
            ("points", Json::Arr(points)),
        ]));
    }
    write_out(
        out_dir,
        "fig2",
        Json::obj(vec![("figure", Json::s("fig2")), ("series", Json::Arr(series))]),
    )
}

// ---------------------------------------------------------------------
// Fig. 3 — training loss vs model size (5 hetero-a models, IID).
// ---------------------------------------------------------------------
fn fig3(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let mut series = Vec::new();
    for i in 1..=5 {
        let mut cfg = base.clone();
        cfg.dataset = "cifar10".into();
        cfg.model = "het_a".into();
        cfg.lr = lr_for_dataset("cifar10");
        cfg.width_pct = 25;
        cfg.partition = "iid".into();
        cfg.scheme = "fedavg".into();
        cfg.n_clients = 5;
        // every client runs sub-model i: override via a homogeneous run of
        // the specific sub-model family member.
        // Run the specific sub-model homogeneously (validate() accepts
        // concrete sub-model names for exactly this use).
        cfg.model = format!("het_a_{i}");
        cfg.rounds = base.rounds * 2;
        cfg.local_steps = base.local_steps.max(4);
        let r = run_experiment(cfg)?;
        let losses: Vec<f64> = r.rounds.iter().map(|x| x.train_loss).collect();
        println!(
            "fig3 het_a_{i}: first loss {:.3} last loss {:.3}",
            losses.first().unwrap_or(&0.0),
            losses.last().unwrap_or(&0.0)
        );
        series.push(Json::obj(vec![
            ("model", Json::s(&format!("het_a_{i}"))),
            ("train_loss", Json::arr_f64(&losses)),
        ]));
    }
    write_out(
        out_dir,
        "fig3",
        Json::obj(vec![("figure", Json::s("fig3")), ("series", Json::Arr(series))]),
    )
}

// ---------------------------------------------------------------------
// Figs. 4–6 — accuracy curves, model-homogeneous, one per partition.
// ---------------------------------------------------------------------
fn accuracy_grid(
    id: &str,
    base: &ExpConfig,
    partition: &str,
    _hetero: bool,
    out_dir: &Path,
) -> anyhow::Result<()> {
    let mut series = Vec::new();
    for ds in ["mnist", "fmnist", "cifar10"] {
        for scheme in schemes() {
            let mut cfg = base.clone();
            cfg.dataset = ds.into();
            cfg.model = model_for_dataset(ds).into();
            cfg.lr = lr_for_dataset(ds);
            cfg.partition = partition.into();
            cfg.scheme = scheme.into();
            let r = run_experiment(cfg)?;
            println!(
                "{id} {ds} {scheme}: final acc {:.3} (vt {:.0}s)",
                r.final_accuracy().unwrap_or(0.0),
                r.evals.last().map(|e| e.v_time).unwrap_or(0.0)
            );
            series.push(series_json(&format!("{ds}/{scheme}"), &r));
        }
    }
    write_out(
        out_dir,
        id,
        Json::obj(vec![
            ("figure", Json::s(id)),
            ("partition", Json::s(partition)),
            ("series", Json::Arr(series)),
        ]),
    )
}

// ---------------------------------------------------------------------
// Fig. 7 / Fig. 10 — time-to-accuracy, normalized to FedAvg.
// ---------------------------------------------------------------------
fn t2a_grid(id: &str, base: &ExpConfig, hetero: bool, out_dir: &Path) -> anyhow::Result<()> {
    let datasets: Vec<(&str, &str)> = if hetero {
        vec![("cifar10", "het_a"), ("cifar10", "het_b")]
    } else {
        vec![("mnist", "mlp"), ("fmnist", "cnn1"), ("cifar10", "cnn2")]
    };
    let mut rows = Vec::new();
    for (ds, model) in datasets {
        for partition in ["iid", "noniid_b"] {
            // Reference: FedAvg reaches its best accuracy; targets are
            // fractions of that.
            let mut runs = Vec::new();
            for scheme in schemes() {
                let mut cfg = base.clone();
                cfg.dataset = ds.into();
                cfg.model = model.into();
                cfg.lr = lr_for_dataset(ds);
                if hetero {
                    cfg.width_pct = 25;
                    cfg.rounds = base.rounds * 2;
                    cfg.local_steps = base.local_steps.max(4);
                }
                cfg.partition = partition.into();
                cfg.scheme = scheme.into();
                runs.push((scheme, run_experiment(cfg)?));
            }
            let fedavg_best = runs
                .iter()
                .find(|(s, _)| *s == "fedavg")
                .map(|(_, r)| r.best_accuracy())
                .unwrap_or(0.0);
            for frac in [0.8, 0.9, 0.95] {
                let target = fedavg_best * frac;
                let t_ref = runs
                    .iter()
                    .find(|(s, _)| *s == "fedavg")
                    .and_then(|(_, r)| r.time_to_accuracy(target));
                let mut row = vec![
                    ("dataset", Json::s(ds)),
                    ("model", Json::s(model)),
                    ("partition", Json::s(partition)),
                    ("target", Json::Num(target)),
                ];
                for (scheme, r) in &runs {
                    let t2a = r.time_to_accuracy(target);
                    let norm = match (t2a, t_ref) {
                        (Some(t), Some(tr)) if tr > 0.0 => Json::Num(t / tr),
                        (Some(_), None) => Json::Num(0.0),
                        _ => Json::Null,
                    };
                    row.push((*scheme, norm));
                }
                println!(
                    "{id} {ds}/{model}/{partition} target={target:.3}: {}",
                    runs.iter()
                        .map(|(s, r)| format!(
                            "{s}={:?}",
                            r.time_to_accuracy(target).map(|t| (t * 10.0).round() / 10.0)
                        ))
                        .collect::<Vec<_>>()
                        .join(" ")
                );
                rows.push(Json::obj(row));
            }
        }
    }
    write_out(
        out_dir,
        id,
        Json::obj(vec![("figure", Json::s(id)), ("rows", Json::Arr(rows))]),
    )
}

// ---------------------------------------------------------------------
// Fig. 8 — testbed (Table 5 fleet), CNN2/CIFAR10, three partitions.
// ---------------------------------------------------------------------
fn fig8(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let mut series = Vec::new();
    for partition in ["iid", "noniid_a", "noniid_b"] {
        for scheme in schemes() {
            let mut cfg = ExpConfig::testbed();
            cfg.seed = base.seed;
            cfg.rounds = base.rounds;
            cfg.train_per_client = base.train_per_client;
            cfg.test_n = base.test_n;
            cfg.partition = partition.into();
            cfg.scheme = scheme.into();
            let r = run_experiment(cfg)?;
            println!(
                "fig8 {partition} {scheme}: final acc {:.3}",
                r.final_accuracy().unwrap_or(0.0)
            );
            series.push(series_json(&format!("{partition}/{scheme}"), &r));
        }
    }
    write_out(
        out_dir,
        "fig8",
        Json::obj(vec![("figure", Json::s("fig8")), ("series", Json::Arr(series))]),
    )
}

// ---------------------------------------------------------------------
// Fig. 9 — accuracy curves under model-heterogeneous a/b settings.
// ---------------------------------------------------------------------
fn accuracy_hetero(id: &str, base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let mut series = Vec::new();
    for fam in ["het_a", "het_b"] {
        for partition in ["iid", "noniid_a", "noniid_b"] {
            for scheme in schemes() {
                let mut cfg = base.clone();
                cfg.dataset = "cifar10".into();
                cfg.model = fam.into();
                cfg.lr = lr_for_dataset("cifar10");
                cfg.width_pct = 25;
                cfg.rounds = base.rounds * 2;
                cfg.local_steps = base.local_steps.max(4);
                cfg.partition = partition.into();
                cfg.scheme = scheme.into();
                let r = run_experiment(cfg)?;
                println!(
                    "{id} {fam}/{partition}/{scheme}: final acc {:.3}",
                    r.final_accuracy().unwrap_or(0.0)
                );
                series.push(series_json(&format!("{fam}/{partition}/{scheme}"), &r));
            }
        }
    }
    write_out(
        out_dir,
        id,
        Json::obj(vec![("figure", Json::s(id)), ("series", Json::Arr(series))]),
    )
}

// ---------------------------------------------------------------------
// Figs. 11–13 — FedDD selection-policy variants per dataset.
// ---------------------------------------------------------------------
fn selection_grid(id: &str, base: &ExpConfig, ds: &str, out_dir: &Path) -> anyhow::Result<()> {
    let mut series = Vec::new();
    for partition in ["iid", "noniid_a", "noniid_b"] {
        for sel in ["importance", "random", "max", "delta", "ordered"] {
            let mut cfg = base.clone();
            cfg.dataset = ds.into();
            cfg.model = model_for_dataset(ds).into();
            cfg.lr = lr_for_dataset(ds);
            cfg.partition = partition.into();
            cfg.scheme = "feddd".into();
            cfg.selection = sel.into();
            let r = run_experiment(cfg)?;
            println!(
                "{id} {partition} {sel}: final acc {:.3}",
                r.final_accuracy().unwrap_or(0.0)
            );
            series.push(series_json(&format!("{partition}/{sel}"), &r));
        }
    }
    write_out(
        out_dir,
        id,
        Json::obj(vec![
            ("figure", Json::s(id)),
            ("dataset", Json::s(ds)),
            ("series", Json::Arr(series)),
        ]),
    )
}

// Fig. 14 — selection variants on the testbed fleet.
fn fig14(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let mut series = Vec::new();
    for partition in ["iid", "noniid_a", "noniid_b"] {
        for sel in ["importance", "random", "max", "delta", "ordered"] {
            let mut cfg = ExpConfig::testbed();
            cfg.seed = base.seed;
            cfg.rounds = base.rounds;
            cfg.train_per_client = base.train_per_client;
            cfg.test_n = base.test_n;
            cfg.partition = partition.into();
            cfg.scheme = "feddd".into();
            cfg.selection = sel.into();
            let r = run_experiment(cfg)?;
            println!(
                "fig14 {partition} {sel}: final acc {:.3}",
                r.final_accuracy().unwrap_or(0.0)
            );
            series.push(series_json(&format!("{partition}/{sel}"), &r));
        }
    }
    write_out(
        out_dir,
        "fig14",
        Json::obj(vec![("figure", Json::s("fig14")), ("series", Json::Arr(series))]),
    )
}

// Fig. 15 — selection variants, hetero-a/b.
fn fig15(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let mut series = Vec::new();
    for fam in ["het_a", "het_b"] {
        for partition in ["iid", "noniid_a", "noniid_b"] {
            for sel in ["importance", "random", "max", "delta", "ordered"] {
                let mut cfg = base.clone();
                cfg.dataset = "cifar10".into();
                cfg.model = fam.into();
                cfg.lr = lr_for_dataset("cifar10");
                cfg.width_pct = 25;
                cfg.rounds = base.rounds * 2;
                cfg.local_steps = base.local_steps.max(4);
                cfg.partition = partition.into();
                cfg.scheme = "feddd".into();
                cfg.selection = sel.into();
                let r = run_experiment(cfg)?;
                println!(
                    "fig15 {fam}/{partition}/{sel}: final acc {:.3}",
                    r.final_accuracy().unwrap_or(0.0)
                );
                series.push(series_json(&format!("{fam}/{partition}/{sel}"), &r));
            }
        }
    }
    write_out(
        out_dir,
        "fig15",
        Json::obj(vec![("figure", Json::s("fig15")), ("series", Json::Arr(series))]),
    )
}

// ---------------------------------------------------------------------
// Figs. 16/17 — robustness to the communication budget A_server.
// ---------------------------------------------------------------------
fn budget_sweep(id: &str, base: &ExpConfig, hetero: bool, out_dir: &Path) -> anyhow::Result<()> {
    let budgets = [0.8, 0.6, 0.4, 0.2];
    let mut rows = Vec::new();
    let combos: Vec<(&str, &str, &str)> = if hetero {
        vec![
            ("cifar10", "het_a", "noniid_a"),
            ("cifar10", "het_b", "noniid_a"),
        ]
    } else {
        vec![
            ("mnist", "mlp", "noniid_a"),
            ("cifar10", "cnn2", "noniid_a"),
        ]
    };
    for (ds, model, partition) in combos {
        for scheme in ["feddd", "fedcs", "oort"] {
            let mut accs = Vec::new();
            for &a in &budgets {
                let mut cfg = base.clone();
                cfg.dataset = ds.into();
                cfg.model = model.into();
                cfg.lr = lr_for_dataset(ds);
                if hetero {
                    cfg.width_pct = 25;
                    cfg.rounds = base.rounds * 2;
                    cfg.local_steps = base.local_steps.max(4);
                }
                cfg.partition = partition.into();
                cfg.scheme = scheme.into();
                cfg.a_server = a;
                cfg.d_max = cfg.d_max.max(1.0 - a + 0.05).min(0.95);
                let r = run_experiment(cfg)?;
                accs.push(r.final_accuracy().unwrap_or(0.0));
            }
            println!("{id} {ds}/{model} {scheme}: acc@budgets {budgets:?} = {accs:?}");
            rows.push(Json::obj(vec![
                ("dataset", Json::s(ds)),
                ("model", Json::s(model)),
                ("scheme", Json::s(scheme)),
                ("budgets", Json::arr_f64(&budgets)),
                ("final_accuracy", Json::arr_f64(&accs)),
            ]));
        }
    }
    write_out(
        out_dir,
        id,
        Json::obj(vec![("figure", Json::s(id)), ("rows", Json::Arr(rows))]),
    )
}

// Fig. 18 — penalty factor δ sweep (Non-IID-a, hetero).
fn fig18(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let deltas = [0.0, 0.1, 1.0, 10.0];
    let mut rows = Vec::new();
    for &delta in &deltas {
        let mut cfg = base.clone();
        cfg.dataset = "cifar10".into();
        cfg.model = "het_a".into();
        cfg.lr = lr_for_dataset("cifar10");
        cfg.width_pct = 25;
        cfg.rounds = base.rounds * 2;
        cfg.local_steps = base.local_steps.max(4);
        cfg.partition = "noniid_a".into();
        cfg.scheme = "feddd".into();
        cfg.delta = delta;
        let r = run_experiment(cfg)?;
        let acc = r.final_accuracy().unwrap_or(0.0);
        let vt = r.evals.last().map(|e| e.v_time).unwrap_or(0.0);
        println!("fig18 delta={delta}: final acc {acc:.3} vtime {vt:.0}s");
        rows.push(Json::obj(vec![
            ("delta", Json::Num(delta)),
            ("final_accuracy", Json::Num(acc)),
            ("virtual_time", Json::Num(vt)),
            ("result", r.to_json()),
        ]));
    }
    write_out(
        out_dir,
        "fig18",
        Json::obj(vec![("figure", Json::s("fig18")), ("rows", Json::Arr(rows))]),
    )
}

// Figs. 19/20 — broadcast period h sweep.
fn h_sweep(id: &str, base: &ExpConfig, hetero: bool, out_dir: &Path) -> anyhow::Result<()> {
    let hs = [1usize, 5, 10, 20];
    let mut rows = Vec::new();
    for &h in &hs {
        let mut cfg = base.clone();
        cfg.dataset = "cifar10".into();
        cfg.lr = lr_for_dataset("cifar10");
        if hetero {
            cfg.model = "het_a".into();
            cfg.width_pct = 25;
            cfg.rounds = base.rounds * 2;
            cfg.local_steps = base.local_steps.max(4);
            cfg.partition = "noniid_a".into();
        } else {
            cfg.model = "cnn2".into();
            cfg.partition = "iid".into();
        }
        cfg.scheme = "feddd".into();
        cfg.h = h;
        let r = run_experiment(cfg)?;
        let acc = r.final_accuracy().unwrap_or(0.0);
        println!("{id} h={h}: final acc {acc:.3}");
        rows.push(Json::obj(vec![
            ("h", Json::Num(h as f64)),
            ("final_accuracy", Json::Num(acc)),
            ("result", r.to_json()),
        ]));
    }
    write_out(
        out_dir,
        id,
        Json::obj(vec![("figure", Json::s(id)), ("rows", Json::Arr(rows))]),
    )
}

// ---------------------------------------------------------------------
// Ablation (DESIGN.md §5): Eq. 16/17 optimized allocation vs uniform
// dropout D_n = 1 − A_server. Isolates the value of the allocator under
// system heterogeneity: uniform dropout leaves the straggler at full
// delay penalty, so its T2A should be strictly worse.
// ---------------------------------------------------------------------
fn ablation_alloc(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for alloc in ["optimal", "uniform"] {
        let mut cfg = base.clone();
        cfg.scheme = "feddd".into();
        cfg.alloc = alloc.into();
        cfg.partition = "noniid_a".into();
        let r = run_experiment(cfg)?;
        let acc = r.final_accuracy().unwrap_or(0.0);
        let vt = r.evals.last().map(|e| e.v_time).unwrap_or(0.0);
        println!("ablation_alloc {alloc}: final acc {acc:.3} vtime {vt:.0}s");
        rows.push(Json::obj(vec![
            ("alloc", Json::s(alloc)),
            ("final_accuracy", Json::Num(acc)),
            ("virtual_time", Json::Num(vt)),
            ("result", r.to_json()),
        ]));
    }
    write_out(
        out_dir,
        "ablation_alloc",
        Json::obj(vec![
            ("figure", Json::s("ablation_alloc")),
            ("rows", Json::Arr(rows)),
        ]),
    )
}

// ---------------------------------------------------------------------
// Fig. 21 — per-class accuracy on the class-imbalanced dataset, A=20%.
// ---------------------------------------------------------------------
fn fig21(base: &ExpConfig, out_dir: &Path) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for ds in ["mnist", "fmnist", "cifar10"] {
        for scheme in schemes() {
            let mut cfg = base.clone();
            cfg.dataset = ds.into();
            cfg.model = model_for_dataset(ds).into();
            cfg.partition = "noniid_b".into();
            cfg.scheme = scheme.into();
            cfg.rare_classes = vec![0, 1, 2];
            cfg.rare_ratio = 0.4;
            cfg.a_server = 0.2;
            cfg.d_max = 0.85;
            let r = run_experiment(cfg)?;
            let pca = r
                .evals
                .last()
                .map(|e| e.per_class_accuracy.clone())
                .unwrap_or_default();
            let rare_mean = r.rare_class_accuracy(&[0, 1, 2]).unwrap_or(0.0);
            println!(
                "fig21 {ds} {scheme}: rare-class acc {rare_mean:.3}, overall {:.3}",
                r.final_accuracy().unwrap_or(0.0)
            );
            rows.push(Json::obj(vec![
                ("dataset", Json::s(ds)),
                ("scheme", Json::s(scheme)),
                ("per_class_accuracy", Json::arr_f64(&pca)),
                ("rare_mean", Json::Num(rare_mean)),
                (
                    "overall",
                    Json::Num(r.final_accuracy().unwrap_or(0.0)),
                ),
            ]));
        }
    }
    write_out(
        out_dir,
        "fig21",
        Json::obj(vec![("figure", Json::s("fig21")), ("rows", Json::Arr(rows))]),
    )
}
