//! `feddd` — the FedDD coordinator CLI.
//!
//! Subcommands:
//! * `train  [--preset smoke|table4|testbed] [--<cfg-key> v ...]` — run one
//!   experiment and write results JSON.
//! * `figure <figN|all> [--preset ...] [--out results/]` — regenerate a
//!   paper figure's experiment matrix (DESIGN.md §5).
//! * `matrix [--tier smoke] | --list | --compare A.json B.json` — run the
//!   scenario-matrix harness (`feddd::scenarios`, docs/SCENARIOS.md) and
//!   emit per-cell reports, or diff two reports regression-only.
//! * `serve [--listen host:port ...]` — bind the coordinator on a real
//!   socket and run the experiment against remote `agent` processes.
//! * `agent --connect host:port [--slot_start N] [--slot_count N]` — host
//!   a slot range of the fleet for a `serve` coordinator.
//! * `inspect models|config|manifest` — print registry/config/manifest.
//! * `help`

use std::path::Path;

use feddd::cli::Args;
use feddd::config::ExpConfig;
use feddd::coordinator::{run_experiment, FedRun};
use feddd::transport::{run_agent, AgentOpts, BoundServer, ServeOpts};
use feddd::figures;
use feddd::model::{all_model_names, ModelSpec};
use feddd::scenarios;
use feddd::util::json;
use feddd::util::logging;

const HELP: &str = "\
feddd — FedDD (differential parameter dropout FL) coordinator

USAGE:
  feddd train   [--preset smoke|table4|testbed|fleet] [--key value ...] [--out results/]
  feddd figure  <fig2..fig21|all> [--preset ...] [--key value ...] [--out results/]
  feddd matrix  [--tier smoke|small|medium] [--scenarios a,b] [--schemes x,y]
                [--seeds 17,18] [--label name] [--workers N] [--out reports/]
  feddd matrix  --list
  feddd matrix  --compare BASELINE.json CURRENT.json [--tol_acc 0.01] [--out diff.md]
  feddd serve   [--preset ...] [--key value ...] [--listen 127.0.0.1:7070] [--out results/]
  feddd agent   --connect HOST:PORT [--slot_start N] [--slot_count N]
                [--workers N] [--artifacts_dir DIR]
  feddd inspect models|config|manifest [--preset ...]
  feddd help

Config keys (see `feddd inspect config`): seed dataset partition model
width_pct n_clients rounds local_steps batch lr scheme selection d_max
a_server delta h train_per_client test_n fleet eval_every agg_backend
rare_classes rare_ratio artifacts_dir oort_alpha alloc workers
round_mode quorum deadline_s staleness_beta codec value_plane
plane_error data_mode snapshot_ring_cap trace trace_period_s
churn_rate listen max_conns ingest_queue fd_rate afd_ema.

`--scheme feddd|fedavg|fedcs|oort|fed_dropout|afd` picks the federated
scheme. `fed_dropout` is Caldas-style random federated dropout: every
client gets the same server-chosen rate `--fd_rate` (default 0.5; 0
reproduces fedavg byte-for-byte) with masks drawn at dispatch. `afd` is
Adaptive Federated Dropout: the server ranks units by an activation-score
EMA (decay `--afd_ema`, default 0.9) and anneals the rate on loss
plateaus; afd keeps server-resident mask state, so it cannot run in
serve mode.

`--value_plane f32|f16|i8|auto` picks the wire value plane for uploads
(README §Codec): `auto` chooses the smallest plane per layer whose
realized quantization error stays within `--plane_error` (relative to
the layer's max |value|, default 0.005). The downlink echo is always
full-precision f32.

`--workers N` fans the per-client round phases (training, mask selection,
sharded aggregation) over N threads (0 = one per core); results are
bitwise-identical for every worker count.

`--round_mode semi_async` replaces the synchronous barrier with
event-driven rounds: the server closes a round once `--quorum` (fraction
of in-flight uploads, default 0.7) arrivals are in or `--deadline_s`
elapses; stragglers stay in flight and fold into a later round with the
`--staleness_beta` discount (1+s)^-beta. `--round_mode sync` (default)
is bitwise-identical to the classic engine.

`feddd matrix` crosses the registered scenarios (docs/SCENARIOS.md) with
schemes x seeds at a tier, writes one-line-per-cell JSON + a Markdown
table per run into --out (default reports/) and regenerates
reports/INDEX.md; `--compare` prints only regressions between two
reports and exits non-zero when any are found (mirrored in CI by
ci/matrix_diff.py). Every cell is deterministic: same spec, same bytes.

Fleet size is the `--n_clients` knob; client state is virtualized
(snapshot ring + sparse residuals, DESIGN.md Fleet-Virtualization), so
10k-50k-client fleets fit in memory. `--preset fleet` gives the
large-fleet defaults (10k clients, width-25% MLP, h=1); e.g.
`feddd train --preset fleet --n_clients 50000`.

`feddd serve` binds the coordinator on `--listen` (port 0 = ephemeral;
the resolved address is written to <out>/serve_addr.txt before
accepting) and waits until connecting agents cover slots 0..n_clients
exactly; `feddd agent` connects, receives the config over the wire,
rebuilds a bitwise replica of the run and trains its slot range
(`--slot_count` omitted = everything from `--slot_start` up). A
loopback serve reproduces the in-process run's losses, accuracies and
wire bytes exactly (DESIGN.md §Serve). `--max_conns` caps connection
attempts; `--ingest_queue` bounds the server's decoded-upload buffer —
a slow server blocks agents through TCP backpressure instead of
buffering without limit. Serve requires snapshot_ring_cap = 0.

Artifacts must be built first (`make artifacts`), or use a native-exec
manifest (runtime::write_native_manifest) for FC models without XLA.
";

fn main() {
    logging::init();
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        "train" => cmd_train(&args),
        "figure" => cmd_figure(&args),
        "matrix" => cmd_matrix(&args),
        "serve" => cmd_serve(&args),
        "agent" => cmd_agent(&args),
        "inspect" => cmd_inspect(&args),
        other => anyhow::bail!("unknown command {other:?}\n{HELP}"),
    }
}

fn artifacts_default(cfg: &mut ExpConfig) {
    if cfg.artifacts_dir == "artifacts" {
        cfg.artifacts_dir = feddd::runtime::default_artifacts_dir()
            .to_string_lossy()
            .into_owned();
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let (mut cfg, leftover) = args.to_config()?;
    anyhow::ensure!(leftover.is_empty(), "unknown options: {leftover:?}");
    artifacts_default(&mut cfg);
    cfg.validate()?;
    let out_dir = Path::new(args.get_or("out", "results"));
    std::fs::create_dir_all(out_dir)?;
    log::info!("config: {}", cfg.to_json().to_string_compact());
    let result = run_experiment(cfg.clone())?;
    println!(
        "final accuracy: {:.4}  (virtual time {:.1}s, wall {:.1}s)",
        result.final_accuracy().unwrap_or(0.0),
        result.evals.last().map(|e| e.v_time).unwrap_or(0.0),
        result.wall_seconds
    );
    let body = feddd::util::json::Json::obj(vec![
        ("config", cfg.to_json()),
        ("result", result.to_json()),
    ]);
    let path = out_dir.join("train.json");
    json::to_file(&path, &body)?;
    std::fs::write(out_dir.join("train_curve.csv"), result.eval_csv())?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: feddd figure <figN|all>"))?
        .clone();
    let (mut cfg, leftover) = args.to_config()?;
    anyhow::ensure!(leftover.is_empty(), "unknown options: {leftover:?}");
    artifacts_default(&mut cfg);
    let out_dir = Path::new(args.get_or("out", "results")).to_path_buf();
    std::fs::create_dir_all(&out_dir)?;
    if id == "all" {
        for f in figures::FIGURES {
            log::info!("=== running {f} ===");
            figures::run_figure(f, &cfg, &out_dir)?;
        }
        Ok(())
    } else {
        figures::run_figure(&id, &cfg, &out_dir)
    }
}

fn cmd_matrix(args: &Args) -> anyhow::Result<()> {
    if args.has_flag("list") {
        println!("{:<16} {:<28} title", "scenario", "claim");
        for sc in scenarios::registry() {
            println!("{:<16} {:<28} {}", sc.name, sc.claim, sc.title);
        }
        println!("\nschemes: {}   tiers: smoke small medium", scenarios::MATRIX_SCHEMES.join(" "));
        println!("catalogue: docs/SCENARIOS.md");
        return Ok(());
    }
    if let Some(baseline) = args.get("compare") {
        let current = args
            .positionals
            .first()
            .ok_or_else(|| anyhow::anyhow!("usage: feddd matrix --compare BASE.json CUR.json"))?;
        let base = scenarios::MatrixReport::load(Path::new(baseline))?;
        let cur = scenarios::MatrixReport::load(Path::new(current))?;
        let tol_acc = args.get_f64("tol_acc")?.unwrap_or(0.01);
        let diff = scenarios::compare_reports(&base, &cur, tol_acc);
        let md = diff.markdown();
        print!("{md}");
        if let Some(out) = args.get("out") {
            std::fs::write(out, &md)?;
            println!("wrote {out}");
        }
        anyhow::ensure!(
            !diff.has_failures(),
            "{} matrix regression(s) vs {}",
            diff.regressions.len(),
            baseline
        );
        return Ok(());
    }
    let tier = scenarios::Tier::by_name(args.get_or("tier", "smoke"))?;
    let split = |key: &str| -> Vec<String> {
        let mut out = Vec::new();
        if let Some(v) = args.get(key) {
            for part in v.split(',') {
                if !part.is_empty() {
                    out.push(part.to_string());
                }
            }
        }
        out
    };
    let mut seeds: Vec<u64> = Vec::new();
    if let Some(v) = args.get("seeds") {
        for part in v.split(',') {
            if part.is_empty() {
                continue;
            }
            let seed = part.parse().map_err(|e| anyhow::anyhow!("--seeds: {e}"))?;
            seeds.push(seed);
        }
    } else {
        seeds.push(17);
    }
    let out_dir = Path::new(args.get_or("out", "reports")).to_path_buf();
    // The smoke matrix must run on hosts with no compiled artifacts: fall
    // back to an on-the-fly native-exec manifest for the FC stack.
    let mut artifacts_dir = feddd::runtime::default_artifacts_dir();
    if !artifacts_dir.join("manifest.json").exists() {
        let native = out_dir.join("native_artifacts");
        feddd::runtime::write_native_manifest(&native, &[("mlp", 1.0), ("mlp", 0.25)], 16, 64)?;
        log::info!("no compiled artifacts; using native manifest at {}", native.display());
        artifacts_dir = native;
    }
    let spec = scenarios::MatrixSpec {
        tier,
        label: args.get_or("label", "local").to_string(),
        scenarios: split("scenarios"),
        schemes: split("schemes"),
        seeds,
        workers: args.get_usize("workers")?.unwrap_or(1),
        artifacts_dir: artifacts_dir.to_string_lossy().into_owned(),
    };
    let report = scenarios::run_matrix(&spec)?;
    let json_path = scenarios::write_report(&out_dir, &report)?;
    println!(
        "wrote {} ({} cells) + Markdown + {}",
        json_path.display(),
        report.cells.len(),
        out_dir.join("INDEX.md").display()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let (mut cfg, leftover) = args.to_config()?;
    anyhow::ensure!(leftover.is_empty(), "unknown options: {leftover:?}");
    artifacts_default(&mut cfg);
    let out_dir = Path::new(args.get_or("out", "results")).to_path_buf();
    std::fs::create_dir_all(&out_dir)?;
    // Like the smoke matrix, serve must run on hosts with no compiled
    // artifacts: fall back to an on-the-fly native-exec manifest.
    if !Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        let native = out_dir.join("native_artifacts");
        feddd::runtime::write_native_manifest(&native, &[("mlp", 1.0), ("mlp", 0.25)], 16, 64)?;
        log::info!("no compiled artifacts; using native manifest at {}", native.display());
        cfg.artifacts_dir = native.to_string_lossy().into_owned();
    }
    anyhow::ensure!(
        cfg.snapshot_ring_cap == 0,
        "serve mode requires snapshot_ring_cap = 0 (uncapped); remote replicas rebase from \
         close notes and must never evict"
    );
    cfg.validate()?;
    anyhow::ensure!(
        feddd::baselines::scheme_by_name(&cfg.scheme)?.agent_masks(&cfg).is_some(),
        "scheme {:?} keeps server-resident dispatch-mask state and cannot run in serve mode",
        cfg.scheme
    );
    let opts = ServeOpts::from_config(&cfg);
    let bound = BoundServer::bind(&opts)?;
    // Publish the resolved address *before* accepting, so scripts that
    // asked for an ephemeral port (`--listen 127.0.0.1:0`) can find us.
    let addr_path = out_dir.join("serve_addr.txt");
    std::fs::write(&addr_path, format!("{}\n", bound.local_addr))?;
    println!("listening on {} ({})", bound.local_addr, addr_path.display());
    log::info!("config: {}", cfg.to_json().to_string_compact());
    let coordinator = bound.accept_agents(&opts, &cfg)?;
    let mut run = FedRun::with_transport(cfg.clone(), Box::new(coordinator))?;
    let result = run.run()?;
    run.shutdown_transport()?;
    println!(
        "final accuracy: {:.4}  (virtual time {:.1}s, wall {:.1}s)",
        result.final_accuracy().unwrap_or(0.0),
        result.evals.last().map(|e| e.v_time).unwrap_or(0.0),
        result.wall_seconds
    );
    let body = feddd::util::json::Json::obj(vec![
        ("config", cfg.to_json()),
        ("result", result.to_json()),
    ]);
    let path = out_dir.join("serve.json");
    json::to_file(&path, &body)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_agent(args: &Args) -> anyhow::Result<()> {
    let connect = args.get("connect").ok_or_else(|| {
        anyhow::anyhow!("usage: feddd agent --connect HOST:PORT [--slot_start N] [--slot_count N]")
    })?;
    let mut overrides = Vec::new();
    for key in ["workers", "artifacts_dir"] {
        if let Some(v) = args.get(key) {
            overrides.push((key.to_string(), v.to_string()));
        }
    }
    let opts = AgentOpts {
        connect: connect.to_string(),
        slot_start: args.get_usize("slot_start")?.unwrap_or(0),
        slot_count: args.get_usize("slot_count")?,
        overrides,
    };
    let report = run_agent(&opts)?;
    println!(
        "agent done: slots {}..{}, {} rounds, {} uploads ({} bytes), {} acks",
        report.slot_start,
        report.slot_start + report.slot_count,
        report.rounds,
        report.uploads,
        report.upload_bytes,
        report.acks
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let what = args.positionals.first().map(|s| s.as_str()).unwrap_or("models");
    match what {
        "models" => {
            println!(
                "{:<10} {:>6} {:>12} {:>10}  layers",
                "model", "width", "params", "bytes"
            );
            for name in all_model_names() {
                for width in [1.0, 0.25] {
                    let s = ModelSpec::get(&name, width)?;
                    println!(
                        "{:<10} {:>5}% {:>12} {:>10}  {:?}",
                        name,
                        (width * 100.0) as u32,
                        s.param_count(),
                        s.size_bytes(),
                        s.unit_counts()
                    );
                }
            }
            Ok(())
        }
        "config" => {
            let (cfg, _) = args.to_config()?;
            println!("{}", cfg.to_json().to_string_pretty());
            Ok(())
        }
        "manifest" => {
            let dir = feddd::runtime::default_artifacts_dir();
            let m = feddd::runtime::Manifest::load(&dir)?;
            println!(
                "{} artifacts in {} (train_batch={}, eval_batch={}, chunk={})",
                m.artifacts.len(),
                dir.display(),
                m.train_batch,
                m.eval_batch,
                m.kernel_chunk
            );
            let mut names: Vec<&String> = m.artifacts.keys().collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
            Ok(())
        }
        other => anyhow::bail!("unknown inspect target {other:?}"),
    }
}
