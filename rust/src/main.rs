//! `feddd` — the FedDD coordinator CLI.
//!
//! Subcommands:
//! * `train  [--preset smoke|table4|testbed] [--<cfg-key> v ...]` — run one
//!   experiment and write results JSON.
//! * `figure <figN|all> [--preset ...] [--out results/]` — regenerate a
//!   paper figure's experiment matrix (DESIGN.md §5).
//! * `inspect models|config|manifest` — print registry/config/manifest.
//! * `help`

use std::path::Path;

use feddd::cli::Args;
use feddd::config::ExpConfig;
use feddd::coordinator::run_experiment;
use feddd::figures;
use feddd::model::{all_model_names, ModelSpec};
use feddd::util::json;
use feddd::util::logging;

const HELP: &str = "\
feddd — FedDD (differential parameter dropout FL) coordinator

USAGE:
  feddd train   [--preset smoke|table4|testbed|fleet] [--key value ...] [--out results/]
  feddd figure  <fig2..fig21|all> [--preset ...] [--key value ...] [--out results/]
  feddd inspect models|config|manifest [--preset ...]
  feddd help

Config keys (see `feddd inspect config`): seed dataset partition model
width_pct n_clients rounds local_steps batch lr scheme selection d_max
a_server delta h train_per_client test_n fleet eval_every agg_backend
rare_classes rare_ratio artifacts_dir oort_alpha alloc workers
round_mode quorum deadline_s staleness_beta.

`--workers N` fans the per-client round phases (training, mask selection,
sharded aggregation) over N threads (0 = one per core); results are
bitwise-identical for every worker count.

`--round_mode semi_async` replaces the synchronous barrier with
event-driven rounds: the server closes a round once `--quorum` (fraction
of in-flight uploads, default 0.7) arrivals are in or `--deadline_s`
elapses; stragglers stay in flight and fold into a later round with the
`--staleness_beta` discount (1+s)^-beta. `--round_mode sync` (default)
is bitwise-identical to the classic engine.

Fleet size is the `--n_clients` knob; client state is virtualized
(snapshot ring + sparse residuals, DESIGN.md Fleet-Virtualization), so
10k-50k-client fleets fit in memory. `--preset fleet` gives the
large-fleet defaults (10k clients, width-25% MLP, h=1); e.g.
`feddd train --preset fleet --n_clients 50000`.

Artifacts must be built first (`make artifacts`), or use a native-exec
manifest (runtime::write_native_manifest) for FC models without XLA.
";

fn main() {
    logging::init();
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        "train" => cmd_train(&args),
        "figure" => cmd_figure(&args),
        "inspect" => cmd_inspect(&args),
        other => anyhow::bail!("unknown command {other:?}\n{HELP}"),
    }
}

fn artifacts_default(cfg: &mut ExpConfig) {
    if cfg.artifacts_dir == "artifacts" {
        cfg.artifacts_dir = feddd::runtime::default_artifacts_dir()
            .to_string_lossy()
            .into_owned();
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let (mut cfg, leftover) = args.to_config()?;
    anyhow::ensure!(leftover.is_empty(), "unknown options: {leftover:?}");
    artifacts_default(&mut cfg);
    cfg.validate()?;
    let out_dir = Path::new(args.get_or("out", "results"));
    std::fs::create_dir_all(out_dir)?;
    log::info!("config: {}", cfg.to_json().to_string_compact());
    let result = run_experiment(cfg.clone())?;
    println!(
        "final accuracy: {:.4}  (virtual time {:.1}s, wall {:.1}s)",
        result.final_accuracy().unwrap_or(0.0),
        result.evals.last().map(|e| e.v_time).unwrap_or(0.0),
        result.wall_seconds
    );
    let body = feddd::util::json::Json::obj(vec![
        ("config", cfg.to_json()),
        ("result", result.to_json()),
    ]);
    let path = out_dir.join("train.json");
    json::to_file(&path, &body)?;
    std::fs::write(out_dir.join("train_curve.csv"), result.eval_csv())?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: feddd figure <figN|all>"))?
        .clone();
    let (mut cfg, leftover) = args.to_config()?;
    anyhow::ensure!(leftover.is_empty(), "unknown options: {leftover:?}");
    artifacts_default(&mut cfg);
    let out_dir = Path::new(args.get_or("out", "results")).to_path_buf();
    std::fs::create_dir_all(&out_dir)?;
    if id == "all" {
        for f in figures::FIGURES {
            log::info!("=== running {f} ===");
            figures::run_figure(f, &cfg, &out_dir)?;
        }
        Ok(())
    } else {
        figures::run_figure(&id, &cfg, &out_dir)
    }
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let what = args.positionals.first().map(|s| s.as_str()).unwrap_or("models");
    match what {
        "models" => {
            println!(
                "{:<10} {:>6} {:>12} {:>10}  layers",
                "model", "width", "params", "bytes"
            );
            for name in all_model_names() {
                for width in [1.0, 0.25] {
                    let s = ModelSpec::get(&name, width)?;
                    println!(
                        "{:<10} {:>5}% {:>12} {:>10}  {:?}",
                        name,
                        (width * 100.0) as u32,
                        s.param_count(),
                        s.size_bytes(),
                        s.unit_counts()
                    );
                }
            }
            Ok(())
        }
        "config" => {
            let (cfg, _) = args.to_config()?;
            println!("{}", cfg.to_json().to_string_pretty());
            Ok(())
        }
        "manifest" => {
            let dir = feddd::runtime::default_artifacts_dir();
            let m = feddd::runtime::Manifest::load(&dir)?;
            println!(
                "{} artifacts in {} (train_batch={}, eval_batch={}, chunk={})",
                m.artifacts.len(),
                dir.display(),
                m.train_batch,
                m.eval_batch,
                m.kernel_chunk
            );
            let mut names: Vec<&String> = m.artifacts.keys().collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
            Ok(())
        }
        other => anyhow::bail!("unknown inspect target {other:?}"),
    }
}
