//! Minimal JSON substrate (parser + serializer) — serde is not available
//! offline. Used for the artifact manifest, experiment configs and result
//! files. Supports the full JSON grammar; numbers are kept as f64 and
//! strings are UTF-8.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers (error messages carry the key).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not an array"))
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn s(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    // ---------------- serialize ----------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------- parse ----------------

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            out.push(cp);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        // self.pos points at 'u'
        self.pos += 1;
        let hex = |p: &Self, i: usize| -> Result<u32, JsonError> {
            let b = p.bytes.get(p.pos + i).ok_or_else(|| p.err("short \\u"))?;
            (*b as char).to_digit(16).ok_or_else(|| p.err("bad hex"))
        };
        let mut code =
            (hex(self, 0)? << 12) | (hex(self, 1)? << 8) | (hex(self, 2)? << 4) | hex(self, 3)?;
        self.pos += 4;
        // surrogate pair
        if (0xD800..0xDC00).contains(&code)
            && self.bytes.get(self.pos) == Some(&b'\\')
            && self.bytes.get(self.pos + 1) == Some(&b'u')
        {
            self.pos += 2;
            let low =
                (hex(self, 0)? << 12) | (hex(self, 1)? << 8) | (hex(self, 2)? << 4) | hex(self, 3)?;
            self.pos += 4;
            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
        }
        char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Read + parse a JSON file.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
}

/// Serialize + write a JSON file (pretty).
pub fn to_file(path: &std::path::Path, v: &Json) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().req_str("b").unwrap(), "x");
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x": [1.5, -2, true, null, "s\n"], "y": {"z": []}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn large_numeric_array_roundtrip() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64 * 0.25).collect();
        let v = Json::arr_f64(&xs);
        let v2 = parse(&v.to_string_compact()).unwrap();
        let back: Vec<f64> = v2.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(xs, back);
    }
}
