//! Deterministic PRNG substrate: SplitMix64 seeding + Xoshiro256++ core,
//! with the distributions the simulator needs (uniform, normal, integer
//! ranges, categorical, Dirichlet, permutations).
//!
//! Everything in the repository that consumes randomness takes an explicit
//! `&mut Rng`, so every experiment is reproducible from a single `u64`
//! seed recorded in its config.

/// SplitMix64 — used to expand a user seed into the Xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate (Box–Muller produces pairs).
    cached_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (e.g. one per client) — splits by
    /// hashing the label into a fresh seed.
    pub fn split(&mut self, label: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Symmetric Dirichlet(alpha) over k categories (via Gamma(alpha,1)
    /// Marsaglia–Tsang sampling).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut g {
            *v /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// `k` distinct indices sampled from 0..n (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut v: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            v.swap(i, j);
        }
        v.truncate(k);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(6);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(8);
        let v = r.choose_k(100, 30);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(v.iter().all(|&x| x < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.split(0);
        let mut b = root.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
