//! Descriptive statistics used by the bench harness and the metrics
//! reporters: mean/std, percentiles, min/max, linear regression (for
//! throughput fits) and a Welford online accumulator.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary over a sample.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::default();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Ordinary least squares y = a + b*x; returns (a, b).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx == 0.0 || n < 2.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p90 > 89.0 && s.p90 < 92.0);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile_sorted(&[7.0], 0.99), 7.0);
    }
}
