//! Persistent worker pool (tokio is unavailable offline; the FL round's
//! per-client work is CPU-bound and synchronous anyway).
//!
//! Workers are **long-lived**: [`ThreadPool::new`] spawns them once (one
//! OS thread per worker, each behind its own channel lane) and every
//! [`ThreadPool::scoped_map`] call dispatches borrowed drain-loop jobs to
//! the same threads. The engine creates one pool per `FedRun`, so a run's
//! total thread-spawn count is O(`workers`) — **not** O(micro-batches),
//! as the old spawn-per-call implementation was — which is what makes
//! per-worker scratch reuse possible at all: a worker thread's
//! thread-local arenas (`coordinator::scratch`, the native executor's
//! buffer pool) survive across micro-batches and rounds because the
//! thread itself does. The process-wide [`total_threads_spawned`] counter
//! lets tests and benches assert the spawn invariant
//! (`rust/tests/pool_determinism.rs`, `rust/benches/round.rs`).
//!
//! # How borrowed jobs run on `'static` threads
//!
//! `scoped_map`'s per-call state (item queue, the job closure, the panic
//! slot) lives on the caller's stack frame; the drain-loop closures sent
//! to the workers borrow it, with the lifetime erased at the dispatch
//! boundary. Soundness rests on a completion barrier: every drain loop
//! owns a clone of the result `Sender`, dropped only after the loop has
//! finished touching the borrows, and the caller returns only once the
//! result channel has **disconnected** — i.e. once every dispatched
//! closure has run to completion (or been caught panicking) on every
//! lane. No worker can touch the borrowed frame after `scoped_map`
//! returns. Panics inside jobs are caught on the worker (which stays
//! alive for the next call) and resumed on the caller.
//!
//! On the 1-core CI image the pool degrades gracefully to sequential
//! execution on the caller thread (no worker threads are spawned at all
//! for `workers <= 1`); the coordinator's structure (one logical task per
//! client) is what we are encoding.

use std::any::Any;
use std::cell::Cell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Mutex;
use std::thread::{self, JoinHandle};

/// OS threads ever spawned by any [`ThreadPool`] in this process. The
/// observable half of the spawn invariant: after a pool is constructed,
/// dispatching work must not move this counter.
static SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// Process-wide total of pool-spawned OS threads (the `SPAWNED` counter).
pub fn total_threads_spawned() -> usize {
    SPAWNED.load(Ordering::SeqCst)
}

/// A lifetime-erased job as it travels down a worker lane.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Erase the borrow lifetime of a dispatch closure so it can travel down
/// a worker lane — the single unsafe operation of this module.
///
/// # Safety
///
/// The caller must not return (or unwind past its frame) before every
/// closure it dispatched has finished executing on its worker:
/// `scoped_map` blocks until its result channel *disconnects*, which
/// happens only once every drain loop has dropped its `Sender` clone —
/// its last touch of the borrowed frame; `broadcast` blocks until every
/// lane has acknowledged, and the acknowledgement is sent only after `f`
/// returned (or its unwind was caught). After those points workers only
/// drop the closure box, whose drop glue touches no borrowed data.
unsafe fn erase_job_lifetime(job: Box<dyn FnOnce() + Send + '_>) -> Job {
    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
}

/// What a caught job panic carries back to the caller.
type PanicPayload = Box<dyn Any + Send + 'static>;

thread_local! {
    /// Set once on pool worker threads. A `scoped_map` issued *from a
    /// worker* (a nested call) runs sequentially inline instead of
    /// dispatching: its own lane is busy running the outer job, so
    /// waiting on it would deadlock.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

pub struct ThreadPool {
    workers: usize,
    /// One dispatch lane per worker thread (kept in spawn order; dropping
    /// a lane's `Sender` is the worker's shutdown signal). Behind a
    /// `Mutex` so the pool stays `Sync` (`mpsc::Sender` is not): each
    /// call locks only to enqueue its jobs, and concurrent calls simply
    /// interleave on the lanes' FIFO queues.
    lanes: Mutex<Vec<Sender<Job>>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// `workers = 0` ⇒ available_parallelism. Spawns the worker threads
    /// immediately (none for `workers <= 1`, which runs sequentially on
    /// the caller); they live until the pool is dropped.
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        let mut lanes = Vec::new();
        let mut handles = Vec::new();
        if workers > 1 {
            for i in 0..workers {
                let (tx, rx) = mpsc::channel::<Job>();
                let handle = thread::Builder::new()
                    .name(format!("feddd-worker-{i}"))
                    .spawn(move || {
                        IN_WORKER.with(|w| w.set(true));
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn pool worker");
                SPAWNED.fetch_add(1, Ordering::SeqCst);
                lanes.push(tx);
                handles.push(handle);
            }
        }
        ThreadPool { workers, lanes: Mutex::new(lanes), handles }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Worker threads this pool owns (0 when sequential) — a pool's whole
    /// spawn budget; no call spawns anything further.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Apply `f` to every item (in parallel across up to `workers`
    /// persistent threads), returning outputs in input order. Panics in
    /// jobs are propagated to the caller; the workers survive them.
    pub fn scoped_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let n_lanes = self.handles.len().min(n);
        if n_lanes <= 1 || IN_WORKER.with(|w| w.get()) {
            return items.into_iter().map(f).collect();
        }
        // Dynamic work queue: scheduling order is nondeterministic, but
        // outputs are index-ordered and each job is a pure function of its
        // item, so results never depend on the schedule. All of this state
        // is borrowed by the dispatched drain loops and outlives them (see
        // the module docs for the completion argument).
        let queue: Mutex<Vec<(usize, T)>> =
            Mutex::new(items.into_iter().enumerate().collect());
        let first_panic: Mutex<Option<PanicPayload>> = Mutex::new(None);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let queue_ref = &queue;
        let f_ref = &f;
        let panic_ref = &first_panic;
        let lanes = self.lanes.lock().unwrap_or_else(|e| e.into_inner());
        for lane in &lanes[..n_lanes] {
            let tx = tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let drained = panic::catch_unwind(AssertUnwindSafe(|| loop {
                    let item = queue_ref.lock().unwrap_or_else(|e| e.into_inner()).pop();
                    match item {
                        Some((i, x)) => {
                            let r = f_ref(x);
                            if tx.send((i, r)).is_err() {
                                return;
                            }
                        }
                        None => return,
                    }
                }));
                if let Err(payload) = drained {
                    let mut slot = panic_ref.lock().unwrap_or_else(|e| e.into_inner());
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                // `tx` (this drain loop's Sender clone) drops here — the
                // caller's result channel disconnects only after every
                // dispatched closure has reached this point.
            });
            // SAFETY: the closure borrows `queue`/`f`/`first_panic` from
            // this stack frame, and this call returns only once the
            // result channel below has disconnected — the completion
            // barrier `erase_job_lifetime` requires.
            let job: Job = unsafe { erase_job_lifetime(job) };
            lane.send(job).expect("pool worker thread is gone");
        }
        drop(lanes);
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        // Channel disconnected ⇒ every drain loop completed ⇒ safe to
        // unwind or return; borrowed state is no longer touched.
        if let Some(p) = first_panic.lock().unwrap_or_else(|e| e.into_inner()).take() {
            panic::resume_unwind(p);
        }
        out.into_iter()
            .map(|o| o.expect("worker died before producing result"))
            .collect()
    }

    /// [`Self::scoped_map`] over fallible jobs: runs every job, then
    /// returns the outputs or the first error *in input order* (not in
    /// completion order), keeping error reporting deterministic under
    /// parallelism.
    pub fn scoped_try_map<T, R, F>(&self, items: Vec<T>, f: F) -> anyhow::Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> anyhow::Result<R> + Sync,
    {
        self.scoped_map(items, f).into_iter().collect()
    }

    /// Run `f` once on the calling thread and once on **every** worker
    /// thread, returning after all invocations completed. Each lane gets
    /// its own job, so no worker is skipped however fast the others
    /// drain. Used to maintain per-worker thread-local state — e.g. the
    /// scratch-arena sentinel poisoning in the determinism battery
    /// (`FedRun::poison_worker_scratch`). A panic inside `f` on a worker
    /// is swallowed; the worker stays alive.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn() + Sync,
    {
        f();
        if self.handles.is_empty() {
            return;
        }
        let f_ref = &f;
        let (tx, rx) = mpsc::channel::<()>();
        let lanes = self.lanes.lock().unwrap_or_else(|e| e.into_inner());
        for lane in lanes.iter() {
            let tx = tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let _ = panic::catch_unwind(AssertUnwindSafe(f_ref));
                let _ = tx.send(());
            });
            // SAFETY: the closure borrows `f` from this stack frame, and
            // this call returns only after every lane has acknowledged —
            // the completion barrier `erase_job_lifetime` requires.
            let job: Job = unsafe { erase_job_lifetime(job) };
            lane.send(job).expect("pool worker thread is gone");
        }
        drop(lanes);
        drop(tx);
        for _ in 0..self.handles.len() {
            let _ = rx.recv();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Dropping the lanes disconnects every worker's receiver; each
        // worker finishes its in-flight job (there are none outside an
        // active call) and exits. Join so no worker outlives the pool.
        self.lanes.lock().unwrap_or_else(|e| e.into_inner()).clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// The spawn-counter assertions read the process-wide [`SPAWNED`]
    /// counter, so tests in this module (the only lib-unit tests that
    /// construct pools) must not construct pools concurrently.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn serial() -> std::sync::MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn map_preserves_order() {
        let _g = serial();
        let pool = ThreadPool::new(4);
        let out = pool.scoped_map((0..100).collect(), |x: usize| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_fallback() {
        let _g = serial();
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 0, "sequential pool must spawn nothing");
        let out = pool.scoped_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let _g = serial();
        let pool = ThreadPool::new(4);
        let out: Vec<i32> = pool.scoped_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn borrows_environment() {
        let _g = serial();
        let pool = ThreadPool::new(3);
        let offset = 10usize;
        let out = pool.scoped_map(vec![1usize, 2, 3], |x| x + offset);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn try_map_reports_first_error_by_input_order() {
        let _g = serial();
        let pool = ThreadPool::new(4);
        let out = pool.scoped_try_map((0..100).collect::<Vec<usize>>(), |x| {
            if x % 7 == 3 {
                Err(anyhow::anyhow!("bad item {x}"))
            } else {
                Ok(x * 2)
            }
        });
        // First failing input is 3 regardless of which worker hit it first.
        assert_eq!(out.unwrap_err().to_string(), "bad item 3");
        let ok = pool.scoped_try_map(vec![1usize, 2], |x| Ok(x + 1)).unwrap();
        assert_eq!(ok, vec![2, 3]);
    }

    #[test]
    fn mutable_items_fan_out() {
        // The round engine hands each worker a disjoint `&mut` client.
        let _g = serial();
        let pool = ThreadPool::new(4);
        let mut state = vec![0u64; 16];
        let items: Vec<(usize, &mut u64)> = state.iter_mut().enumerate().collect();
        pool.scoped_map(items, |(i, slot)| {
            *slot = (i as u64) * 3;
        });
        for (i, v) in state.iter().enumerate() {
            assert_eq!(*v, (i as u64) * 3);
        }
    }

    #[test]
    fn workers_are_persistent_across_calls() {
        // The tentpole invariant: construction spawns O(workers) threads,
        // and no amount of scoped_map traffic spawns any more — the old
        // implementation spawned min(workers, n) per call.
        let _g = serial();
        let before = total_threads_spawned();
        let pool = ThreadPool::new(3);
        assert_eq!(total_threads_spawned() - before, 3);
        assert_eq!(pool.threads(), 3);
        for round in 0..50usize {
            let out = pool.scoped_map((0..8).collect(), |x: usize| x + round);
            assert_eq!(out, (0..8).map(|x| x + round).collect::<Vec<_>>());
        }
        assert_eq!(
            total_threads_spawned() - before,
            3,
            "dispatching 50 calls must spawn zero additional threads"
        );
    }

    #[test]
    fn worker_thread_locals_survive_across_calls() {
        // Per-worker scratch reuse rests on this: a worker's thread-local
        // state written during one scoped_map call is still there in the
        // next call, because the OS thread is the same.
        thread_local! {
            static CALLS: Cell<usize> = const { Cell::new(0) };
        }
        let _g = serial();
        let pool = ThreadPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..20 {
            pool.scoped_map((0..6).collect::<Vec<usize>>(), |_| {
                CALLS.with(|c| c.set(c.get() + 1));
            });
        }
        // Every job ran on one of the two persistent workers, so the two
        // thread-locals must account for all 120 jobs.
        pool.broadcast(|| {
            total.fetch_add(CALLS.with(|c| c.get()), Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 20 * 6);
    }

    #[test]
    fn broadcast_reaches_every_worker_and_the_caller() {
        let _g = serial();
        let pool = ThreadPool::new(4);
        let count = AtomicUsize::new(0);
        pool.broadcast(|| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5, "4 workers + the caller");
        let seq = ThreadPool::new(1);
        let count = AtomicUsize::new(0);
        seq.broadcast(|| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1, "sequential pool: caller only");
    }

    #[test]
    fn job_panics_propagate_and_the_pool_survives() {
        let _g = serial();
        let pool = ThreadPool::new(3);
        let err = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_map((0..10).collect::<Vec<usize>>(), |x| {
                if x == 4 {
                    panic!("boom on {x}");
                }
                x
            })
        }))
        .expect_err("job panic must propagate to the caller");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string payload>".into());
        assert!(msg.contains("boom on 4"), "unexpected payload {msg:?}");
        // Workers caught the unwind and are still serving.
        let out = pool.scoped_map(vec![1, 2, 3], |x| x * 10);
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn nested_scoped_map_runs_inline_without_deadlock() {
        // A job that calls back into the pool must not wait on its own
        // busy lane: nested calls degrade to sequential execution.
        let _g = serial();
        let pool = ThreadPool::new(2);
        let out = pool.scoped_map(vec![10usize, 20], |base| {
            pool.scoped_map((0..3).collect::<Vec<usize>>(), |x| x + base)
                .into_iter()
                .sum::<usize>()
        });
        assert_eq!(out, vec![33, 63]);
    }
}
