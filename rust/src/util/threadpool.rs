//! Minimal scoped threadpool (tokio is unavailable offline; the FL
//! round's per-client work is CPU-bound and synchronous anyway).
//!
//! `ThreadPool::scoped_map` fans a job per item out to worker threads and
//! collects results in input order. On the 1-core CI image this degrades
//! gracefully to near-sequential execution; the coordinator's structure
//! (one logical task per client) is what we are encoding.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// `workers = 0` ⇒ available_parallelism.
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        ThreadPool { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to every item (in parallel across up to `workers`
    /// threads), returning outputs in input order. Panics in jobs are
    /// propagated.
    pub fn scoped_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let nworkers = self.workers.min(n);
        if nworkers <= 1 {
            return items.into_iter().map(f).collect();
        }
        // Dynamic work queue: scheduling order is nondeterministic, but
        // outputs are index-ordered and each job is a pure function of its
        // item, so results never depend on the schedule.
        let queue = Arc::new(Mutex::new(
            items.into_iter().enumerate().collect::<Vec<_>>(),
        ));
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let fref = &f;
        thread::scope(|scope| {
            for _ in 0..nworkers {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                scope.spawn(move || loop {
                    let item = queue.lock().unwrap().pop();
                    match item {
                        Some((i, x)) => {
                            let r = fref(x);
                            if tx.send((i, r)).is_err() {
                                return;
                            }
                        }
                        None => return,
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
            for (i, r) in rx {
                out[i] = Some(r);
            }
            out.into_iter()
                .map(|o| o.expect("worker died before producing result"))
                .collect()
        })
    }

    /// [`Self::scoped_map`] over fallible jobs: runs every job, then
    /// returns the outputs or the first error *in input order* (not in
    /// completion order), keeping error reporting deterministic under
    /// parallelism.
    pub fn scoped_try_map<T, R, F>(&self, items: Vec<T>, f: F) -> anyhow::Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> anyhow::Result<R> + Sync,
    {
        self.scoped_map(items, f).into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scoped_map((0..100).collect(), |x: usize| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_fallback() {
        let pool = ThreadPool::new(1);
        let out = pool.scoped_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let pool = ThreadPool::new(4);
        let out: Vec<i32> = pool.scoped_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn borrows_environment() {
        let pool = ThreadPool::new(3);
        let offset = 10usize;
        let out = pool.scoped_map(vec![1usize, 2, 3], |x| x + offset);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn try_map_reports_first_error_by_input_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scoped_try_map((0..100).collect::<Vec<usize>>(), |x| {
            if x % 7 == 3 {
                Err(anyhow::anyhow!("bad item {x}"))
            } else {
                Ok(x * 2)
            }
        });
        // First failing input is 3 regardless of which worker hit it first.
        assert_eq!(out.unwrap_err().to_string(), "bad item 3");
        let ok = pool.scoped_try_map(vec![1usize, 2], |x| Ok(x + 1)).unwrap();
        assert_eq!(ok, vec![2, 3]);
    }

    #[test]
    fn mutable_items_fan_out() {
        // The round engine hands each worker a disjoint `&mut` client.
        let pool = ThreadPool::new(4);
        let mut state = vec![0u64; 16];
        let items: Vec<(usize, &mut u64)> = state.iter_mut().enumerate().collect();
        pool.scoped_map(items, |(i, slot)| {
            *slot = (i as u64) * 3;
        });
        for (i, v) in state.iter().enumerate() {
            assert_eq!(*v, (i as u64) * 3);
        }
    }
}
