//! Minimal scoped threadpool (tokio is unavailable offline; the FL
//! round's per-client work is CPU-bound and synchronous anyway).
//!
//! `ThreadPool::scoped_map` fans a job per item out to worker threads and
//! collects results in input order. On the 1-core CI image this degrades
//! gracefully to near-sequential execution; the coordinator's structure
//! (one logical task per client) is what we are encoding.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// `workers = 0` ⇒ available_parallelism.
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        ThreadPool { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to every item (in parallel across up to `workers`
    /// threads), returning outputs in input order. Panics in jobs are
    /// propagated.
    pub fn scoped_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let nworkers = self.workers.min(n);
        if nworkers <= 1 {
            return items.into_iter().map(f).collect();
        }
        let queue = Arc::new(Mutex::new(
            items.into_iter().enumerate().collect::<Vec<_>>(),
        ));
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let fref = &f;
        thread::scope(|scope| {
            for _ in 0..nworkers {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                scope.spawn(move || loop {
                    let item = queue.lock().unwrap().pop();
                    match item {
                        Some((i, x)) => {
                            let r = fref(x);
                            if tx.send((i, r)).is_err() {
                                return;
                            }
                        }
                        None => return,
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
            for (i, r) in rx {
                out[i] = Some(r);
            }
            out.into_iter()
                .map(|o| o.expect("worker died before producing result"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scoped_map((0..100).collect(), |x: usize| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_fallback() {
        let pool = ThreadPool::new(1);
        let out = pool.scoped_map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let pool = ThreadPool::new(4);
        let out: Vec<i32> = pool.scoped_map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn borrows_environment() {
        let pool = ThreadPool::new(3);
        let offset = 10usize;
        let out = pool.scoped_map(vec![1usize, 2, 3], |x| x + offset);
        assert_eq!(out, vec![11, 12, 13]);
    }
}
