//! Tiny `log`-facade backend: leveled stderr logger with wall-clock
//! timestamps relative to process start (no chrono offline).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INIT: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    start: Instant,
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger once; level from `FEDDD_LOG` (error/warn/info/debug/
/// trace), default `info`. Safe to call multiple times.
pub fn init() {
    if INIT.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("FEDDD_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { start: Instant::now(), level }));
    let _ = log::set_logger(logger);
    log::set_max_level(match level {
        Level::Error => LevelFilter::Error,
        Level::Warn => LevelFilter::Warn,
        Level::Info => LevelFilter::Info,
        Level::Debug => LevelFilter::Debug,
        Level::Trace => LevelFilter::Trace,
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
