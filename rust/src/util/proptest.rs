//! Tiny property-testing driver (proptest/quickcheck unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs the closure `cases` times with
//! independent deterministic RNG streams; on failure it reports the case
//! seed so the exact instance can be replayed with `replay(seed, ...)`.
//! Set `FEDDD_PROPTEST_CASES` to scale case counts globally.

use crate::util::rng::Rng;

/// Run `body` over `cases` random cases. `body` returns `Err(msg)` to fail.
pub fn check<F>(name: &str, cases: usize, mut body: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = std::env::var("FEDDD_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(cases);
    let base = 0xFEDD_D000u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = body(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case.
pub fn replay<F>(seed: u64, mut body: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = body(&mut rng) {
        panic!("replay {seed:#x} failed: {msg}");
    }
}

/// Assert two f64 are close (absolute + relative tolerance).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

/// Assert slices are elementwise close.
pub fn close_slice(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        if (x - y).abs() > tol * scale {
            return Err(format!("at [{i}]: {x} !~ {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("addition commutes", 50, |rng| {
            let (a, b) = (rng.f64(), rng.f64());
            close(a + b, b + a, 1e-12)
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn close_slice_catches_mismatch() {
        assert!(close_slice(&[1.0, 2.0], &[1.0, 2.5], 1e-3).is_err());
        assert!(close_slice(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-3).is_ok());
    }
}
