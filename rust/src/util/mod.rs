//! Hand-rolled substrates (the offline image ships no crates.io access
//! beyond the `xla` closure — see DESIGN.md §3): PRNG, JSON, CLI-free
//! stats, logging, threadpool, bench harness and a property-test driver.

pub mod bench;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
