//! Criterion-free benchmark harness used by `rust/benches/*` (criterion
//! is unavailable offline). Warms up, runs timed iterations until a time
//! or count budget is reached, and prints a one-line summary per case
//! plus machine-readable JSON when `FEDDD_BENCH_JSON` is set.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

pub use std::hint::black_box;

pub struct Bencher {
    name: String,
    results: Vec<(String, Summary, f64)>, // (case, per-iter seconds, iters/sec)
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
}

impl Bencher {
    pub fn new(name: &str) -> Self {
        let quick = std::env::var("FEDDD_BENCH_QUICK").is_ok();
        Bencher {
            name: name.to_string(),
            results: Vec::new(),
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            budget: if quick { Duration::from_millis(300) } else { Duration::from_secs(2) },
            min_iters: 5,
        }
    }

    /// Time `f` (one logical iteration per call).
    pub fn bench<F: FnMut()>(&mut self, case: &str, mut f: F) {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup || warm_iters < 1 {
            bb(&mut f)();
            warm_iters += 1;
        }
        // Timed.
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < self.min_iters {
            let s = Instant::now();
            bb(&mut f)();
            samples.push(s.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        let summary = Summary::of(&samples);
        let ips = 1.0 / summary.mean;
        println!(
            "{:<44} {:>12} /iter   (p50 {:>10}, n={})  {:>12.1} it/s",
            format!("{}::{}", self.name, case),
            fmt_time(summary.mean),
            fmt_time(summary.p50),
            summary.n,
            ips
        );
        self.results.push((case.to_string(), summary, ips));
    }

    /// Report throughput in items/sec for a case processing `items` per iter.
    pub fn bench_throughput<F: FnMut()>(&mut self, case: &str, items: u64, mut f: F) {
        self.bench(case, &mut f);
        if let Some((_, s, _)) = self.results.last() {
            println!(
                "{:<44} {:>12.2} M items/s",
                format!("{}::{} throughput", self.name, case),
                items as f64 / s.mean / 1e6
            );
        }
    }

    /// Write JSON results if FEDDD_BENCH_JSON names a directory.
    pub fn finish(self) {
        if let Ok(dir) = std::env::var("FEDDD_BENCH_JSON") {
            let cases: Vec<Json> = self
                .results
                .iter()
                .map(|(c, s, ips)| {
                    Json::obj(vec![
                        ("case", Json::s(c)),
                        ("mean_s", Json::Num(s.mean)),
                        ("p50_s", Json::Num(s.p50)),
                        ("p90_s", Json::Num(s.p90)),
                        ("std_s", Json::Num(s.std)),
                        ("n", Json::Num(s.n as f64)),
                        ("iters_per_s", Json::Num(*ips)),
                    ])
                })
                .collect();
            let out = Json::obj(vec![
                ("bench", Json::s(&self.name)),
                ("cases", Json::Arr(cases)),
            ]);
            let path = std::path::Path::new(&dir).join(format!("{}.json", self.name));
            let _ = crate::util::json::to_file(&path, &out);
        }
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        std::env::set_var("FEDDD_BENCH_QUICK", "1");
        let mut b = Bencher::new("selftest");
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = acc.wrapping_add(black_box(1));
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].1.mean >= 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
