//! Criterion-free benchmark harness used by `rust/benches/*` (criterion
//! is unavailable offline). Warms up, runs timed iterations until a time
//! or count budget is reached, and prints a one-line summary per case
//! plus machine-readable JSON when `FEDDD_BENCH_JSON` names a directory:
//! each bench writes `BENCH_<name>.json` there (the repo's recorded perf
//! trajectory — CI uploads it as an artifact on every run). Cases and the
//! run itself can carry extra structured fields ([`Bencher::annotate`] /
//! [`Bencher::annotate_run`]), e.g. uploaded bytes per round or the
//! sync-vs-semi-async virtual-time comparison.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

pub use std::hint::black_box;

struct BenchCase {
    case: String,
    summary: Summary,
    iters_per_s: f64,
    extra: Vec<(String, Json)>,
}

pub struct Bencher {
    name: String,
    results: Vec<BenchCase>,
    run_extra: Vec<(String, Json)>,
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
}

impl Bencher {
    pub fn new(name: &str) -> Self {
        let quick = std::env::var("FEDDD_BENCH_QUICK").is_ok();
        Bencher {
            name: name.to_string(),
            results: Vec::new(),
            run_extra: Vec::new(),
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            budget: if quick { Duration::from_millis(300) } else { Duration::from_secs(2) },
            min_iters: 5,
        }
    }

    /// Attach an extra structured field to the most recent case (e.g.
    /// `uploaded_bytes`); a no-op before the first case.
    pub fn annotate(&mut self, key: &str, value: Json) {
        if let Some(last) = self.results.last_mut() {
            last.extra.push((key.to_string(), value));
        }
    }

    /// Attach an extra run-level field to the emitted JSON (e.g. the
    /// sync-vs-semi-async virtual-time gate numbers).
    pub fn annotate_run(&mut self, key: &str, value: Json) {
        self.run_extra.push((key.to_string(), value));
    }

    /// Time `f` (one logical iteration per call).
    pub fn bench<F: FnMut()>(&mut self, case: &str, mut f: F) {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup || warm_iters < 1 {
            bb(&mut f)();
            warm_iters += 1;
        }
        // Timed.
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < self.min_iters {
            let s = Instant::now();
            bb(&mut f)();
            samples.push(s.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        let summary = Summary::of(&samples);
        let ips = 1.0 / summary.mean;
        let label = format!("{}::{}", self.name, case);
        println!(
            "{label:<44} {:>12} /iter   (p50 {:>10}, n={})  {ips:>12.1} it/s",
            fmt_time(summary.mean),
            fmt_time(summary.p50),
            summary.n,
        );
        self.results.push(BenchCase {
            case: case.to_string(),
            summary,
            iters_per_s: ips,
            extra: Vec::new(),
        });
    }

    /// Report throughput in items/sec for a case processing `items` per iter.
    pub fn bench_throughput<F: FnMut()>(&mut self, case: &str, items: u64, mut f: F) {
        self.bench(case, &mut f);
        if let Some(last) = self.results.last() {
            let label = format!("{}::{} throughput", self.name, case);
            println!(
                "{label:<44} {:>12.2} M items/s",
                items as f64 / last.summary.mean / 1e6
            );
        }
    }

    /// Write `BENCH_<name>.json` if FEDDD_BENCH_JSON names a directory.
    pub fn finish(self) {
        if let Ok(dir) = std::env::var("FEDDD_BENCH_JSON") {
            self.finish_to_dir(std::path::Path::new(&dir));
        }
    }

    /// Write `BENCH_<name>.json` into `dir`.
    pub fn finish_to_dir(self, dir: &std::path::Path) {
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let s = &r.summary;
                let mut fields = vec![
                    ("case", Json::s(&r.case)),
                    ("mean_s", Json::Num(s.mean)),
                    ("mean_ns", Json::Num(s.mean * 1e9)),
                    ("p50_s", Json::Num(s.p50)),
                    ("p90_s", Json::Num(s.p90)),
                    ("std_s", Json::Num(s.std)),
                    ("n", Json::Num(s.n as f64)),
                    ("iters_per_s", Json::Num(r.iters_per_s)),
                ];
                for (k, v) in &r.extra {
                    fields.push((k.as_str(), v.clone()));
                }
                Json::obj(fields)
            })
            .collect();
        let mut fields = vec![("bench", Json::s(&self.name)), ("cases", Json::Arr(cases))];
        for (k, v) in &self.run_extra {
            fields.push((k.as_str(), v.clone()));
        }
        let out = Json::obj(fields);
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let _ = crate::util::json::to_file(&path, &out);
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Millisecond-budget bencher for tests. Built directly (same module)
    /// rather than via `FEDDD_BENCH_QUICK`: mutating process env from
    /// tests races other test threads' `std::env::var` calls.
    fn quick_bencher(name: &str) -> Bencher {
        Bencher {
            name: name.to_string(),
            results: Vec::new(),
            run_extra: Vec::new(),
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(20),
            min_iters: 2,
        }
    }

    #[test]
    fn bench_runs_and_summarizes() {
        let mut b = quick_bencher("selftest");
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = acc.wrapping_add(black_box(1));
        });
        b.annotate("uploaded_bytes", Json::Num(123.0));
        b.annotate_run("gate", Json::Bool(true));
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].summary.mean >= 0.0);
        assert_eq!(b.results[0].extra.len(), 1);
        assert_eq!(b.run_extra.len(), 1);
    }

    #[test]
    fn finish_writes_bench_json() {
        let dir = std::env::temp_dir().join(format!("feddd_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut b = quick_bencher("jsontest");
        b.bench("tiny", || {
            black_box(1 + 1);
        });
        b.annotate("uploaded_bytes", Json::Num(42.0));
        b.annotate_run("round_mode_gate", Json::s("ok"));
        b.finish_to_dir(std::path::Path::new(&dir));
        let path = dir.join("BENCH_jsontest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::parse(&text).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "jsontest");
        let cases = j.req_arr("cases").unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("uploaded_bytes").and_then(|v| v.as_f64()), Some(42.0));
        assert_eq!(j.get("round_mode_gate").and_then(|v| v.as_str()), Some("ok"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
