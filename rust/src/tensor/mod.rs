//! Dense f32 tensor substrate for the coordinator-side hot paths
//! (aggregation, masking, importance reductions) and for test oracles.
//!
//! Model *training* math runs in the AOT XLA executables; this module owns
//! the server-side parameter manipulation where the FedDD contribution
//! lives. The layout is always a flat `Vec<f32>` plus a shape, and model
//! parameter sets are `Vec<Tensor>` ordered exactly like the artifact
//! manifest's `params` list.

mod ops;

pub use ops::*;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn from_scalar(v: f32) -> Tensor {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Rows of a 2-D (or flattened-leading) tensor: number of elements in
    /// dims 1.. — used to slice per-unit parameter groups.
    pub fn row_size(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[1..].iter().product()
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

/// A full model parameter set (ordered like the manifest).
pub type Params = Vec<Tensor>;

/// Total element count of a parameter set.
pub fn params_numel(params: &[Tensor]) -> usize {
    params.iter().map(|t| t.numel()).sum()
}

/// Copy `src` into `dst` (same values and shapes as `dst = src.to_vec()`,
/// bit for bit), reusing `dst`'s allocations wherever the shapes already
/// match — the per-worker scratch-arena path, where `dst` is a reused
/// buffer whose previous contents are arbitrary. Every retained element
/// is fully overwritten; surplus elements are truncated.
pub fn copy_tensors_into(src: &[Tensor], dst: &mut Vec<Tensor>) {
    dst.truncate(src.len());
    for (i, t) in src.iter().enumerate() {
        match dst.get_mut(i) {
            Some(d) if d.shape() == t.shape() => d.data_mut().copy_from_slice(t.data()),
            Some(d) => *d = t.clone(),
            None => dst.push(t.clone()),
        }
    }
}

/// Deep elementwise binary op over parameter sets.
pub fn params_zip_mut(a: &mut [Tensor], b: &[Tensor], f: impl Fn(&mut f32, f32)) {
    assert_eq!(a.len(), b.len());
    for (ta, tb) in a.iter_mut().zip(b) {
        assert_eq!(ta.shape(), tb.shape());
        for (x, &y) in ta.data_mut().iter_mut().zip(tb.data()) {
            f(x, y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.row_size(), 3);
        assert_eq!(Tensor::zeros(vec![4]).data(), &[0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn params_zip() {
        let mut a = vec![Tensor::full(vec![3], 1.0)];
        let b = vec![Tensor::full(vec![3], 2.0)];
        params_zip_mut(&mut a, &b, |x, y| *x += y);
        assert_eq!(a[0].data(), &[3.0, 3.0, 3.0]);
    }

    #[test]
    fn l2_norm() {
        let t = Tensor::new(vec![2], vec![3.0, 4.0]);
        assert!((t.l2_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn copy_tensors_into_reuses_and_matches_clone() {
        let src = vec![
            Tensor::new(vec![2, 2], vec![1.0, -2.0, 3.5, f32::MIN_POSITIVE]),
            Tensor::full(vec![3], -0.0),
        ];
        // dirty destination: wrong shapes, wrong arity, poisoned values
        let mut dst = vec![
            Tensor::full(vec![2, 2], f32::NAN), // shape matches → reused
            Tensor::full(vec![5], f32::NAN),    // shape differs → rebuilt
            Tensor::full(vec![7], f32::NAN),    // surplus → truncated
        ];
        let reused_ptr = dst[0].data().as_ptr();
        copy_tensors_into(&src, &mut dst);
        assert_eq!(dst.len(), src.len());
        for (a, b) in src.iter().zip(&dst) {
            assert_eq!(a.shape(), b.shape());
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(dst[0].data().as_ptr(), reused_ptr, "matching shape must reuse");
        // growing from a short destination works too
        let mut short: Vec<Tensor> = Vec::new();
        copy_tensors_into(&src, &mut short);
        assert_eq!(short, src);
    }
}
