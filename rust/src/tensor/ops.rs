//! Elementwise / linear-algebra kernels on flat f32 slices. Written as
//! straight loops over exact-length slices so LLVM auto-vectorizes them
//! (the aggregation path is the L3 byte-moving hot loop — see
//! EXPERIMENTS.md §Perf).

use super::Tensor;

/// y += alpha * x
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y += alpha * (x ⊙ m)  — masked accumulate (Eq. 4 numerator).
pub fn axpy_masked(y: &mut [f32], alpha: f32, x: &[f32], m: &[f32]) {
    assert_eq!(y.len(), x.len());
    assert_eq!(y.len(), m.len());
    for ((yi, xi), mi) in y.iter_mut().zip(x).zip(m) {
        *yi += alpha * xi * mi;
    }
}

/// out[i] = if den[i] > 0 { num[i]/den[i] } else { prev[i] }  (Eq. 4).
pub fn masked_div(out: &mut [f32], num: &[f32], den: &[f32], prev: &[f32]) {
    assert!(out.len() == num.len() && num.len() == den.len() && den.len() == prev.len());
    for i in 0..out.len() {
        out[i] = if den[i] > 0.0 { num[i] / den[i] } else { prev[i] };
    }
}

/// w = w ⊙ m + v ⊙ (1 - m)   (Eq. 5 local merge; m is 0/1).
pub fn merge_masked(w: &mut [f32], v: &[f32], m: &[f32]) {
    assert!(w.len() == v.len() && v.len() == m.len());
    for i in 0..w.len() {
        w[i] = w[i] * m[i] + v[i] * (1.0 - m[i]);
    }
}

/// Importance elementwise scores |dw * (w+dw) / w_safe| (Eq. 20), the rust
/// mirror of the Pallas `importance_flat` kernel (cross-checked in the
/// runtime integration tests).
pub const IMPORTANCE_EPS: f32 = 1e-8;

pub fn importance_scores(out: &mut [f32], w: &[f32], dw: &[f32]) {
    assert!(out.len() == w.len() && w.len() == dw.len());
    for i in 0..out.len() {
        let wi = w[i];
        let sign = if wi >= 0.0 { 1.0 } else { -1.0 };
        let w_safe = if wi.abs() < IMPORTANCE_EPS { sign * IMPORTANCE_EPS } else { wi };
        out[i] = (dw[i] * (wi + dw[i]) / w_safe).abs();
    }
}

/// Naive-but-blocked matmul used only by test oracles and the synthetic
/// data generator (runtime matmuls run inside the XLA executables).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        for kk in 0..k {
            let aik = ad[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Sum of x ⊙ m (used by upload-size accounting invariants).
pub fn masked_count(m: &[f32]) -> usize {
    m.iter().filter(|&&x| x != 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }

    #[test]
    fn axpy_masked_skips_masked_out() {
        let mut y = vec![0.0, 0.0];
        axpy_masked(&mut y, 3.0, &[5.0, 7.0], &[1.0, 0.0]);
        assert_eq!(y, vec![15.0, 0.0]);
    }

    #[test]
    fn masked_div_zero_coverage_keeps_prev() {
        let mut out = vec![0.0; 3];
        masked_div(&mut out, &[6.0, 1.0, 9.0], &[2.0, 0.0, 3.0], &[9.9, 7.7, 9.9]);
        assert_eq!(out, vec![3.0, 7.7, 3.0]);
    }

    #[test]
    fn merge_masked_eq5() {
        // w = global⊙M + local⊙(1-M)
        let mut w = vec![10.0, 20.0]; // global values
        merge_masked(&mut w, &[1.0, 2.0], &[1.0, 0.0]);
        assert_eq!(w, vec![10.0, 2.0]);
    }

    #[test]
    fn importance_matches_formula() {
        let mut out = vec![0.0; 2];
        importance_scores(&mut out, &[2.0, 0.0], &[1.0, 1.0]);
        assert!((out[0] - (1.0f32 * 3.0 / 2.0)).abs() < 1e-6);
        assert!(out[1].is_finite()); // guarded division
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]);
        assert_eq!(matmul(&a, &b).data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn masked_count_counts() {
        assert_eq!(masked_count(&[0.0, 1.0, 2.0, 0.0]), 2);
    }
}
