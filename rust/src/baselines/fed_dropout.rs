//! Random Federated Dropout (`scheme = fed_dropout`), after Caldas et
//! al., "Expanding the Reach of Federated Learning by Reducing Client
//! Resource Requirements" (arXiv:1812.07210).
//!
//! Every round the server drops the *same* uniform fraction
//! `cfg.fd_rate` of units from every client's sub-model, choosing each
//! client's mask uniformly at random at dispatch. Both directions
//! shrink: the Eq. 5 download ships only the masked values on
//! non-broadcast rounds and the upload carries only the masked units —
//! charged from the realized masked bytes through the same
//! `downlink_bytes` / `wire_len()` paths FedDD uses.
//!
//! # Determinism / serve compatibility
//!
//! The per-(round, client) mask is a **pure function** of
//! `(cfg.seed, round, client)` via [`dispatch_mask_rng`] — mirroring the
//! `simnet::churn_drops` pure-hash precedent — so no engine or
//! per-client RNG state is consumed. That buys two properties at once:
//! with `fd_rate = 0` a run is byte-for-byte identical to `fedavg`
//! (every RNG stream in the system advances identically), and a
//! serve-mode agent recomputes the exact mask from the shared config
//! while the wire carries only `(slot, rate)` dispatch entries.

use crate::config::ExpConfig;
use crate::util::rng::Rng;

use super::{DispatchMasks, RoundCtx, RoundPlan, Scheme};

/// The dispatch-mask RNG for one (run, round, client): a SplitMix-style
/// hash of the triple seeding a fresh stream, so the draw mutates no
/// shared state (cf. `simnet::churn_drops`).
pub fn dispatch_mask_rng(seed: u64, round: u64, client: usize) -> Rng {
    Rng::new(
        seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(round.wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add((client as u64).wrapping_mul(0x94D0_49BB_1331_11EB)),
    )
}

/// Caldas-style random federated dropout: uniform server-chosen rate,
/// random server-chosen masks, everyone participates.
pub struct FedDropout;

impl Scheme for FedDropout {
    fn name(&self) -> &'static str {
        "fed_dropout"
    }

    /// Stateful like FedDD: masked downloads leave residual channels, so
    /// clients keep snapshot + residual state and ride the `cfg.h`
    /// broadcast schedule.
    fn stateful(&self) -> bool {
        true
    }

    /// The uniform rate applies from round 1 (unlike FedDD's D¹ = 0).
    fn reports_round_dropout(&self, _t: usize) -> bool {
        true
    }

    fn agent_masks(&self, _cfg: &ExpConfig) -> Option<DispatchMasks> {
        Some(DispatchMasks::Random)
    }

    fn plan_round(&mut self, _t: usize, ctx: &mut RoundCtx<'_>) -> anyhow::Result<RoundPlan> {
        let n = ctx.clients.len();
        Ok(RoundPlan {
            participants: (0..n).collect(),
            dropout: vec![ctx.cfg.fd_rate; n],
            masks: DispatchMasks::Random,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::selection::{keep_count, random_mask};

    #[test]
    fn dispatch_mask_rng_is_a_pure_function_of_the_triple() {
        let mut a = dispatch_mask_rng(17, 3, 5);
        let mut b = dispatch_mask_rng(17, 3, 5);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Any coordinate change moves the stream.
        for mut other in [
            dispatch_mask_rng(18, 3, 5),
            dispatch_mask_rng(17, 4, 5),
            dispatch_mask_rng(17, 3, 6),
        ] {
            let mut base = dispatch_mask_rng(17, 3, 5);
            assert_ne!(
                (0..8).map(|_| base.next_u64()).collect::<Vec<_>>(),
                (0..8).map(|_| other.next_u64()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn masks_are_reproducible_and_sized_by_the_rate() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        for &(round, client) in &[(1u64, 0usize), (2, 3), (9, 7)] {
            let a = random_mask(&spec, 0.6, &mut dispatch_mask_rng(17, round, client));
            let b = random_mask(&spec, 0.6, &mut dispatch_mask_rng(17, round, client));
            assert_eq!(a, b);
            let want: Vec<usize> =
                spec.unit_counts().iter().map(|&n| keep_count(n, 0.6)).collect();
            assert_eq!(a.selected_per_layer(), want);
        }
        // Different clients in the same round get different masks.
        let a = random_mask(&spec, 0.6, &mut dispatch_mask_rng(17, 2, 0));
        let b = random_mask(&spec, 0.6, &mut dispatch_mask_rng(17, 2, 1));
        assert_ne!(a, b);
    }
}
