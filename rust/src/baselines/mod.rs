//! Client-selection baselines (paper §6.2), run through the same round
//! engine and under the same per-round uploaded-byte budget
//! `A_server · Σ U_n` as FedDD:
//!
//! * **FedAvg** [4] — every client uploads the full model, no budget
//!   (the paper's reference point for T2A = 1).
//! * **FedCS** [8] — drops the clients with the longest round time:
//!   greedily admits the *fastest* clients while their full-model uploads
//!   fit the byte budget.
//! * **Oort** [10] — utility-guided selection: statistical utility
//!   `m_n · loss_n` times a straggler penalty `(T_pref / t_n)^α` when the
//!   client is slower than the preferred round time (α = 2 per the
//!   paper's setup), with optimistic values for unexplored clients and
//!   ε-greedy exploration.

use crate::config::ExpConfig;
use crate::coordinator::ClientState;
use crate::util::rng::Rng;

/// Estimated full-model round time for a client (download + train +
/// upload, Eq. 12 inner term).
pub fn full_round_time(c: &ClientState, cfg: &ExpConfig) -> f64 {
    let bytes = c.u_bytes() as f64;
    c.profile.t_down(bytes)
        + c.profile.t_cmp(c.samples_per_round(cfg.local_steps, cfg.batch))
        + c.profile.t_up(bytes)
}

/// FedCS: fastest clients first while full uploads fit the budget.
///
/// All orderings in this module use [`f64::total_cmp`]: a NaN round-time
/// or utility (e.g. a degenerate device profile) sorts deterministically
/// to the end instead of panicking mid-selection, so FedCS/Oort have a
/// documented total order on any input.
pub fn fedcs_select(
    clients: &[ClientState],
    cfg: &ExpConfig,
    budget_bytes: usize,
) -> Vec<usize> {
    let mut order: Vec<usize> = (0..clients.len()).collect();
    order.sort_by(|&a, &b| {
        full_round_time(&clients[a], cfg).total_cmp(&full_round_time(&clients[b], cfg))
    });
    let mut selected = Vec::new();
    let mut used = 0usize;
    for n in order {
        let u = clients[n].u_bytes();
        if used + u <= budget_bytes {
            used += u;
            selected.push(n);
        }
    }
    if selected.is_empty() {
        // budget smaller than the smallest model: still run one client
        // (the fastest), as FedCS would extend the deadline.
        let fastest = (0..clients.len())
            .min_by(|&a, &b| {
                full_round_time(&clients[a], cfg).total_cmp(&full_round_time(&clients[b], cfg))
            })
            .unwrap();
        selected.push(fastest);
    }
    selected.sort_unstable();
    selected
}

/// Oort: top statistical×system utility under the byte budget.
pub fn oort_select(
    clients: &[ClientState],
    cfg: &ExpConfig,
    budget_bytes: usize,
    round: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    // Preferred round duration: median full-round time.
    let mut times: Vec<f64> = clients.iter().map(|c| full_round_time(c, cfg)).collect();
    let mut sorted = times.clone();
    sorted.sort_by(f64::total_cmp);
    let t_pref = sorted[sorted.len() / 2];

    // Statistical utility m_n · loss_n; unexplored clients get the current
    // max (optimistic prior), so everyone is tried early.
    let mut utils: Vec<f64> = clients
        .iter()
        .map(|c| c.m_n() as f64 * c.last_loss.max(0.0))
        .collect();
    let max_util = utils.iter().cloned().fold(f64::MIN, f64::max).max(1e-9);
    for (u, c) in utils.iter_mut().zip(clients) {
        if c.participations == 0 {
            *u = max_util;
        }
    }
    // System penalty.
    for (u, t) in utils.iter_mut().zip(&mut times) {
        if *t > t_pref {
            *u *= (t_pref / *t).powf(cfg.oort_alpha);
        }
    }
    // ε-greedy exploration: a decaying fraction of the budget goes to
    // random clients (Oort §5; ε0=0.2, ×0.98 per round).
    let eps = 0.2 * 0.98f64.powi(round as i32 - 1);

    let mut order: Vec<usize> = (0..clients.len()).collect();
    // Descending utility; total_cmp keeps the order total (NaN sorts low).
    order.sort_by(|&a, &b| utils[b].total_cmp(&utils[a]));

    let mut selected = Vec::new();
    let mut used = 0usize;
    // exploration picks first
    let explore_budget = (budget_bytes as f64 * eps) as usize;
    let mut perm: Vec<usize> = rng.permutation(clients.len());
    perm.retain(|&n| clients[n].participations == 0);
    for &n in &perm {
        let u = clients[n].u_bytes();
        if used + u <= explore_budget {
            used += u;
            selected.push(n);
        }
    }
    for n in order {
        if selected.contains(&n) {
            continue;
        }
        let u = clients[n].u_bytes();
        if used + u <= budget_bytes {
            used += u;
            selected.push(n);
        }
    }
    if selected.is_empty() {
        selected.push(order_first_by_util(&utils));
    }
    selected.sort_unstable();
    selected
}

fn order_first_by_util(utils: &[f64]) -> usize {
    utils
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ClientParams, SnapshotRing};
    use crate::data::ClientShard;
    use crate::model::{ModelId, ModelSpec};
    use crate::simnet::DeviceProfile;

    fn clients(n: usize) -> (Vec<ClientState>, ExpConfig) {
        let cfg = ExpConfig::smoke();
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(0);
        let global = spec.init_params(&mut rng);
        let mut ring = SnapshotRing::new();
        let snap = ring.publish(0, &global);
        let v = (0..n)
            .map(|i| ClientState {
                id: i,
                model_id: ModelId::new("mlp", 100),
                spec: spec.clone(),
                params: ClientParams::synced(snap.clone()),
                data: ClientShard::Owned((0..100).collect()),
                profile: DeviceProfile {
                    cycles_per_sample: 2e6,
                    cpu_hz: 2e9,
                    up_bps: 5e4 / (i as f64 + 1.0),
                    down_bps: 20e4,
                },
                dis_score: 5.0,
                last_loss: 1.0 + i as f64 * 0.1,
                participations: 0,
                rng: Rng::new(i as u64),
                train_artifact: "mlp_w100_train".into(),
                scan_artifact: None,
            })
            .collect();
        (v, cfg)
    }

    #[test]
    fn fedcs_prefers_fast_clients_within_budget() {
        let (cs, cfg) = clients(10);
        let u = cs[0].u_bytes();
        // budget for exactly 4 full models
        let sel = fedcs_select(&cs, &cfg, 4 * u);
        assert_eq!(sel.len(), 4);
        // fastest = lowest index (uplink degrades with index)
        assert_eq!(sel, vec![0, 1, 2, 3]);
    }

    #[test]
    fn fedcs_never_empty() {
        let (cs, cfg) = clients(5);
        let sel = fedcs_select(&cs, &cfg, 10); // tiny budget
        assert_eq!(sel.len(), 1);
    }

    #[test]
    fn oort_respects_budget_and_explores() {
        let (mut cs, cfg) = clients(10);
        let u = cs[0].u_bytes();
        let mut rng = Rng::new(7);
        let sel = oort_select(&cs, &cfg, 5 * u, 1, &mut rng);
        assert!(sel.len() <= 5 && !sel.is_empty());
        // mark some as explored with low loss; high-loss clients preferred
        for c in cs.iter_mut() {
            c.participations = 1;
        }
        cs[2].last_loss = 100.0; // huge statistical utility, fast-ish client
        let sel2 = oort_select(&cs, &cfg, 3 * u, 5, &mut rng);
        assert!(sel2.contains(&2), "{sel2:?}");
    }

    #[test]
    fn oort_penalizes_stragglers() {
        let (mut cs, cfg) = clients(6);
        for c in cs.iter_mut() {
            c.participations = 1;
            c.last_loss = 1.0;
        }
        // client 5 is by construction the slowest (up_bps lowest)
        let u = cs[0].u_bytes();
        let mut rng = Rng::new(9);
        let sel = oort_select(&cs, &cfg, 3 * u, 10, &mut rng);
        assert!(!sel.contains(&5), "straggler selected: {sel:?}");
    }
}
