//! Baseline schemes (paper §6.2) and the [`Scheme`] seam the round
//! engine drives every scheme — FedDD included — through.
//!
//! Two families run through the same round engine and under the same
//! per-round uploaded-byte budget `A_server · Σ U_n` as FedDD:
//!
//! **Client selection** — which clients upload (full models):
//!
//! * **FedAvg** [4] — every client uploads the full model, no budget
//!   (the paper's reference point for T2A = 1).
//! * **FedCS** [8] — drops the clients with the longest round time:
//!   greedily admits the *fastest* clients while their full-model uploads
//!   fit the byte budget.
//! * **Oort** [10] — utility-guided selection: statistical utility
//!   `m_n · loss_n` times a straggler penalty `(T_pref / t_n)^α` when the
//!   client is slower than the preferred round time (α = 2 per the
//!   paper's setup), with optimistic values for unexplored clients and
//!   ε-greedy exploration.
//!
//! **Parameter dropout** — which *units* ship (every client uploads):
//!
//! * **fed_dropout** ([`fed_dropout::FedDropout`]) — Caldas-style random
//!   federated dropout (arXiv:1812.07210): the server picks one uniform
//!   rate `cfg.fd_rate` and a random unit mask per (round, client) at
//!   dispatch; sub-model download *and* upload both shrink.
//! * **afd** ([`afd::Afd`]) — Adaptive Federated Dropout
//!   (arXiv:2011.04050): a server-maintained per-unit activation-score
//!   map (an EMA of the global update's importance scores) decides which
//!   units ship, with the rate annealed on plateau of round loss.
//!
//! The engine never string-matches on `cfg.scheme` inside a round:
//! [`scheme_by_name`] resolves the config to a boxed [`Scheme`] once at
//! build, [`Scheme::plan_round`] produces the round's participants /
//! rates / [`DispatchMasks`], and the boolean contract surface
//! ([`Scheme::stateful`] &c.) drives the broadcast schedule, the rebase
//! gates and the dropout reporting in both round modes.

pub mod afd;
pub mod fed_dropout;

pub use afd::Afd;
pub use fed_dropout::{dispatch_mask_rng, FedDropout};

use crate::config::ExpConfig;
use crate::coordinator::ClientState;
use crate::model::ModelSpec;
use crate::solver::{allocate_fast, AllocInput, AllocParams};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Every scheme [`scheme_by_name`] resolves, in the order the docs and
/// the scenario matrix list them. `config::validate` whitelists against
/// this — one source of truth for "what is a scheme".
pub const SCHEME_NAMES: &[&str] = &["feddd", "fedavg", "fedcs", "oort", "fed_dropout", "afd"];

/// How the upload masks of one round's dispatch are chosen — the part of
/// a round plan the ingest stage (`coordinator::ingest::stage_clients`)
/// consumes. FedDD picks masks client-side *after* training (Algorithm
/// 2); the dropout-family baselines pick them server-side *at dispatch*,
/// which is what lets the Eq. 5 download shrink too.
#[derive(Clone, Debug, PartialEq)]
pub enum DispatchMasks {
    /// The client selects its own mask post-training (FedDD Algorithm 2,
    /// under `cfg.selection` with the client's round-labeled RNG split).
    ClientChoice,
    /// Full-model uploads, no masking (FedAvg/FedCS/Oort).
    Full,
    /// Server-chosen uniform random mask per (round, client) at the
    /// dispatched rate. The draw is a *pure function* of
    /// `(cfg.seed, round, client)` ([`dispatch_mask_rng`]) — no engine or
    /// client RNG state is consumed, so a serve-mode agent recomputes the
    /// identical mask from the shared config and the wire carries only
    /// `(slot, rate)` pairs.
    Random,
    /// Server-chosen mask ranked by a per-(layer, unit) score map over
    /// the *global* model's units (AFD's activation-score map; narrower
    /// hetero clients index it through the leading-corner prefix).
    Scored { scores: Vec<Vec<f64>> },
}

/// What a scheme sees when planning a round: read-only fleet + model
/// state, the round byte budget, and the engine's RNG (the only
/// randomness a plan may consume — drawing anywhere else would break the
/// bitwise-determinism-across-worker-counts contract).
pub struct RoundCtx<'a> {
    pub cfg: &'a ExpConfig,
    pub clients: &'a [ClientState],
    pub global_spec: &'a ModelSpec,
    /// Per-round byte budget `A_server · Σ U_n`.
    pub budget_bytes: usize,
    pub rng: &'a mut Rng,
}

/// One round's plan: who participates, at what dropout rate (indexed by
/// absolute client id), and how upload masks are chosen.
pub struct RoundPlan {
    /// Participants, strictly ascending client ids.
    pub participants: Vec<usize>,
    /// Dropout rates indexed by **absolute** client id (0 where unused).
    pub dropout: Vec<f64>,
    pub masks: DispatchMasks,
}

/// A federated scheme, as the round engine sees it. One boxed instance
/// lives on the [`crate::coordinator::FedRun`] for the whole run; any
/// mutable fields are server-resident scheme state (AFD's score map).
///
/// Determinism contract: [`Self::plan_round`] may draw randomness only
/// from `ctx.rng`, and [`Self::observe_round`] sees only
/// worker-count-independent inputs (the global before/after and the
/// round's mean loss) — so every scheme inherits the engine's
/// bitwise-identical-across-worker-counts guarantee for free.
pub trait Scheme: Send {
    /// The `cfg.scheme` string this scheme answers to.
    fn name(&self) -> &'static str;

    /// Stateful schemes keep virtualized per-client params (snapshot +
    /// residual), rebase after every round and ride the `cfg.h` sparse /
    /// broadcast download schedule; stateless baselines re-extract from
    /// the live global at every dispatch and always broadcast.
    fn stateful(&self) -> bool {
        false
    }

    /// Whether round `t`'s `mean_dropout` column reports this scheme's
    /// realized/allocated dropout (false ⇒ the column reads 0).
    fn reports_round_dropout(&self, _t: usize) -> bool {
        false
    }

    /// Whether the engine must clone the pre-aggregation global and call
    /// [`Self::observe_round`] after each fold (AFD's score map).
    fn needs_observation(&self) -> bool {
        false
    }

    /// The [`DispatchMasks`] a serve-mode agent can rebuild from config
    /// alone, or `None` when the scheme keeps server-resident mask state
    /// that cannot ride the wire's `(slot, rate)` dispatch entries — such
    /// a scheme cannot run in serve mode (`feddd serve`/`agent` refuse it
    /// up front, and `stage_for_dispatch` errors rather than drifting the
    /// replica).
    fn agent_masks(&self, cfg: &ExpConfig) -> Option<DispatchMasks>;

    /// Plan round `t`: participants, per-client dropout rates, masks.
    fn plan_round(&mut self, t: usize, ctx: &mut RoundCtx<'_>) -> anyhow::Result<RoundPlan>;

    /// Post-fold observation hook (only called when
    /// [`Self::needs_observation`]): the global parameters before and
    /// after round `t`'s aggregation, plus the round's mean train loss.
    fn observe_round(
        &mut self,
        _t: usize,
        _spec: &ModelSpec,
        _before: &[Tensor],
        _after: &[Tensor],
        _mean_loss: f64,
    ) {
    }
}

/// Resolve a `cfg.scheme` string to its [`Scheme`] (see [`SCHEME_NAMES`]).
pub fn scheme_by_name(name: &str) -> anyhow::Result<Box<dyn Scheme>> {
    Ok(match name {
        "feddd" => Box::new(FedDd),
        "fedavg" => Box::new(FedAvg),
        "fedcs" => Box::new(FedCs),
        "oort" => Box::new(Oort),
        "fed_dropout" => Box::new(FedDropout),
        "afd" => Box::new(Afd::new()),
        _ => anyhow::bail!("unknown scheme {name:?}"),
    })
}

/// FedDD proper: everyone participates, rates from the Eq. 16/17
/// allocation (or the uniform ablation), masks chosen client-side.
pub struct FedDd;

impl Scheme for FedDd {
    fn name(&self) -> &'static str {
        "feddd"
    }
    fn stateful(&self) -> bool {
        true
    }
    fn reports_round_dropout(&self, t: usize) -> bool {
        t > 1 // Algorithm 1: D^1 = 0
    }
    fn agent_masks(&self, _cfg: &ExpConfig) -> Option<DispatchMasks> {
        Some(DispatchMasks::ClientChoice)
    }
    fn plan_round(&mut self, t: usize, ctx: &mut RoundCtx<'_>) -> anyhow::Result<RoundPlan> {
        let n = ctx.clients.len();
        let dropout = if t == 1 {
            vec![0.0; n] // Algorithm 1: D^1 = 0
        } else {
            allocate_feddd_dropout(ctx)?
        };
        Ok(RoundPlan {
            participants: (0..n).collect(),
            dropout,
            masks: DispatchMasks::ClientChoice,
        })
    }
}

/// Dropout rates for a FedDD round: the Eq. 16/17 optimum, or the
/// uniform ablation (D_n = 1 − A_server for everyone).
fn allocate_feddd_dropout(ctx: &RoundCtx<'_>) -> anyhow::Result<Vec<f64>> {
    let cfg = ctx.cfg;
    if cfg.alloc == "uniform" {
        let d = (1.0 - cfg.a_server).min(cfg.d_max);
        return Ok(vec![d; ctx.clients.len()]);
    }
    let m_total: f64 = ctx.clients.iter().map(|c| c.m_n() as f64).sum();
    let u_global = ctx.global_spec.size_bytes() as f64;
    let inputs: Vec<AllocInput> = ctx
        .clients
        .iter()
        .map(|c| AllocInput {
            u_bytes: c.u_bytes() as f64,
            t_cmp: c.profile.t_cmp(c.samples_per_round(cfg.local_steps, cfg.batch)),
            sec_per_byte: c.profile.sec_per_byte(),
            // re_n = (m_n/m)(Σ_c min(C·dis,1))(U_n/U)·loss_n  (Eq. 13)
            re: (c.m_n() as f64 / m_total)
                * c.dis_score
                * (c.u_bytes() as f64 / u_global)
                * c.last_loss,
        })
        .collect();
    let params = AllocParams {
        d_max: cfg.d_max,
        a_server: cfg.a_server,
        delta: cfg.delta,
    };
    Ok(allocate_fast(&inputs, &params)?.d)
}

/// FedAvg: everyone participates, full uploads.
pub struct FedAvg;

impl Scheme for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }
    fn agent_masks(&self, _cfg: &ExpConfig) -> Option<DispatchMasks> {
        Some(DispatchMasks::Full)
    }
    fn plan_round(&mut self, _t: usize, ctx: &mut RoundCtx<'_>) -> anyhow::Result<RoundPlan> {
        let n = ctx.clients.len();
        Ok(RoundPlan {
            participants: (0..n).collect(),
            dropout: vec![0.0; n],
            masks: DispatchMasks::Full,
        })
    }
}

/// FedCS: the fastest clients whose full uploads fit the budget.
pub struct FedCs;

impl Scheme for FedCs {
    fn name(&self) -> &'static str {
        "fedcs"
    }
    fn agent_masks(&self, _cfg: &ExpConfig) -> Option<DispatchMasks> {
        Some(DispatchMasks::Full)
    }
    fn plan_round(&mut self, _t: usize, ctx: &mut RoundCtx<'_>) -> anyhow::Result<RoundPlan> {
        let sel = fedcs_select(ctx.clients, ctx.cfg, ctx.budget_bytes);
        Ok(RoundPlan {
            participants: sel,
            dropout: vec![0.0; ctx.clients.len()],
            masks: DispatchMasks::Full,
        })
    }
}

/// Oort: top statistical×system utility under the budget.
pub struct Oort;

impl Scheme for Oort {
    fn name(&self) -> &'static str {
        "oort"
    }
    fn agent_masks(&self, _cfg: &ExpConfig) -> Option<DispatchMasks> {
        Some(DispatchMasks::Full)
    }
    fn plan_round(&mut self, t: usize, ctx: &mut RoundCtx<'_>) -> anyhow::Result<RoundPlan> {
        let sel = oort_select(ctx.clients, ctx.cfg, ctx.budget_bytes, t, ctx.rng);
        Ok(RoundPlan {
            participants: sel,
            dropout: vec![0.0; ctx.clients.len()],
            masks: DispatchMasks::Full,
        })
    }
}

/// Estimated full-model round time for a client (download + train +
/// upload, Eq. 12 inner term).
pub fn full_round_time(c: &ClientState, cfg: &ExpConfig) -> f64 {
    let bytes = c.u_bytes() as f64;
    c.profile.t_down(bytes)
        + c.profile.t_cmp(c.samples_per_round(cfg.local_steps, cfg.batch))
        + c.profile.t_up(bytes)
}

/// FedCS: fastest clients first while full uploads fit the budget.
///
/// All orderings in this module use [`f64::total_cmp`]: a NaN round-time
/// or utility (e.g. a degenerate device profile) sorts deterministically
/// to the end instead of panicking mid-selection, so FedCS/Oort have a
/// documented total order on any input. An empty fleet selects nothing —
/// selection sits downstream of the serve ingest path, which must fail a
/// round with an error, never panic the process (DESIGN.md §Serve).
pub fn fedcs_select(
    clients: &[ClientState],
    cfg: &ExpConfig,
    budget_bytes: usize,
) -> Vec<usize> {
    if clients.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..clients.len()).collect();
    order.sort_by(|&a, &b| {
        full_round_time(&clients[a], cfg).total_cmp(&full_round_time(&clients[b], cfg))
    });
    // The sort is stable, so `order[0]` is exactly the client a
    // first-minimum scan would find — kept for the budget-too-small
    // fallback below without a second pass.
    let fastest = order[0];
    let mut selected = Vec::new();
    let mut used = 0usize;
    for n in order {
        let u = clients[n].u_bytes();
        if used + u <= budget_bytes {
            used += u;
            selected.push(n);
        }
    }
    if selected.is_empty() {
        // budget smaller than the smallest model: still run one client
        // (the fastest), as FedCS would extend the deadline.
        selected.push(fastest);
    }
    selected.sort_unstable();
    selected
}

/// Oort: top statistical×system utility under the byte budget.
pub fn oort_select(
    clients: &[ClientState],
    cfg: &ExpConfig,
    budget_bytes: usize,
    round: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    if clients.is_empty() {
        return Vec::new();
    }
    // Preferred round duration: median full-round time (midpoint mean of
    // the two central values for an even fleet — `sorted[len/2]` alone
    // would take the *upper* median and under-penalize).
    let times: Vec<f64> = clients.iter().map(|c| full_round_time(c, cfg)).collect();
    let mut sorted = times.clone();
    sorted.sort_by(f64::total_cmp);
    let m = sorted.len();
    let t_pref = if m % 2 == 0 {
        (sorted[m / 2 - 1] + sorted[m / 2]) / 2.0
    } else {
        sorted[m / 2]
    };

    // Statistical utility m_n · loss_n; unexplored clients get the current
    // max (optimistic prior), so everyone is tried early.
    let mut utils: Vec<f64> = clients
        .iter()
        .map(|c| c.m_n() as f64 * c.last_loss.max(0.0))
        .collect();
    let max_util = utils.iter().cloned().fold(f64::MIN, f64::max).max(1e-9);
    for (u, c) in utils.iter_mut().zip(clients) {
        if c.participations == 0 {
            *u = max_util;
        }
    }
    // System penalty.
    for (u, t) in utils.iter_mut().zip(&times) {
        if *t > t_pref {
            *u *= (t_pref / *t).powf(cfg.oort_alpha);
        }
    }
    // ε-greedy exploration: a decaying fraction of the budget goes to
    // random clients (Oort §5; ε0=0.2, ×0.98 per round). The exponent is
    // clamped at 0: `powi(round - 1)` alone would *grow* ε above ε0 at
    // round 0 (powi(-1) = 1/0.98).
    let eps = 0.2 * 0.98f64.powi((round as i32 - 1).max(0));

    let mut order: Vec<usize> = (0..clients.len()).collect();
    // Descending utility; total_cmp keeps the order total (NaN sorts low).
    order.sort_by(|&a, &b| utils[b].total_cmp(&utils[a]));

    let mut selected = Vec::new();
    // O(1) membership for the exploitation loop's dedup against the
    // exploration picks (a `selected.contains` scan would be O(n²) over
    // the fleet).
    let mut picked = vec![false; clients.len()];
    let mut used = 0usize;
    // exploration picks first
    let explore_budget = (budget_bytes as f64 * eps) as usize;
    let mut perm: Vec<usize> = rng.permutation(clients.len());
    perm.retain(|&n| clients[n].participations == 0);
    for &n in &perm {
        let u = clients[n].u_bytes();
        if used + u <= explore_budget {
            used += u;
            picked[n] = true;
            selected.push(n);
        }
    }
    for n in order {
        if picked[n] {
            continue;
        }
        let u = clients[n].u_bytes();
        if used + u <= budget_bytes {
            used += u;
            picked[n] = true;
            selected.push(n);
        }
    }
    if selected.is_empty() {
        selected.push(order_first_by_util(&utils));
    }
    selected.sort_unstable();
    selected
}

fn order_first_by_util(utils: &[f64]) -> usize {
    utils
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ClientParams, SnapshotRing};
    use crate::data::ClientShard;
    use crate::model::{ModelId, ModelSpec};
    use crate::simnet::DeviceProfile;

    fn clients(n: usize) -> (Vec<ClientState>, ExpConfig) {
        let cfg = ExpConfig::smoke();
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(0);
        let global = spec.init_params(&mut rng);
        let mut ring = SnapshotRing::new();
        let snap = ring.publish(0, &global);
        let v = (0..n)
            .map(|i| ClientState {
                id: i,
                model_id: ModelId::new("mlp", 100),
                spec: spec.clone(),
                params: ClientParams::synced(snap.clone()),
                data: ClientShard::Owned((0..100).collect()),
                profile: DeviceProfile {
                    cycles_per_sample: 2e6,
                    cpu_hz: 2e9,
                    up_bps: 5e4 / (i as f64 + 1.0),
                    down_bps: 20e4,
                },
                dis_score: 5.0,
                last_loss: 1.0 + i as f64 * 0.1,
                participations: 0,
                rng: Rng::new(i as u64),
                train_artifact: "mlp_w100_train".into(),
                scan_artifact: None,
            })
            .collect();
        (v, cfg)
    }

    #[test]
    fn fedcs_prefers_fast_clients_within_budget() {
        let (cs, cfg) = clients(10);
        let u = cs[0].u_bytes();
        // budget for exactly 4 full models
        let sel = fedcs_select(&cs, &cfg, 4 * u);
        assert_eq!(sel.len(), 4);
        // fastest = lowest index (uplink degrades with index)
        assert_eq!(sel, vec![0, 1, 2, 3]);
    }

    #[test]
    fn fedcs_never_empty() {
        let (cs, cfg) = clients(5);
        let sel = fedcs_select(&cs, &cfg, 10); // tiny budget
        assert_eq!(sel.len(), 1);
    }

    #[test]
    fn empty_fleet_selects_nothing_instead_of_panicking() {
        // Both selectors sit downstream of the serve ingest path: a
        // degenerate (empty) fleet must yield an empty selection, not an
        // index panic (FedCS's old `min_by(..).unwrap()`) or an
        // empty-slice index (Oort's old `sorted[len / 2]`).
        let (cs, cfg) = clients(0);
        assert!(fedcs_select(&cs, &cfg, 1_000_000).is_empty());
        let mut rng = Rng::new(3);
        assert!(oort_select(&cs, &cfg, 1_000_000, 1, &mut rng).is_empty());
    }

    #[test]
    fn oort_respects_budget_and_explores() {
        let (mut cs, cfg) = clients(10);
        let u = cs[0].u_bytes();
        let mut rng = Rng::new(7);
        let sel = oort_select(&cs, &cfg, 5 * u, 1, &mut rng);
        assert!(sel.len() <= 5 && !sel.is_empty());
        // mark some as explored with low loss; high-loss clients preferred
        for c in cs.iter_mut() {
            c.participations = 1;
        }
        cs[2].last_loss = 100.0; // huge statistical utility, fast-ish client
        let sel2 = oort_select(&cs, &cfg, 3 * u, 5, &mut rng);
        assert!(sel2.contains(&2), "{sel2:?}");
    }

    #[test]
    fn oort_penalizes_stragglers() {
        // NOTE: with 6 clients this test used to pin the *upper*-median
        // `t_pref = sorted[3]` (penalizing clients 4 and 5); the
        // even-midpoint fix moves `t_pref` to `(sorted[2] + sorted[3])/2`,
        // which penalizes client 3 as well — strictly harder on
        // stragglers, so the assertion is unchanged.
        let (mut cs, cfg) = clients(6);
        for c in cs.iter_mut() {
            c.participations = 1;
            c.last_loss = 1.0;
        }
        // client 5 is by construction the slowest (up_bps lowest)
        let u = cs[0].u_bytes();
        let mut rng = Rng::new(9);
        let sel = oort_select(&cs, &cfg, 3 * u, 10, &mut rng);
        assert!(!sel.contains(&5), "straggler selected: {sel:?}");
    }

    #[test]
    fn oort_t_pref_uses_even_midpoint() {
        // Two clients: 0 fast, 1 ~100× slower but with higher statistical
        // utility. The upper median `sorted[1]` equals client 1's own
        // round time, so the old code never penalized it and picked {1};
        // the midpoint median sits halfway, the straggler penalty
        // (≈ 0.505² ≈ 0.25) collapses client 1's utility below client
        // 0's, and {0} wins.
        let (mut cs, cfg) = clients(2);
        for c in cs.iter_mut() {
            c.participations = 1;
        }
        cs[0].last_loss = 1.0;
        cs[1].last_loss = 1.5;
        cs[1].profile.up_bps = cs[0].profile.up_bps / 1000.0; // ~100× round time
        let u = cs[0].u_bytes();
        let mut rng = Rng::new(11);
        let sel = oort_select(&cs, &cfg, u, 10, &mut rng);
        assert_eq!(sel, vec![0], "midpoint t_pref must penalize the straggler");
    }

    #[test]
    fn oort_round_zero_exploration_is_clamped() {
        // ε must satisfy ε(0) = ε0 = 0.2, not 0.2/0.98: with a budget of
        // 4.95·u the exploration budget is 0.99·u under the clamp (admits
        // nobody) but 1.01·u under the old `powi(-1)` (admits the one
        // unexplored client). Client 5 is unexplored *and* the slowest —
        // penalized to the bottom of the exploitation order — so the old
        // code selected {0,1,2,5} at round 0 and {0,1,2,3} at round 1,
        // while the clamp makes round 0 identical to round 1.
        let (mut cs, cfg) = clients(6);
        for c in cs.iter_mut().take(5) {
            c.participations = 1;
            c.last_loss = 1.0;
        }
        cs[5].participations = 0;
        let u = cs[0].u_bytes();
        let budget = (4.95 * u as f64) as usize;
        let sel0 = oort_select(&cs, &cfg, budget, 0, &mut Rng::new(13));
        let sel1 = oort_select(&cs, &cfg, budget, 1, &mut Rng::new(13));
        assert_eq!(sel0, sel1, "ε(0) must equal ε(1) = ε0");
        assert!(!sel0.contains(&5), "round-0 over-exploration: {sel0:?}");
        assert_eq!(sel0, vec![0, 1, 2, 3]);
    }

    /// Verbatim copy of [`oort_select`]'s selection loops with the old
    /// O(n²) `selected.contains` dedup — the reference the membership-
    /// mask rewrite must match output-for-output.
    fn oort_select_contains_dedup(
        clients: &[ClientState],
        cfg: &ExpConfig,
        budget_bytes: usize,
        round: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        if clients.is_empty() {
            return Vec::new();
        }
        let times: Vec<f64> = clients.iter().map(|c| full_round_time(c, cfg)).collect();
        let mut sorted = times.clone();
        sorted.sort_by(f64::total_cmp);
        let m = sorted.len();
        let t_pref = if m % 2 == 0 {
            (sorted[m / 2 - 1] + sorted[m / 2]) / 2.0
        } else {
            sorted[m / 2]
        };
        let mut utils: Vec<f64> = clients
            .iter()
            .map(|c| c.m_n() as f64 * c.last_loss.max(0.0))
            .collect();
        let max_util = utils.iter().cloned().fold(f64::MIN, f64::max).max(1e-9);
        for (u, c) in utils.iter_mut().zip(clients) {
            if c.participations == 0 {
                *u = max_util;
            }
        }
        for (u, t) in utils.iter_mut().zip(&times) {
            if *t > t_pref {
                *u *= (t_pref / *t).powf(cfg.oort_alpha);
            }
        }
        let eps = 0.2 * 0.98f64.powi((round as i32 - 1).max(0));
        let mut order: Vec<usize> = (0..clients.len()).collect();
        order.sort_by(|&a, &b| utils[b].total_cmp(&utils[a]));
        let mut selected = Vec::new();
        let mut used = 0usize;
        let explore_budget = (budget_bytes as f64 * eps) as usize;
        let mut perm: Vec<usize> = rng.permutation(clients.len());
        perm.retain(|&n| clients[n].participations == 0);
        for &n in &perm {
            let u = clients[n].u_bytes();
            if used + u <= explore_budget {
                used += u;
                selected.push(n);
            }
        }
        for n in order {
            if selected.contains(&n) {
                continue;
            }
            let u = clients[n].u_bytes();
            if used + u <= budget_bytes {
                used += u;
                selected.push(n);
            }
        }
        if selected.is_empty() {
            selected.push(order_first_by_util(&utils));
        }
        selected.sort_unstable();
        selected
    }

    #[test]
    fn oort_dedup_rewrite_is_bitwise_identical() {
        // The O(n²)→O(n) dedup must change nothing observable: same
        // selections, same RNG consumption, over fleets that exercise the
        // explore/exploit overlap (mixed participation, varied budgets
        // and rounds).
        for n in [1usize, 2, 5, 12, 30] {
            let (mut cs, cfg) = clients(n);
            for (i, c) in cs.iter_mut().enumerate() {
                c.participations = (i % 3 == 0) as usize; // mix of (un)explored
            }
            let u = cs[0].u_bytes();
            for round in [0usize, 1, 5, 40] {
                for budget_u in [1usize, 3, n, 4 * n] {
                    let budget = budget_u * u;
                    let seed = (n * 1000 + round * 10 + budget_u) as u64;
                    let a = oort_select(&cs, &cfg, budget, round, &mut Rng::new(seed));
                    let b = oort_select_contains_dedup(
                        &cs,
                        &cfg,
                        budget,
                        round,
                        &mut Rng::new(seed),
                    );
                    assert_eq!(a, b, "n={n} round={round} budget={budget_u}u");
                }
            }
        }
    }

    #[test]
    fn scheme_registry_covers_every_name() {
        for &name in SCHEME_NAMES {
            let s = scheme_by_name(name).unwrap();
            assert_eq!(s.name(), name);
        }
        assert!(scheme_by_name("nope").is_err());
        // Serve compatibility: exactly the schemes whose masks are a pure
        // function of config can ride the wire's (slot, rate) dispatches.
        let cfg = ExpConfig::smoke();
        for &name in SCHEME_NAMES {
            let serveable = scheme_by_name(name).unwrap().agent_masks(&cfg).is_some();
            assert_eq!(serveable, name != "afd", "{name}");
        }
    }

    #[test]
    fn schemes_plan_rounds_within_the_fleet() {
        let (cs, cfg) = clients(6);
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let total: usize = cs.iter().map(|c| c.u_bytes()).sum();
        for &name in SCHEME_NAMES {
            let mut scheme = scheme_by_name(name).unwrap();
            let mut rng = Rng::new(21);
            let mut ctx = RoundCtx {
                cfg: &cfg,
                clients: &cs,
                global_spec: &spec,
                budget_bytes: (cfg.a_server * total as f64).round() as usize,
                rng: &mut rng,
            };
            let plan = scheme.plan_round(1, &mut ctx).unwrap();
            assert!(!plan.participants.is_empty(), "{name}");
            assert!(plan.participants.windows(2).all(|w| w[0] < w[1]), "{name}");
            assert!(plan.participants.iter().all(|&p| p < cs.len()), "{name}");
            assert_eq!(plan.dropout.len(), cs.len(), "{name}");
            assert!(
                plan.dropout.iter().all(|&d| (0.0..=1.0).contains(&d)),
                "{name}: {:?}",
                plan.dropout
            );
        }
    }
}
