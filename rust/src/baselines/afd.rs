//! Adaptive Federated Dropout (`scheme = afd`), after Bouacida et al.,
//! "Adaptive Federated Dropout: Improving Communication Efficiency and
//! Generalization for Federated Learning" (arXiv:2011.04050).
//!
//! The server maintains a per-unit **activation-score map** over the
//! global model: after every aggregated round it scores the global
//! update's units with the same importance index FedDD's Algorithm 2
//! uses (`selection::unit_scores` under `Policy::Importance` — the
//! Eq. 21 elementwise score group-normed per unit) and folds the scores
//! into an exponential moving average with decay `cfg.afd_ema`. Each
//! dispatch ships only the highest-scoring units at the current rate
//! (initially `cfg.fd_rate`), and the rate is **annealed on plateau**:
//! two consecutive rounds without a new best mean train loss halve it
//! (flooring to 0 below 1e-3), trading communication savings back for
//! convergence exactly when progress stalls.
//!
//! The score map is server-resident state that never crosses the wire —
//! the dispatch frames carry only `(slot, rate)` pairs — so `afd` is
//! **not serveable**: [`Scheme::agent_masks`] returns `None` and
//! `feddd serve`/`agent` refuse the scheme up front. The map is
//! JSON-serializable ([`Afd::to_json`]/[`Afd::from_json`]) for
//! inspection and checkpointing.

use crate::config::ExpConfig;
use crate::model::ModelSpec;
use crate::selection::{unit_scores, Policy};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{DispatchMasks, RoundCtx, RoundPlan, Scheme};

/// Rounds without a new best loss before the rate halves.
const PLATEAU_ROUNDS: usize = 2;
/// Rates annealed below this floor snap to 0 (full uploads).
const RATE_FLOOR: f64 = 1e-3;

/// Adaptive Federated Dropout server state: the activation-score EMA,
/// the annealed rate, and the plateau detector.
pub struct Afd {
    /// EMA decay β (armed from `cfg.afd_ema` at the first plan).
    pub beta: Option<f64>,
    /// Current dropout rate (armed from `cfg.fd_rate` at the first plan,
    /// halved on plateau).
    pub rate: Option<f64>,
    /// Activation-score EMA per (global layer, unit); empty until the
    /// first observed round.
    pub ema: Vec<Vec<f64>>,
    /// Best mean train loss seen so far (+∞ before any observation).
    pub best_loss: f64,
    /// Consecutive observed rounds without a new best loss.
    pub plateau: usize,
}

impl Afd {
    pub fn new() -> Afd {
        Afd {
            beta: None,
            rate: None,
            ema: Vec::new(),
            best_loss: f64::INFINITY,
            plateau: 0,
        }
    }

    /// Serialize the activation map + annealing state. Finiteness is
    /// preserved by construction: the unset `best_loss = +∞` is *omitted*
    /// (JSON has no infinity — `Num(inf)` would not round-trip), as are
    /// the unarmed `beta`/`rate` options.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("scheme", Json::s("afd")),
            (
                "ema",
                Json::Arr(self.ema.iter().map(|l| Json::arr_f64(l)).collect()),
            ),
            ("plateau", Json::Num(self.plateau as f64)),
        ];
        if let Some(b) = self.beta {
            pairs.push(("beta", Json::Num(b)));
        }
        if let Some(r) = self.rate {
            pairs.push(("rate", Json::Num(r)));
        }
        if self.best_loss.is_finite() {
            pairs.push(("best_loss", Json::Num(self.best_loss)));
        }
        Json::obj(pairs)
    }

    /// Inverse of [`Self::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Afd> {
        let ema = j
            .req_arr("ema")?
            .iter()
            .map(|l| {
                l.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("afd ema layer is not an array"))?
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .ok_or_else(|| anyhow::anyhow!("afd ema score is not a number"))
                    })
                    .collect::<anyhow::Result<Vec<f64>>>()
            })
            .collect::<anyhow::Result<Vec<Vec<f64>>>>()?;
        Ok(Afd {
            beta: j.get("beta").and_then(|x| x.as_f64()),
            rate: j.get("rate").and_then(|x| x.as_f64()),
            ema,
            best_loss: j.get("best_loss").and_then(|x| x.as_f64()).unwrap_or(f64::INFINITY),
            plateau: j.req_usize("plateau")?,
        })
    }
}

impl Default for Afd {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheme for Afd {
    fn name(&self) -> &'static str {
        "afd"
    }

    /// Stateful like FedDD: masked downloads leave residual channels.
    fn stateful(&self) -> bool {
        true
    }

    fn reports_round_dropout(&self, _t: usize) -> bool {
        true
    }

    fn needs_observation(&self) -> bool {
        true
    }

    /// The score map lives on the server only — not reconstructible from
    /// config, so `afd` cannot ride serve mode's dispatch frames.
    fn agent_masks(&self, _cfg: &ExpConfig) -> Option<DispatchMasks> {
        None
    }

    fn plan_round(&mut self, _t: usize, ctx: &mut RoundCtx<'_>) -> anyhow::Result<RoundPlan> {
        let n = ctx.clients.len();
        self.beta.get_or_insert(ctx.cfg.afd_ema);
        let rate = *self.rate.get_or_insert(ctx.cfg.fd_rate);
        let (dropout, scores) = if self.ema.is_empty() {
            // No observed update yet (round 1): ship everything — there
            // is no signal to rank units by.
            let zeros = ctx
                .global_spec
                .layers
                .iter()
                .map(|l| vec![0.0; l.out_dim])
                .collect();
            (vec![0.0; n], zeros)
        } else {
            (vec![rate; n], self.ema.clone())
        };
        Ok(RoundPlan {
            participants: (0..n).collect(),
            dropout,
            masks: DispatchMasks::Scored { scores },
        })
    }

    fn observe_round(
        &mut self,
        _t: usize,
        spec: &ModelSpec,
        before: &[Tensor],
        after: &[Tensor],
        mean_loss: f64,
    ) {
        let beta = self.beta.unwrap_or(0.9);
        // Importance scoring never draws from the RNG; the stream is a
        // formality of the shared `unit_scores` signature.
        let mut rng = Rng::new(0);
        let scores: Vec<Vec<f64>> = (0..spec.layers.len())
            .map(|l| unit_scores(spec, l, Policy::Importance, before, after, &mut rng))
            .collect();
        if self.ema.is_empty() {
            self.ema = scores;
        } else {
            for (e_l, s_l) in self.ema.iter_mut().zip(&scores) {
                for (e, s) in e_l.iter_mut().zip(s_l) {
                    *e = beta * *e + (1.0 - beta) * s;
                }
            }
        }
        // Anneal on plateau of round loss.
        if mean_loss.is_finite() && mean_loss < self.best_loss {
            self.best_loss = mean_loss;
            self.plateau = 0;
        } else {
            self.plateau += 1;
            if self.plateau >= PLATEAU_ROUNDS {
                let halved = self.rate.unwrap_or(0.0) * 0.5;
                self.rate = Some(if halved < RATE_FLOOR { 0.0 } else { halved });
                self.plateau = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mlp_update(seed: u64) -> (ModelSpec, Vec<Tensor>, Vec<Tensor>) {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(seed);
        let before = spec.init_params(&mut rng);
        let after: Vec<Tensor> = before
            .iter()
            .map(|t| {
                let d: Vec<f32> =
                    t.data().iter().map(|&x| x + rng.normal_f32(0.0, 0.01)).collect();
                Tensor::new(t.shape().to_vec(), d)
            })
            .collect();
        (spec, before, after)
    }

    #[test]
    fn first_observation_seeds_the_ema() {
        let (spec, before, after) = mlp_update(1);
        let mut afd = Afd::new();
        afd.beta = Some(0.9);
        afd.observe_round(1, &spec, &before, &after, 1.0);
        assert_eq!(afd.ema.len(), spec.layers.len());
        for (l, layer) in spec.layers.iter().enumerate() {
            assert_eq!(afd.ema[l].len(), layer.out_dim);
        }
        let mut rng = Rng::new(0);
        let direct = unit_scores(&spec, 0, Policy::Importance, &before, &after, &mut rng);
        assert_eq!(afd.ema[0], direct);
    }

    #[test]
    fn ema_folds_with_the_configured_decay() {
        let (spec, before, after) = mlp_update(2);
        let mut afd = Afd::new();
        afd.beta = Some(0.75);
        afd.observe_round(1, &spec, &before, &after, 1.0);
        let seeded = afd.ema.clone();
        // Second observation of the *same* update: ema' = 0.75 e + 0.25 s
        // with e == s, so the map is a fixed point.
        afd.observe_round(2, &spec, &before, &after, 0.9);
        assert_eq!(afd.ema, seeded);
        // A zero update decays the map toward 0 by exactly beta.
        afd.observe_round(3, &spec, &after, &after, 0.8);
        for (e_l, s_l) in afd.ema.iter().zip(&seeded) {
            for (e, s) in e_l.iter().zip(s_l) {
                assert!((e - 0.75 * s).abs() <= 1e-12 * s.abs().max(1.0), "{e} vs 0.75*{s}");
            }
        }
    }

    #[test]
    fn rate_anneals_on_loss_plateau_and_resets_on_improvement() {
        let (spec, before, after) = mlp_update(3);
        let mut afd = Afd::new();
        afd.beta = Some(0.9);
        afd.rate = Some(0.5);
        afd.observe_round(1, &spec, &before, &after, 1.0); // best = 1.0
        afd.observe_round(2, &spec, &before, &after, 1.2); // plateau 1
        assert_eq!(afd.rate, Some(0.5));
        afd.observe_round(3, &spec, &before, &after, 1.1); // plateau 2 -> halve
        assert_eq!(afd.rate, Some(0.25));
        assert_eq!(afd.plateau, 0);
        afd.observe_round(4, &spec, &before, &after, 0.5); // new best resets
        assert_eq!(afd.plateau, 0);
        assert_eq!(afd.rate, Some(0.25));
        // Annealing floors to zero instead of chasing denormals.
        afd.rate = Some(1.5e-3);
        afd.observe_round(5, &spec, &before, &after, 2.0);
        afd.observe_round(6, &spec, &before, &after, 2.0);
        assert_eq!(afd.rate, Some(0.0));
    }

    #[test]
    fn activation_map_round_trips_through_json() {
        // Armed, observed state round-trips bit-for-bit.
        let (spec, before, after) = mlp_update(4);
        let mut afd = Afd::new();
        afd.beta = Some(0.9);
        afd.rate = Some(0.5);
        afd.observe_round(1, &spec, &before, &after, 1.25);
        afd.observe_round(2, &spec, &before, &after, 1.5);
        let j = afd.to_json();
        let text = j.to_string_compact();
        let back = Afd::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.beta, afd.beta);
        assert_eq!(back.rate, afd.rate);
        assert_eq!(back.ema, afd.ema);
        assert_eq!(back.best_loss, afd.best_loss);
        assert_eq!(back.plateau, afd.plateau);

        // The fresh (unarmed) state has best_loss = +inf, which JSON
        // cannot carry as a number: it must round-trip via omission.
        let fresh = Afd::new();
        let text = fresh.to_json().to_string_compact();
        assert!(!text.contains("best_loss"), "{text}");
        let back = Afd::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert!(back.best_loss.is_infinite());
        assert_eq!(back.beta, None);
        assert_eq!(back.rate, None);
        assert!(back.ema.is_empty());
    }

    #[test]
    fn round_one_plan_ships_everything() {
        let cfg = {
            let mut c = ExpConfig::smoke();
            c.fd_rate = 0.6;
            c.afd_ema = 0.8;
            c
        };
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut afd = Afd::new();
        // An empty fleet keeps the test free of ClientState scaffolding;
        // plan_round only reads the fleet's length.
        let mut rng = Rng::new(5);
        let mut ctx = RoundCtx {
            cfg: &cfg,
            clients: &[],
            global_spec: &spec,
            budget_bytes: 0,
            rng: &mut rng,
        };
        let plan = afd.plan_round(1, &mut ctx).unwrap();
        assert!(plan.dropout.is_empty() && plan.participants.is_empty());
        match &plan.masks {
            DispatchMasks::Scored { scores } => {
                assert_eq!(scores.len(), spec.layers.len());
                assert!(scores.iter().flatten().all(|&s| s == 0.0));
            }
            m => panic!("expected scored masks, got {m:?}"),
        }
        // Arming happened even with no clients.
        assert_eq!(afd.beta, Some(0.8));
        assert_eq!(afd.rate, Some(0.6));
        // Once observed, the plan dispatches the armed rate + the EMA.
        let (pspec, before, after) = mlp_update(6);
        afd.observe_round(1, &pspec, &before, &after, 1.0);
        let clients: &[crate::coordinator::ClientState] = &[];
        let mut rng = Rng::new(6);
        let mut ctx = RoundCtx {
            cfg: &cfg,
            clients,
            global_spec: &pspec,
            budget_bytes: 0,
            rng: &mut rng,
        };
        let plan = afd.plan_round(2, &mut ctx).unwrap();
        match &plan.masks {
            DispatchMasks::Scored { scores } => assert_eq!(scores, &afd.ema),
            m => panic!("expected scored masks, got {m:?}"),
        }
    }
}
