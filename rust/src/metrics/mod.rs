//! Run metrics: per-round records, accuracy / time-to-accuracy (T2A)
//! tracking, per-class accuracy (Fig. 21), JSON + CSV writers.

use crate::codec::{EncodingMix, PlaneMix};
use crate::util::json::Json;

/// One synchronous round's accounting.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Virtual time at the *end* of the round (seconds).
    pub v_time: f64,
    /// Duration of this round.
    pub duration: f64,
    /// Mean training loss over participants.
    pub train_loss: f64,
    /// Masked value payload bytes uploaded by all participants this round
    /// (the budget-accounting column; no wire framing).
    pub uploaded_bytes: usize,
    /// Realized encoded upload bytes this round (headers + indices +
    /// values — `WireUpload::wire_len`, what the uplinks were charged).
    pub wire_bytes: usize,
    /// Per-layout layer counts over this round's folded uploads
    /// (dense / bitmap / COO — the encoding-mix column).
    pub encodings: EncodingMix,
    /// Per-value-plane layer counts and serialized value bytes over this
    /// round's folded uploads (f32 / f16 / i8 — the plane-mix column).
    pub planes: PlaneMix,
    /// The byte budget the scheme was allowed (A_server · Σ U_n).
    pub budget_bytes: usize,
    /// Participating clients.
    pub participants: usize,
    /// Mean dropout rate: realized byte savings (sync) or mean allocated
    /// rate over dispatched clients (semi-async); 0 for baselines.
    pub mean_dropout: f64,
    /// Whether this round broadcast the full model.
    pub full_broadcast: bool,
    /// Uploads still in flight when the round closed (semi-async rounds;
    /// always 0 under the synchronous barrier).
    pub stragglers: usize,
    /// Mean staleness, in rounds, of the uploads folded this round
    /// (0 when every fold was fresh — in particular in sync mode).
    pub mean_staleness: f64,
    /// Uploads dropped by arrival-time churn this round (`trace =
    /// "churn"` under semi-async rounds; always 0 otherwise).
    pub churned: usize,
    /// Fleet state footprint at the end of the round: Σ per-client
    /// residual bytes + live shared snapshots (each counted once) +
    /// in-flight buffered uploads (semi-async pending; 0 in sync mode) —
    /// see `FedRun::client_state_bytes`. Zero residuals right after a
    /// full broadcast; the persistent per-client part stays strictly
    /// below `clients · model` under any dropout.
    pub client_state_bytes: usize,
    /// Simulation-runtime footprint at the end of the round: device
    /// profiles + per-client clocks + the in-flight arrival heap — see
    /// `FedRun::sim_state_bytes`. O(fleet) scalars, never O(fleet · model).
    pub sim_state_bytes: usize,
    /// Data-plane footprint: dataset store + shared partition + owned
    /// shard indices — see `FedRun::data_state_bytes`. Constant across
    /// rounds; O(prototypes + fleet) in lazy mode, O(samples · dim) eager.
    pub data_state_bytes: usize,
}

/// One evaluation of the global model.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub round: usize,
    pub v_time: f64,
    pub accuracy: f64,
    pub loss: f64,
    pub per_class_accuracy: Vec<f64>,
}

/// Full result of a run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub scheme: String,
    pub label: String,
    pub rounds: Vec<RoundRecord>,
    pub evals: Vec<EvalRecord>,
    /// Wall-clock seconds the run took (host time, not virtual).
    pub wall_seconds: f64,
}

impl RunResult {
    pub fn new(scheme: &str, label: &str) -> RunResult {
        RunResult {
            scheme: scheme.to_string(),
            label: label.to_string(),
            ..Default::default()
        }
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.evals.last().map(|e| e.accuracy)
    }

    /// Best accuracy seen at any evaluation.
    pub fn best_accuracy(&self) -> f64 {
        self.evals.iter().map(|e| e.accuracy).fold(0.0, f64::max)
    }

    /// Virtual time to first reach `target` accuracy (T2A; None if never).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.evals
            .iter()
            .find(|e| e.accuracy >= target)
            .map(|e| e.v_time)
    }

    /// Total uploaded payload bytes across the run.
    pub fn total_uploaded(&self) -> usize {
        self.rounds.iter().map(|r| r.uploaded_bytes).sum()
    }

    /// Total realized wire bytes across the run — the true communication
    /// volume Table-4-style comparisons report.
    pub fn total_wire_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.wire_bytes).sum()
    }

    /// Layer-encoding mix summed over every round's folded uploads.
    pub fn encoding_mix(&self) -> EncodingMix {
        let mut mix = EncodingMix::default();
        for r in &self.rounds {
            mix.merge(r.encodings);
        }
        mix
    }

    /// Value-plane mix summed over every round's folded uploads.
    pub fn plane_mix(&self) -> PlaneMix {
        let mut mix = PlaneMix::default();
        for r in &self.rounds {
            mix.merge(r.planes);
        }
        mix
    }

    /// Virtual time at the end of the run (the last round's clock).
    pub fn final_v_time(&self) -> f64 {
        self.rounds.last().map(|r| r.v_time).unwrap_or(0.0)
    }

    /// Virtual-time speedup of this run over a baseline run with the
    /// same round count (e.g. semi-async vs the synchronous barrier):
    /// `baseline_v_time / this_v_time`. Returns 1.0 when either run has
    /// no rounds or zero duration.
    pub fn speedup_vs(&self, baseline: &RunResult) -> f64 {
        let own = self.final_v_time();
        let base = baseline.final_v_time();
        if own <= 0.0 || base <= 0.0 {
            1.0
        } else {
            base / own
        }
    }

    /// Mean per-round straggler count (uploads left in flight at close).
    pub fn mean_stragglers(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.rounds.iter().map(|r| r.stragglers as f64).sum::<f64>()
                / self.rounds.len() as f64
        }
    }

    /// Mean participants (folded uploads) per round.
    pub fn mean_participants(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.rounds.iter().map(|r| r.participants as f64).sum::<f64>()
                / self.rounds.len() as f64
        }
    }

    /// Total uploads dropped by arrival-time churn across the run.
    pub fn total_churned(&self) -> usize {
        self.rounds.iter().map(|r| r.churned).sum()
    }

    /// Mean accuracy of the *final* evaluation over the given rare-class
    /// indices — the §6.7 "generalization to data of rare classes" column
    /// (Fig. 21). `None` when no eval ran or no listed class exists.
    pub fn rare_class_accuracy(&self, rare: &[usize]) -> Option<f64> {
        let e = self.evals.last()?;
        let vals: Vec<f64> = rare
            .iter()
            .filter_map(|&c| e.per_class_accuracy.get(c).copied())
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// Mean staleness over all rounds' folded uploads.
    pub fn mean_staleness(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.rounds.iter().map(|r| r.mean_staleness).sum::<f64>()
                / self.rounds.len() as f64
        }
    }

    /// Peak end-of-round fleet state footprint across the run — the
    /// headline number of the fleet-virtualization benches (gated by
    /// `ci/bench_diff.py` like the `wire_*` totals).
    pub fn peak_client_state_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.client_state_bytes).max().unwrap_or(0)
    }

    /// Final-round fleet state footprint.
    pub fn final_client_state_bytes(&self) -> usize {
        self.rounds.last().map(|r| r.client_state_bytes).unwrap_or(0)
    }

    /// Peak simulation-runtime footprint across the run (gated alongside
    /// the client-state peak by the fleet benches).
    pub fn peak_sim_state_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.sim_state_bytes).max().unwrap_or(0)
    }

    /// Data-plane footprint (constant across rounds; 0 for an empty run).
    pub fn data_state_bytes(&self) -> usize {
        self.rounds.last().map(|r| r.data_state_bytes).unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scheme", Json::s(&self.scheme)),
            ("label", Json::s(&self.label)),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::Num(r.round as f64)),
                                ("v_time", Json::Num(r.v_time)),
                                ("duration", Json::Num(r.duration)),
                                ("train_loss", Json::Num(r.train_loss)),
                                ("uploaded_bytes", Json::Num(r.uploaded_bytes as f64)),
                                ("wire_bytes", Json::Num(r.wire_bytes as f64)),
                                ("enc_dense", Json::Num(r.encodings.dense as f64)),
                                ("enc_bitmap", Json::Num(r.encodings.bitmap as f64)),
                                ("enc_coo", Json::Num(r.encodings.coo as f64)),
                                ("plane_f32", Json::Num(r.planes.f32_layers as f64)),
                                ("plane_f16", Json::Num(r.planes.f16_layers as f64)),
                                ("plane_i8", Json::Num(r.planes.i8_layers as f64)),
                                ("budget_bytes", Json::Num(r.budget_bytes as f64)),
                                ("participants", Json::Num(r.participants as f64)),
                                ("mean_dropout", Json::Num(r.mean_dropout)),
                                ("full_broadcast", Json::Bool(r.full_broadcast)),
                                ("stragglers", Json::Num(r.stragglers as f64)),
                                ("mean_staleness", Json::Num(r.mean_staleness)),
                                ("churned", Json::Num(r.churned as f64)),
                                (
                                    "client_state_bytes",
                                    Json::Num(r.client_state_bytes as f64),
                                ),
                                ("sim_state_bytes", Json::Num(r.sim_state_bytes as f64)),
                                (
                                    "data_state_bytes",
                                    Json::Num(r.data_state_bytes as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("round", Json::Num(e.round as f64)),
                                ("v_time", Json::Num(e.v_time)),
                                ("accuracy", Json::Num(e.accuracy)),
                                ("loss", Json::Num(e.loss)),
                                (
                                    "per_class_accuracy",
                                    Json::arr_f64(&e.per_class_accuracy),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// CSV of the eval curve: round,v_time,accuracy,loss.
    pub fn eval_csv(&self) -> String {
        let mut out = String::from("round,v_time,accuracy,loss\n");
        for e in &self.evals {
            out.push_str(&format!(
                "{},{:.4},{:.4},{:.4}\n",
                e.round, e.v_time, e.accuracy, e.loss
            ));
        }
        out
    }
}

/// Accumulates per-class eval counts streamed over test batches.
#[derive(Clone, Debug, Default)]
pub struct EvalAccumulator {
    pub loss_sum: f64,
    pub correct: Vec<f64>,
    pub count: Vec<f64>,
}

impl EvalAccumulator {
    pub fn new(num_classes: usize) -> Self {
        EvalAccumulator {
            loss_sum: 0.0,
            correct: vec![0.0; num_classes],
            count: vec![0.0; num_classes],
        }
    }

    pub fn add_batch(&mut self, loss_sum: f32, correct: &[f32], count: &[f32]) {
        self.loss_sum += loss_sum as f64;
        for (a, &b) in self.correct.iter_mut().zip(correct) {
            *a += b as f64;
        }
        for (a, &b) in self.count.iter_mut().zip(count) {
            *a += b as f64;
        }
    }

    pub fn total(&self) -> f64 {
        self.count.iter().sum()
    }

    pub fn accuracy(&self) -> f64 {
        let t = self.total();
        if t == 0.0 {
            0.0
        } else {
            self.correct.iter().sum::<f64>() / t
        }
    }

    pub fn mean_loss(&self) -> f64 {
        let t = self.total();
        if t == 0.0 {
            0.0
        } else {
            self.loss_sum / t
        }
    }

    pub fn per_class_accuracy(&self) -> Vec<f64> {
        self.correct
            .iter()
            .zip(&self.count)
            .map(|(&c, &n)| if n == 0.0 { 0.0 } else { c / n })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_run() -> RunResult {
        let mut r = RunResult::new("feddd", "test");
        for i in 0..5 {
            r.rounds.push(RoundRecord {
                round: i,
                v_time: (i + 1) as f64 * 10.0,
                duration: 10.0,
                train_loss: 1.0 / (i + 1) as f64,
                uploaded_bytes: 1000,
                wire_bytes: 900,
                encodings: EncodingMix { dense: 1, bitmap: 2, coo: 0 },
                planes: PlaneMix {
                    f32_layers: 2,
                    f16_layers: 1,
                    i8_layers: 0,
                    f32_bytes: 800,
                    f16_bytes: 100,
                    i8_bytes: 0,
                },
                budget_bytes: 1200,
                participants: 10,
                mean_dropout: 0.4,
                full_broadcast: i % 5 == 0,
                stragglers: i,
                mean_staleness: i as f64 * 0.5,
                churned: i % 2,
                client_state_bytes: 100 * (5 - i),
                sim_state_bytes: 50 + 10 * i,
                data_state_bytes: 7777,
            });
            r.evals.push(EvalRecord {
                round: i,
                v_time: (i + 1) as f64 * 10.0,
                accuracy: 0.2 * (i + 1) as f64,
                loss: 1.0 / (i + 1) as f64,
                per_class_accuracy: vec![0.5; 10],
            });
        }
        r
    }

    #[test]
    fn t2a_finds_first_crossing() {
        let r = sample_run();
        assert_eq!(r.time_to_accuracy(0.4), Some(20.0));
        assert_eq!(r.time_to_accuracy(1.01), None);
        assert_eq!(r.final_accuracy(), Some(1.0));
        assert_eq!(r.best_accuracy(), 1.0);
        assert_eq!(r.total_uploaded(), 5000);
        assert_eq!(r.total_wire_bytes(), 4500);
        assert_eq!(r.encoding_mix(), EncodingMix { dense: 5, bitmap: 10, coo: 0 });
        let planes = r.plane_mix();
        assert_eq!(planes.f32_layers, 10);
        assert_eq!(planes.f16_layers, 5);
        assert_eq!(planes.f32_bytes, 4000);
        assert_eq!(planes.f16_bytes, 500);
        assert_eq!(planes.total_layers(), 15);
    }

    #[test]
    fn staleness_and_speedup_accounting() {
        let r = sample_run();
        // sample_run: stragglers 0..4, mean_staleness 0,0.5,..,2.0,
        // churned 0,1,0,1,0, participants 10 flat
        assert!((r.mean_stragglers() - 2.0).abs() < 1e-12);
        assert!((r.mean_staleness() - 1.0).abs() < 1e-12);
        assert_eq!(r.total_churned(), 2);
        assert!((r.mean_participants() - 10.0).abs() < 1e-12);
        assert_eq!(r.final_v_time(), 50.0);
        let mut faster = sample_run();
        for rec in faster.rounds.iter_mut() {
            rec.v_time /= 2.0;
        }
        assert!((faster.speedup_vs(&r) - 2.0).abs() < 1e-12);
        assert_eq!(RunResult::new("x", "y").speedup_vs(&r), 1.0);
    }

    #[test]
    fn client_state_accounting() {
        let r = sample_run();
        // sample_run: client_state_bytes 500, 400, 300, 200, 100
        assert_eq!(r.peak_client_state_bytes(), 500);
        assert_eq!(r.final_client_state_bytes(), 100);
        assert_eq!(RunResult::new("x", "y").peak_client_state_bytes(), 0);
        let j = r.to_json();
        let round0 = &j.req_arr("rounds").unwrap()[0];
        assert_eq!(
            round0.get("client_state_bytes").and_then(|v| v.as_f64()),
            Some(500.0)
        );
    }

    #[test]
    fn sim_and_data_state_accounting() {
        let r = sample_run();
        // sample_run: sim_state_bytes 50, 60, 70, 80, 90; data 7777 flat
        assert_eq!(r.peak_sim_state_bytes(), 90);
        assert_eq!(r.data_state_bytes(), 7777);
        assert_eq!(RunResult::new("x", "y").peak_sim_state_bytes(), 0);
        assert_eq!(RunResult::new("x", "y").data_state_bytes(), 0);
        let j = r.to_json();
        let round0 = &j.req_arr("rounds").unwrap()[0];
        assert_eq!(
            round0.get("sim_state_bytes").and_then(|v| v.as_f64()),
            Some(50.0)
        );
        assert_eq!(
            round0.get("data_state_bytes").and_then(|v| v.as_f64()),
            Some(7777.0)
        );
    }

    #[test]
    fn json_shape() {
        let j = sample_run().to_json();
        assert_eq!(j.req_str("scheme").unwrap(), "feddd");
        assert_eq!(j.req_arr("rounds").unwrap().len(), 5);
        assert_eq!(j.req_arr("evals").unwrap().len(), 5);
        // round-trips through the parser
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.req_arr("evals").unwrap().len(), 5);
    }

    #[test]
    fn rare_class_accuracy_reads_the_final_eval() {
        let mut r = sample_run();
        // last eval's per-class vector is all 0.5
        assert_eq!(r.rare_class_accuracy(&[0, 1, 2]), Some(0.5));
        r.evals.last_mut().unwrap().per_class_accuracy = vec![0.2, 0.4, 0.9];
        assert!((r.rare_class_accuracy(&[0, 1]).unwrap() - 0.3).abs() < 1e-12);
        // out-of-range classes are skipped; all-missing and empty → None
        assert!((r.rare_class_accuracy(&[2, 99]).unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(r.rare_class_accuracy(&[99]), None);
        assert_eq!(r.rare_class_accuracy(&[]), None);
        assert_eq!(RunResult::new("x", "y").rare_class_accuracy(&[0]), None);
    }

    #[test]
    fn eval_accumulator_accounting() {
        let mut acc = EvalAccumulator::new(3);
        acc.add_batch(3.0, &[1.0, 0.0, 2.0], &[2.0, 1.0, 2.0]);
        acc.add_batch(2.0, &[1.0, 1.0, 0.0], &[1.0, 2.0, 2.0]);
        assert_eq!(acc.total(), 10.0);
        assert!((acc.accuracy() - 0.5).abs() < 1e-12);
        assert!((acc.mean_loss() - 0.5).abs() < 1e-12);
        let pca = acc.per_class_accuracy();
        assert!((pca[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!((pca[1] - 1.0 / 3.0).abs() < 1e-12);
        assert!((pca[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = sample_run().eval_csv();
        assert!(csv.starts_with("round,v_time"));
        assert_eq!(csv.lines().count(), 6);
    }
}
