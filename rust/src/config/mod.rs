//! Typed experiment configuration with JSON round-trip and the paper's
//! presets (Table 4 simulation defaults, Table 5 testbed, plus a
//! CPU-tractable smoke preset used by the default figure harness).

use crate::util::json::{self, Json};

/// Everything one experiment run needs. Field defaults follow Table 4.
#[derive(Clone, Debug, PartialEq)]
pub struct ExpConfig {
    pub seed: u64,
    /// Dataset stand-in: "mnist" | "fmnist" | "cifar10".
    pub dataset: String,
    /// "iid" | "noniid_a" | "noniid_b".
    pub partition: String,
    /// Model family: "mlp" | "cnn1" | "cnn2" | "het_a" | "het_b".
    /// `het_*` assigns sub-models 1..5 round-robin over clients
    /// (model-heterogeneous setting).
    pub model: String,
    /// Width percent of the compiled artifacts (100 = paper-exact).
    pub width_pct: u32,
    pub n_clients: usize,
    pub rounds: usize,
    /// SGD minibatch steps per client per round (paper: local epochs 1/3/5
    /// for MNIST/FMNIST/CIFAR10 over each client's shard).
    pub local_steps: usize,
    /// Train batch (must equal the artifact's compiled batch).
    pub batch: usize,
    pub lr: f32,
    /// "feddd" | "fedavg" | "fedcs" | "oort" | "fed_dropout" | "afd"
    /// (`baselines::SCHEME_NAMES`).
    pub scheme: String,
    /// Upload-parameter selection for FedDD: "importance" | "random" |
    /// "max" | "delta" | "ordered".
    pub selection: String,
    /// D_max (Table 4: 0.8).
    pub d_max: f64,
    /// A_server (Table 4: 0.6) — also the byte budget for the baselines.
    pub a_server: f64,
    /// Penalty factor δ.
    pub delta: f64,
    /// Full-model broadcast period h (Table 4: 5; testbed: 1).
    pub h: usize,
    /// Training samples per client.
    pub train_per_client: usize,
    /// Test set size.
    pub test_n: usize,
    /// "simulated" | "testbed".
    pub fleet: String,
    /// Evaluate the global model every k rounds.
    pub eval_every: usize,
    /// Aggregation backend: "rust" (vectorized loops) | "xla" (the Pallas
    /// masked_acc/masked_fin artifacts).
    pub agg_backend: String,
    /// Class-imbalance (§6.7): rare classes and their sample ratio.
    pub rare_classes: Vec<usize>,
    pub rare_ratio: f64,
    /// Artifact directory (manifest.json + *.hlo.txt).
    pub artifacts_dir: String,
    /// Oort straggler penalty α (paper: 2).
    pub oort_alpha: f64,
    /// Dropout-rate allocation policy for FedDD: "optimal" (Eq. 16/17)
    /// or "uniform" (ablation: every client gets D_n = 1 − A_server).
    pub alloc: String,
    /// Worker threads for the per-client round phases (local training,
    /// mask selection, sharded aggregation). `1` = sequential (default),
    /// `0` = one per available core. The pool is **persistent**: threads
    /// are spawned once per run and reuse per-worker scratch arenas
    /// across micro-batches and rounds (DESIGN.md §Worker-Pool), so a
    /// run's OS thread spawns are O(workers). Results are
    /// bitwise-identical for every worker count (see
    /// `coordinator::engine` and `rust/tests/pool_determinism.rs`).
    pub workers: usize,
    /// Round engine: "sync" (Algorithm 1's barrier, the default — bitwise
    /// identical to the classic engine) | "semi_async" (event-driven
    /// quorum/deadline rounds with staleness-discounted late folds,
    /// DESIGN.md §7).
    pub round_mode: String,
    /// Semi-async arrival quorum as a fraction of in-flight uploads in
    /// (0, 1]: the round closes once `ceil(quorum · in_flight)` arrivals
    /// are in. `1.0` waits for everyone (reduces to sync output).
    pub quorum: f64,
    /// Semi-async round deadline in virtual seconds; the round closes at
    /// the deadline even if the quorum was not met. `0` = no deadline.
    pub deadline_s: f64,
    /// Staleness discount exponent β: a late arrival folded `s` rounds
    /// after dispatch is weighted by `m_n · (1+s)^{-β}`. `0` disables the
    /// discount.
    pub staleness_beta: f64,
    /// Upload wire-codec layout: "auto" (per-layer smallest of dense /
    /// bitmap / COO, the default) or a forced index layout "bitmap" /
    /// "coo" (ablations and benches; dense cannot represent a partial
    /// layer, so it is not forcible).
    pub codec: String,
    /// Upload value plane (DESIGN.md §Codec): "f32" (full precision, the
    /// default — bitwise-identical rounds), "f16" / "i8" (force that
    /// plane on every layer) or "auto" (per layer, the narrowest plane
    /// whose realized quantization error stays ≤ `plane_error · max|v|`).
    pub value_plane: String,
    /// Relative per-layer error bound for `value_plane = "auto"`, as a
    /// fraction of the layer's max |value|. The default 0.005 admits int8
    /// (guaranteed error ≤ max|v|/254); tighter bounds fall back to fp16
    /// and then f32.
    pub plane_error: f64,
    /// Train-set storage: "lazy" (the default — samples are regenerated
    /// on demand from the dataset seed, O(prototypes) resident) or
    /// "eager" (materialize every sample up front; A/B toggle for the
    /// lazy-vs-eager equivalence sweeps).
    pub data_mode: String,
    /// Maximum live snapshots the ring may pin under semi-async straggler
    /// tails before the engine evicts the oldest round's dependents
    /// (DESIGN.md §Fleet-Virtualization). `0` = uncapped.
    pub snapshot_ring_cap: usize,
    /// Client-availability trace (DESIGN.md §Scenario-Matrix): "none"
    /// (every client reachable, the default), "diurnal" (a rolling half
    /// of the fleet is offline, phase-shifted per client), "flash_crowd"
    /// (only a ~10% vanguard is online until `trace_period_s`, then
    /// everyone joins at once) or "churn" (every client reachable, but
    /// each in-flight upload may drop mid-round — see `churn_rate`). All
    /// traces are pure functions of (client, virtual time, seed), so runs
    /// stay bitwise-reproducible for every worker count.
    pub trace: String,
    /// Period of the availability trace in virtual seconds: the diurnal
    /// day length, or the flash-crowd arrival instant.
    pub trace_period_s: f64,
    /// Probability that a dispatched upload churns (connection drops at
    /// arrival time; the upload is discarded, the client keeps its
    /// pre-dispatch state and reconnects idle). Only consulted when
    /// `trace = "churn"` under `round_mode = "semi_async"`; decided by a
    /// pure hash of (seed, client, dispatch round).
    pub churn_rate: f64,
    /// `feddd serve` listen address, `host:port` (DESIGN.md §Serve).
    /// Port 0 binds an ephemeral port (the resolved address is printed
    /// and written to `<out>/serve_addr.txt` for agents to pick up).
    pub listen: String,
    /// Maximum agent connections `feddd serve` accepts; a connection
    /// beyond this is refused during the handshake.
    pub max_conns: usize,
    /// Bound of the serve-mode ingest queue, in decoded uploads: the
    /// per-connection reader threads block once this many uploads are
    /// waiting to be folded, so a slow server exerts TCP backpressure on
    /// its agents instead of buffering unboundedly (DESIGN.md §Serve).
    pub ingest_queue: usize,
    /// Uniform server-chosen dropout rate for `scheme = "fed_dropout"`
    /// (Caldas-style random federated dropout), and the initial rate AFD
    /// anneals from. In [0, 1); 0 reproduces `fedavg` byte-for-byte.
    pub fd_rate: f64,
    /// EMA decay β of `scheme = "afd"`'s per-unit activation-score map:
    /// `score ← β·score + (1−β)·importance`. In [0, 1); higher = a
    /// longer memory of which units mattered.
    pub afd_ema: f64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            seed: 17,
            dataset: "mnist".into(),
            partition: "iid".into(),
            model: "mlp".into(),
            width_pct: 100,
            n_clients: 100,
            rounds: 100,
            local_steps: 2,
            batch: 16,
            lr: 0.05,
            scheme: "feddd".into(),
            selection: "importance".into(),
            d_max: 0.8,
            a_server: 0.6,
            delta: 1.0,
            h: 5,
            train_per_client: 200,
            test_n: 1000,
            fleet: "simulated".into(),
            eval_every: 1,
            agg_backend: "rust".into(),
            rare_classes: Vec::new(),
            rare_ratio: 1.0,
            artifacts_dir: "artifacts".into(),
            oort_alpha: 2.0,
            alloc: "optimal".into(),
            workers: 1,
            round_mode: "sync".into(),
            quorum: 0.7,
            deadline_s: 0.0,
            staleness_beta: 0.5,
            codec: "auto".into(),
            value_plane: "f32".into(),
            plane_error: 0.005,
            data_mode: "lazy".into(),
            snapshot_ring_cap: 0,
            trace: "none".into(),
            trace_period_s: 600.0,
            churn_rate: 0.0,
            listen: "127.0.0.1:7070".into(),
            max_conns: 64,
            ingest_queue: 64,
            fd_rate: 0.5,
            afd_ema: 0.9,
        }
    }
}

impl ExpConfig {
    /// Table 4 lab-simulation preset (100 clients).
    pub fn table4() -> ExpConfig {
        ExpConfig::default()
    }

    /// CPU-tractable smoke preset (the figure harness default).
    pub fn smoke() -> ExpConfig {
        ExpConfig {
            n_clients: 10,
            rounds: 30,
            local_steps: 4,
            train_per_client: 120,
            test_n: 400,
            ..ExpConfig::default()
        }
    }

    /// Large-fleet preset (DESIGN.md §Fleet-Virtualization): the
    /// virtualized-client-state configuration the fleet benches and the
    /// CI fleet smoke run. Fleet size is the `n_clients` knob — override
    /// it (`--n_clients 50000`) to sweep scale. Defaults keep a round
    /// CPU-tractable at 10k–50k clients: a width-25% MLP, one local step
    /// on a small batch, tiny per-client shards, and the testbed's `h=1`
    /// (full broadcast every round — every client collapses to `Synced`,
    /// so per-client state stays at zero between rounds).
    pub fn fleet() -> ExpConfig {
        ExpConfig {
            n_clients: 10_000,
            rounds: 2,
            local_steps: 1,
            batch: 8,
            width_pct: 25,
            train_per_client: 8,
            test_n: 128,
            h: 1,
            eval_every: 2,
            workers: 0,
            ..ExpConfig::default()
        }
    }

    /// Table 5 geo-testbed preset: 10 clients, h=1, CNN2/CIFAR10.
    pub fn testbed() -> ExpConfig {
        ExpConfig {
            n_clients: 10,
            fleet: "testbed".into(),
            dataset: "cifar10".into(),
            model: "cnn2".into(),
            h: 1,
            rounds: 40,
            local_steps: 3,
            lr: 0.02,
            train_per_client: 150,
            test_n: 400,
            ..ExpConfig::default()
        }
    }

    pub fn preset(name: &str) -> anyhow::Result<ExpConfig> {
        match name {
            "table4" | "paper" => Ok(Self::table4()),
            "smoke" => Ok(Self::smoke()),
            "testbed" => Ok(Self::testbed()),
            "fleet" => Ok(Self::fleet()),
            _ => anyhow::bail!("unknown preset {name:?} (table4|smoke|testbed|fleet)"),
        }
    }

    /// The model family is heterogeneous (sub-models 1..5 over clients)?
    pub fn is_hetero(&self) -> bool {
        self.model == "het_a" || self.model == "het_b"
    }

    /// Model name for client `n` under this config.
    pub fn client_model_name(&self, n: usize) -> String {
        if self.is_hetero() {
            format!("{}_{}", self.model, n % 5 + 1)
        } else {
            self.model.clone()
        }
    }

    /// Sanity checks (bounds, known enum strings, LP feasibility).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_clients > 0, "n_clients must be > 0");
        anyhow::ensure!(self.rounds > 0, "rounds must be > 0");
        anyhow::ensure!((0.0..1.0).contains(&self.d_max), "d_max in [0,1)");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.a_server),
            "a_server in (0,1]"
        );
        anyhow::ensure!(
            self.a_server >= 1.0 - self.d_max - 1e-9,
            "infeasible: a_server {} < 1 - d_max {}",
            self.a_server,
            1.0 - self.d_max
        );
        anyhow::ensure!(self.h >= 1, "h >= 1");
        anyhow::ensure!(
            ["mnist", "fmnist", "cifar10"].contains(&self.dataset.as_str()),
            "unknown dataset {:?}",
            self.dataset
        );
        anyhow::ensure!(
            ["iid", "noniid_a", "noniid_b"].contains(&self.partition.as_str()),
            "unknown partition {:?}",
            self.partition
        );
        anyhow::ensure!(
            crate::baselines::SCHEME_NAMES.contains(&self.scheme.as_str()),
            "unknown scheme {:?} (one of {:?})",
            self.scheme,
            crate::baselines::SCHEME_NAMES
        );
        anyhow::ensure!(
            ["importance", "random", "max", "delta", "ordered"]
                .contains(&self.selection.as_str()),
            "unknown selection {:?}",
            self.selection
        );
        anyhow::ensure!(
            ["rust", "xla"].contains(&self.agg_backend.as_str()),
            "unknown agg_backend {:?}",
            self.agg_backend
        );
        anyhow::ensure!(
            ["optimal", "uniform"].contains(&self.alloc.as_str()),
            "unknown alloc policy {:?}",
            self.alloc
        );
        anyhow::ensure!(
            self.workers <= 1024,
            "workers {} out of range (0 = auto, else ≤ 1024)",
            self.workers
        );
        anyhow::ensure!(
            ["sync", "semi_async"].contains(&self.round_mode.as_str()),
            "unknown round_mode {:?} (sync|semi_async)",
            self.round_mode
        );
        anyhow::ensure!(
            self.quorum > 0.0 && self.quorum <= 1.0,
            "quorum {} must be in (0, 1]",
            self.quorum
        );
        anyhow::ensure!(
            self.deadline_s.is_finite() && self.deadline_s >= 0.0,
            "deadline_s {} must be finite and >= 0 (0 = none)",
            self.deadline_s
        );
        anyhow::ensure!(
            self.staleness_beta.is_finite() && self.staleness_beta >= 0.0,
            "staleness_beta {} must be finite and >= 0",
            self.staleness_beta
        );
        anyhow::ensure!(
            ["auto", "bitmap", "coo"].contains(&self.codec.as_str()),
            "unknown codec {:?} (auto|bitmap|coo)",
            self.codec
        );
        anyhow::ensure!(
            ["f32", "f16", "i8", "auto"].contains(&self.value_plane.as_str()),
            "unknown value_plane {:?} (f32|f16|i8|auto)",
            self.value_plane
        );
        anyhow::ensure!(
            self.plane_error.is_finite() && self.plane_error >= 0.0,
            "plane_error {} must be finite and >= 0",
            self.plane_error
        );
        anyhow::ensure!(
            ["lazy", "eager"].contains(&self.data_mode.as_str()),
            "unknown data_mode {:?} (lazy|eager)",
            self.data_mode
        );
        anyhow::ensure!(
            self.snapshot_ring_cap == 0 || self.snapshot_ring_cap >= 2,
            "snapshot_ring_cap {} must be 0 (uncapped) or >= 2 (the \
             current and previous rounds are always momentarily live)",
            self.snapshot_ring_cap
        );
        anyhow::ensure!(
            ["none", "diurnal", "flash_crowd", "churn"].contains(&self.trace.as_str()),
            "unknown trace {:?} (none|diurnal|flash_crowd|churn)",
            self.trace
        );
        anyhow::ensure!(
            self.trace_period_s.is_finite() && self.trace_period_s > 0.0,
            "trace_period_s {} must be finite and > 0",
            self.trace_period_s
        );
        anyhow::ensure!(
            self.churn_rate.is_finite() && (0.0..1.0).contains(&self.churn_rate),
            "churn_rate {} must be in [0, 1)",
            self.churn_rate
        );
        anyhow::ensure!(
            self.listen.contains(':'),
            "listen {:?} must be a host:port address",
            self.listen
        );
        anyhow::ensure!(
            (1..=4096).contains(&self.max_conns),
            "max_conns {} must be in 1..=4096",
            self.max_conns
        );
        anyhow::ensure!(
            (1..=65536).contains(&self.ingest_queue),
            "ingest_queue {} must be in 1..=65536",
            self.ingest_queue
        );
        anyhow::ensure!(
            self.fd_rate.is_finite() && (0.0..1.0).contains(&self.fd_rate),
            "fd_rate {} must be in [0, 1)",
            self.fd_rate
        );
        anyhow::ensure!(
            self.afd_ema.is_finite() && (0.0..1.0).contains(&self.afd_ema),
            "afd_ema {} must be in [0, 1)",
            self.afd_ema
        );
        let known_family =
            ["mlp", "cnn1", "cnn2", "het_a", "het_b"].contains(&self.model.as_str());
        // Specific sub-models (e.g. "het_a_3") run homogeneously (Fig. 3).
        let known_specific = crate::model::ModelSpec::get(&self.model, 1.0).is_ok();
        anyhow::ensure!(
            known_family || known_specific,
            "unknown model family {:?}",
            self.model
        );
        Ok(())
    }

    // ---------------- JSON ----------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("dataset", Json::s(&self.dataset)),
            ("partition", Json::s(&self.partition)),
            ("model", Json::s(&self.model)),
            ("width_pct", Json::Num(self.width_pct as f64)),
            ("n_clients", Json::Num(self.n_clients as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("local_steps", Json::Num(self.local_steps as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("scheme", Json::s(&self.scheme)),
            ("selection", Json::s(&self.selection)),
            ("d_max", Json::Num(self.d_max)),
            ("a_server", Json::Num(self.a_server)),
            ("delta", Json::Num(self.delta)),
            ("h", Json::Num(self.h as f64)),
            ("train_per_client", Json::Num(self.train_per_client as f64)),
            ("test_n", Json::Num(self.test_n as f64)),
            ("fleet", Json::s(&self.fleet)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("agg_backend", Json::s(&self.agg_backend)),
            ("rare_classes", Json::arr_usize(&self.rare_classes)),
            ("rare_ratio", Json::Num(self.rare_ratio)),
            ("artifacts_dir", Json::s(&self.artifacts_dir)),
            ("oort_alpha", Json::Num(self.oort_alpha)),
            ("alloc", Json::s(&self.alloc)),
            ("workers", Json::Num(self.workers as f64)),
            ("round_mode", Json::s(&self.round_mode)),
            ("quorum", Json::Num(self.quorum)),
            ("deadline_s", Json::Num(self.deadline_s)),
            ("staleness_beta", Json::Num(self.staleness_beta)),
            ("codec", Json::s(&self.codec)),
            ("value_plane", Json::s(&self.value_plane)),
            ("plane_error", Json::Num(self.plane_error)),
            ("data_mode", Json::s(&self.data_mode)),
            ("snapshot_ring_cap", Json::Num(self.snapshot_ring_cap as f64)),
            ("trace", Json::s(&self.trace)),
            ("trace_period_s", Json::Num(self.trace_period_s)),
            ("churn_rate", Json::Num(self.churn_rate)),
            ("listen", Json::s(&self.listen)),
            ("max_conns", Json::Num(self.max_conns as f64)),
            ("ingest_queue", Json::Num(self.ingest_queue as f64)),
            ("fd_rate", Json::Num(self.fd_rate)),
            ("afd_ema", Json::Num(self.afd_ema)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ExpConfig> {
        let d = ExpConfig::default();
        let gs = |k: &str, dv: &str| -> String {
            j.get(k).and_then(|v| v.as_str()).unwrap_or(dv).to_string()
        };
        let gn = |k: &str, dv: f64| -> f64 {
            j.get(k).and_then(|v| v.as_f64()).unwrap_or(dv)
        };
        let cfg = ExpConfig {
            seed: gn("seed", d.seed as f64) as u64,
            dataset: gs("dataset", &d.dataset),
            partition: gs("partition", &d.partition),
            model: gs("model", &d.model),
            width_pct: gn("width_pct", d.width_pct as f64) as u32,
            n_clients: gn("n_clients", d.n_clients as f64) as usize,
            rounds: gn("rounds", d.rounds as f64) as usize,
            local_steps: gn("local_steps", d.local_steps as f64) as usize,
            batch: gn("batch", d.batch as f64) as usize,
            lr: gn("lr", d.lr as f64) as f32,
            scheme: gs("scheme", &d.scheme),
            selection: gs("selection", &d.selection),
            d_max: gn("d_max", d.d_max),
            a_server: gn("a_server", d.a_server),
            delta: gn("delta", d.delta),
            h: gn("h", d.h as f64) as usize,
            train_per_client: gn("train_per_client", d.train_per_client as f64)
                as usize,
            test_n: gn("test_n", d.test_n as f64) as usize,
            fleet: gs("fleet", &d.fleet),
            eval_every: gn("eval_every", d.eval_every as f64) as usize,
            agg_backend: gs("agg_backend", &d.agg_backend),
            rare_classes: j
                .get("rare_classes")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            rare_ratio: gn("rare_ratio", d.rare_ratio),
            artifacts_dir: gs("artifacts_dir", &d.artifacts_dir),
            oort_alpha: gn("oort_alpha", d.oort_alpha),
            alloc: gs("alloc", &d.alloc),
            workers: gn("workers", d.workers as f64) as usize,
            round_mode: gs("round_mode", &d.round_mode),
            quorum: gn("quorum", d.quorum),
            deadline_s: gn("deadline_s", d.deadline_s),
            staleness_beta: gn("staleness_beta", d.staleness_beta),
            codec: gs("codec", &d.codec),
            value_plane: gs("value_plane", &d.value_plane),
            plane_error: gn("plane_error", d.plane_error),
            data_mode: gs("data_mode", &d.data_mode),
            snapshot_ring_cap: gn("snapshot_ring_cap", d.snapshot_ring_cap as f64)
                as usize,
            trace: gs("trace", &d.trace),
            trace_period_s: gn("trace_period_s", d.trace_period_s),
            churn_rate: gn("churn_rate", d.churn_rate),
            listen: gs("listen", &d.listen),
            max_conns: gn("max_conns", d.max_conns as f64) as usize,
            ingest_queue: gn("ingest_queue", d.ingest_queue as f64) as usize,
            fd_rate: gn("fd_rate", d.fd_rate),
            afd_ema: gn("afd_ema", d.afd_ema),
        };
        Ok(cfg)
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        json::to_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<ExpConfig> {
        Self::from_json(&json::from_file(path)?)
    }

    /// Apply a `--key value` style override (used by the CLI).
    pub fn set(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "seed" => self.seed = value.parse()?,
            "dataset" => self.dataset = value.into(),
            "partition" => self.partition = value.into(),
            "model" => self.model = value.into(),
            "width_pct" => self.width_pct = value.parse()?,
            "n_clients" => self.n_clients = value.parse()?,
            "rounds" => self.rounds = value.parse()?,
            "local_steps" => self.local_steps = value.parse()?,
            "batch" => self.batch = value.parse()?,
            "lr" => self.lr = value.parse()?,
            "scheme" => self.scheme = value.into(),
            "selection" => self.selection = value.into(),
            "d_max" => self.d_max = value.parse()?,
            "a_server" => self.a_server = value.parse()?,
            "delta" => self.delta = value.parse()?,
            "h" => self.h = value.parse()?,
            "train_per_client" => self.train_per_client = value.parse()?,
            "test_n" => self.test_n = value.parse()?,
            "fleet" => self.fleet = value.into(),
            "eval_every" => self.eval_every = value.parse()?,
            "agg_backend" => self.agg_backend = value.into(),
            "rare_ratio" => self.rare_ratio = value.parse()?,
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "oort_alpha" => self.oort_alpha = value.parse()?,
            "alloc" => self.alloc = value.into(),
            "workers" => self.workers = value.parse()?,
            "round_mode" => self.round_mode = value.into(),
            "quorum" => self.quorum = value.parse()?,
            "deadline_s" => self.deadline_s = value.parse()?,
            "staleness_beta" => self.staleness_beta = value.parse()?,
            "codec" => self.codec = value.into(),
            "value_plane" => self.value_plane = value.into(),
            "plane_error" => self.plane_error = value.parse()?,
            "data_mode" => self.data_mode = value.into(),
            "snapshot_ring_cap" => self.snapshot_ring_cap = value.parse()?,
            "trace" => self.trace = value.into(),
            "trace_period_s" => self.trace_period_s = value.parse()?,
            "churn_rate" => self.churn_rate = value.parse()?,
            "listen" => self.listen = value.into(),
            "max_conns" => self.max_conns = value.parse()?,
            "ingest_queue" => self.ingest_queue = value.parse()?,
            "fd_rate" => self.fd_rate = value.parse()?,
            "afd_ema" => self.afd_ema = value.parse()?,
            "rare_classes" => {
                self.rare_classes = value
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse())
                    .collect::<Result<_, _>>()?;
            }
            _ => anyhow::bail!("unknown config key {key:?}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table4() {
        let c = ExpConfig::table4();
        assert_eq!(c.n_clients, 100);
        assert_eq!(c.d_max, 0.8);
        assert_eq!(c.a_server, 0.6);
        assert_eq!(c.h, 5);
        c.validate().unwrap();
    }

    #[test]
    fn testbed_matches_table5_text() {
        let c = ExpConfig::testbed();
        assert_eq!(c.n_clients, 10);
        assert_eq!(c.h, 1);
        assert_eq!(c.model, "cnn2");
        assert_eq!(c.dataset, "cifar10");
        c.validate().unwrap();
    }

    #[test]
    fn fleet_preset_is_large_and_broadcast_heavy() {
        let c = ExpConfig::preset("fleet").unwrap();
        assert_eq!(c.n_clients, 10_000);
        assert_eq!(c.h, 1, "fleet preset must broadcast every round");
        assert_eq!(c.width_pct, 25);
        c.validate().unwrap();
        // the fleet size knob is n_clients
        let mut big = ExpConfig::fleet();
        big.set("n_clients", "50000").unwrap();
        assert_eq!(big.n_clients, 50_000);
        big.validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExpConfig::smoke();
        c.rare_classes = vec![0, 1, 2];
        c.rare_ratio = 0.4;
        c.scheme = "oort".into();
        let j = c.to_json();
        let back = ExpConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn validate_rejects_infeasible_budget() {
        let c = ExpConfig { d_max: 0.2, a_server: 0.5, ..ExpConfig::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_unknown_strings() {
        let c = ExpConfig { scheme: "sgd".into(), ..ExpConfig::default() };
        assert!(c.validate().is_err());
        let c = ExpConfig { partition: "dirichlet".into(), ..ExpConfig::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn set_overrides() {
        let mut c = ExpConfig::default();
        c.set("rounds", "7").unwrap();
        c.set("scheme", "fedcs").unwrap();
        c.set("rare_classes", "0,3,5").unwrap();
        c.set("workers", "4").unwrap();
        assert_eq!(c.rounds, 7);
        assert_eq!(c.scheme, "fedcs");
        assert_eq!(c.rare_classes, vec![0, 3, 5]);
        assert_eq!(c.workers, 4);
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn workers_roundtrips_and_validates() {
        let mut c = ExpConfig::smoke();
        c.workers = 8;
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.workers, 8);
        c.validate().unwrap();
        c.workers = 0; // auto
        c.validate().unwrap();
        c.workers = 100_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn round_mode_knobs_roundtrip_and_validate() {
        let mut c = ExpConfig::smoke();
        assert_eq!(c.round_mode, "sync"); // sync stays the default
        c.round_mode = "semi_async".into();
        c.quorum = 0.7;
        c.deadline_s = 120.0;
        c.staleness_beta = 1.5;
        c.validate().unwrap();
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        c.set("round_mode", "sync").unwrap();
        c.set("quorum", "0.9").unwrap();
        c.set("deadline_s", "30.5").unwrap();
        c.set("staleness_beta", "0.25").unwrap();
        assert_eq!(c.round_mode, "sync");
        assert_eq!(c.quorum, 0.9);
        assert_eq!(c.deadline_s, 30.5);
        assert_eq!(c.staleness_beta, 0.25);
    }

    #[test]
    fn codec_knob_roundtrips_and_validates() {
        let mut c = ExpConfig::smoke();
        assert_eq!(c.codec, "auto"); // auto-pick stays the default
        c.set("codec", "coo").unwrap();
        assert_eq!(c.codec, "coo");
        c.validate().unwrap();
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.codec, "coo");
        c.codec = "bitmap".into();
        c.validate().unwrap();
        c.codec = "dense".into(); // dense cannot represent partial layers
        assert!(c.validate().is_err());
        c.codec = "gzip".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn plane_knobs_roundtrip_and_validate() {
        let mut c = ExpConfig::smoke();
        assert_eq!(c.value_plane, "f32"); // full precision stays the default
        assert_eq!(c.plane_error, 0.005);
        c.set("value_plane", "auto").unwrap();
        c.set("plane_error", "0.001").unwrap();
        c.validate().unwrap();
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.value_plane, "auto");
        assert_eq!(back.plane_error, 0.001);
        for p in ["f16", "i8", "f32"] {
            c.value_plane = p.into();
            c.validate().unwrap();
        }
        c.value_plane = "f64".into();
        assert!(c.validate().is_err());
        c.value_plane = "auto".into();
        c.plane_error = -0.1;
        assert!(c.validate().is_err());
        c.plane_error = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn virtualization_knobs_roundtrip_and_validate() {
        let mut c = ExpConfig::smoke();
        assert_eq!(c.data_mode, "lazy"); // virtual train store is the default
        assert_eq!(c.snapshot_ring_cap, 0); // uncapped by default
        c.set("data_mode", "eager").unwrap();
        c.set("snapshot_ring_cap", "3").unwrap();
        assert_eq!(c.data_mode, "eager");
        assert_eq!(c.snapshot_ring_cap, 3);
        c.validate().unwrap();
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        c.data_mode = "mmap".into();
        assert!(c.validate().is_err());
        c.data_mode = "lazy".into();
        c.snapshot_ring_cap = 1; // can't hold current + previous round
        assert!(c.validate().is_err());
        c.snapshot_ring_cap = 2;
        c.validate().unwrap();
    }

    #[test]
    fn round_mode_knobs_reject_bad_values() {
        let c = ExpConfig { round_mode: "async".into(), ..ExpConfig::default() };
        assert!(c.validate().is_err());
        let c = ExpConfig { quorum: 0.0, ..ExpConfig::default() };
        assert!(c.validate().is_err());
        let c = ExpConfig { quorum: 1.2, ..ExpConfig::default() };
        assert!(c.validate().is_err());
        let c = ExpConfig { deadline_s: -1.0, ..ExpConfig::default() };
        assert!(c.validate().is_err());
        let c = ExpConfig { staleness_beta: f64::NAN, ..ExpConfig::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn trace_knobs_roundtrip_and_validate() {
        let mut c = ExpConfig::smoke();
        assert_eq!(c.trace, "none"); // every client reachable by default
        assert_eq!(c.churn_rate, 0.0);
        c.set("trace", "diurnal").unwrap();
        c.set("trace_period_s", "900").unwrap();
        c.validate().unwrap();
        c.set("trace", "churn").unwrap();
        c.set("churn_rate", "0.2").unwrap();
        c.round_mode = "semi_async".into();
        c.validate().unwrap();
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        c.trace = "weekend".into();
        assert!(c.validate().is_err());
        c.trace = "flash_crowd".into();
        c.trace_period_s = 0.0;
        assert!(c.validate().is_err());
        c.trace_period_s = 600.0;
        c.churn_rate = 1.0; // every upload dropping can never converge
        assert!(c.validate().is_err());
        c.churn_rate = -0.1;
        assert!(c.validate().is_err());
        c.churn_rate = 0.999;
        c.validate().unwrap();
    }

    #[test]
    fn serve_knobs_roundtrip_and_validate() {
        let mut c = ExpConfig::smoke();
        assert_eq!(c.listen, "127.0.0.1:7070"); // loopback stays the default
        assert_eq!(c.max_conns, 64);
        assert_eq!(c.ingest_queue, 64);
        c.set("listen", "0.0.0.0:9000").unwrap();
        c.set("max_conns", "8").unwrap();
        c.set("ingest_queue", "256").unwrap();
        assert_eq!(c.listen, "0.0.0.0:9000");
        assert_eq!(c.max_conns, 8);
        assert_eq!(c.ingest_queue, 256);
        c.validate().unwrap();
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        c.listen = "no-port-here".into();
        assert!(c.validate().is_err());
        c.listen = "127.0.0.1:0".into(); // ephemeral port is fine
        c.validate().unwrap();
        c.max_conns = 0;
        assert!(c.validate().is_err());
        c.max_conns = 5000;
        assert!(c.validate().is_err());
        c.max_conns = 64;
        c.ingest_queue = 0; // an unbounded (or zero-capacity) queue is never valid
        assert!(c.validate().is_err());
        c.ingest_queue = 1 << 20;
        assert!(c.validate().is_err());
    }

    #[test]
    fn dropout_family_knobs_roundtrip_and_validate() {
        let mut c = ExpConfig::smoke();
        assert_eq!(c.fd_rate, 0.5);
        assert_eq!(c.afd_ema, 0.9);
        c.set("scheme", "fed_dropout").unwrap();
        c.set("fd_rate", "0.25").unwrap();
        c.set("afd_ema", "0.8").unwrap();
        c.validate().unwrap();
        let back = ExpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        c.scheme = "afd".into();
        c.fd_rate = 0.0; // rate 0 is the fedavg-equivalence point
        c.validate().unwrap();
        for bad in [-0.1, 1.0, f64::NAN] {
            c.fd_rate = bad;
            assert!(c.validate().is_err(), "fd_rate {bad} must be rejected");
        }
        c.fd_rate = 0.5;
        for bad in [-0.1, 1.0, f64::NAN] {
            c.afd_ema = bad;
            assert!(c.validate().is_err(), "afd_ema {bad} must be rejected");
        }
    }

    #[test]
    fn hetero_client_model_assignment() {
        let mut c = ExpConfig::default();
        c.model = "het_a".into();
        assert!(c.is_hetero());
        assert_eq!(c.client_model_name(0), "het_a_1");
        assert_eq!(c.client_model_name(4), "het_a_5");
        assert_eq!(c.client_model_name(5), "het_a_1");
        c.model = "mlp".into();
        assert_eq!(c.client_model_name(3), "mlp");
    }
}
