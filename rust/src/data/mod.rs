//! Data substrate: synthetic class-conditional datasets standing in for
//! MNIST / FMNIST / CIFAR10 (offline image — see DESIGN.md §3), plus the
//! paper's three heterogeneity partitions (IID, Non-IID-a, Non-IID-b) and
//! the class-imbalanced global dataset of §6.7.

mod partition;
mod synth;

pub use partition::*;
pub use synth::*;

/// A federated dataset: flattened train/test tensors plus labels.
#[derive(Clone, Debug)]
pub struct FedDataset {
    /// Per-sample input shape (e.g. `[784]` or `[3, 32, 32]`).
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl FedDataset {
    pub fn sample_dim(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    pub fn train_sample(&self, i: usize) -> &[f32] {
        let d = self.sample_dim();
        &self.train_x[i * d..(i + 1) * d]
    }

    pub fn test_sample(&self, i: usize) -> &[f32] {
        let d = self.sample_dim();
        &self.test_x[i * d..(i + 1) * d]
    }

    /// Gather a training batch into a contiguous buffer.
    pub fn gather_train(&self, idxs: &[usize], x_out: &mut Vec<f32>, y_out: &mut Vec<i32>) {
        let d = self.sample_dim();
        x_out.clear();
        y_out.clear();
        x_out.reserve(idxs.len() * d);
        for &i in idxs {
            x_out.extend_from_slice(self.train_sample(i));
            y_out.push(self.train_y[i]);
        }
    }

    /// Label histogram of the full training set.
    pub fn train_class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_classes];
        for &y in &self.train_y {
            counts[y as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gather_train_layout() {
        let mut rng = Rng::new(0);
        let ds = SynthSpec::mnist_like().generate(100, 20, &mut rng);
        let mut x = Vec::new();
        let mut y = Vec::new();
        ds.gather_train(&[3, 7], &mut x, &mut y);
        assert_eq!(x.len(), 2 * 784);
        assert_eq!(&x[..784], ds.train_sample(3));
        assert_eq!(y, vec![ds.train_y[3], ds.train_y[7]]);
    }
}
