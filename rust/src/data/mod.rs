//! Data substrate: synthetic class-conditional datasets standing in for
//! MNIST / FMNIST / CIFAR10 (offline image — see DESIGN.md §3), plus the
//! paper's three heterogeneity partitions (IID, Non-IID-a, Non-IID-b) and
//! the class-imbalanced global dataset of §6.7.
//!
//! The train store is virtualized for large fleets: `data_mode = "lazy"`
//! (the default) keeps only a [`SynthGen`] — prototypes + apportionment +
//! seed — and regenerates samples on demand straight into the caller's
//! batch buffer; `"eager"` materializes the same bytes up front (A/B
//! toggle). Both paths run through [`SynthGen::sample_into`], so the
//! sample stream is byte-identical by construction.

mod partition;
mod synth;

pub use partition::*;
pub use synth::*;

/// Training-sample storage: materialized tensors or the virtual
/// generator. Private — everything reads through the [`FedDataset`]
/// accessors, which is what makes the representations interchangeable.
#[derive(Clone, Debug)]
enum TrainStore {
    Eager { x: Vec<f32>, y: Vec<i32> },
    Lazy { synth: SynthGen },
}

/// A federated dataset: train store + flattened test tensors and labels.
#[derive(Clone, Debug)]
pub struct FedDataset {
    /// Per-sample input shape (e.g. `[784]` or `[3, 32, 32]`).
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    train: TrainStore,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl FedDataset {
    pub fn sample_dim(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn train_len(&self) -> usize {
        match &self.train {
            TrainStore::Eager { y, .. } => y.len(),
            TrainStore::Lazy { synth } => synth.len(),
        }
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    /// Whether the train store is the virtual (regenerate-on-demand)
    /// representation.
    pub fn is_lazy(&self) -> bool {
        matches!(self.train, TrainStore::Lazy { .. })
    }

    /// Label of training sample `i` — O(1) in both representations.
    pub fn train_label(&self, i: usize) -> i32 {
        match &self.train {
            TrainStore::Eager { y, .. } => y[i],
            TrainStore::Lazy { synth } => synth.label_of(i),
        }
    }

    /// Borrow a materialized training sample. Only the eager store can
    /// hand out a slice; lazy readers go through [`Self::gather_train`].
    pub fn train_sample(&self, i: usize) -> &[f32] {
        let d = self.sample_dim();
        match &self.train {
            TrainStore::Eager { x, .. } => &x[i * d..(i + 1) * d],
            TrainStore::Lazy { .. } => {
                panic!("train_sample: lazy train store has no resident samples")
            }
        }
    }

    pub fn test_sample(&self, i: usize) -> &[f32] {
        let d = self.sample_dim();
        &self.test_x[i * d..(i + 1) * d]
    }

    /// Gather a training batch into a contiguous buffer: copied from the
    /// eager store, or regenerated straight into `x_out` by the lazy one
    /// (no intermediate allocation either way).
    pub fn gather_train(&self, idxs: &[usize], x_out: &mut Vec<f32>, y_out: &mut Vec<i32>) {
        let d = self.sample_dim();
        x_out.clear();
        y_out.clear();
        match &self.train {
            TrainStore::Eager { x, y } => {
                x_out.reserve(idxs.len() * d);
                for &i in idxs {
                    x_out.extend_from_slice(&x[i * d..(i + 1) * d]);
                    y_out.push(y[i]);
                }
            }
            TrainStore::Lazy { synth } => {
                x_out.resize(idxs.len() * d, 0.0);
                for (k, &i) in idxs.iter().enumerate() {
                    y_out.push(synth.sample_into(i, &mut x_out[k * d..(k + 1) * d]));
                }
            }
        }
    }

    /// Label histogram of the full training set (exact in both
    /// representations; the lazy store answers from its apportionment
    /// without generating anything).
    pub fn train_class_counts(&self) -> Vec<usize> {
        match &self.train {
            TrainStore::Eager { y, .. } => {
                let mut counts = vec![0usize; self.num_classes];
                for &v in y {
                    counts[v as usize] += 1;
                }
                counts
            }
            TrainStore::Lazy { synth } => synth.class_counts(),
        }
    }

    /// Resident heap bytes of the dataset: train store + test tensors.
    /// This is the `data_state_bytes` term of the fleet memory audit —
    /// for the lazy store it is O(prototypes), independent of
    /// `train_len()`.
    pub fn mem_bytes(&self) -> usize {
        let train = match &self.train {
            TrainStore::Eager { x, y } => x.len() * 4 + y.len() * 4,
            TrainStore::Lazy { synth } => synth.mem_bytes(),
        };
        train
            + self.test_x.len() * 4
            + self.test_y.len() * 4
            + self.input_shape.len() * std::mem::size_of::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn gather_train_layout() {
        let mut rng = Rng::new(0);
        let ds = SynthSpec::mnist_like().generate(100, 20, &mut rng);
        let mut x = Vec::new();
        let mut y = Vec::new();
        ds.gather_train(&[3, 7], &mut x, &mut y);
        assert_eq!(x.len(), 2 * 784);
        assert_eq!(&x[..784], ds.train_sample(3));
        assert_eq!(y, vec![ds.train_label(3), ds.train_label(7)]);
    }

    /// Lazy and eager stores must be indistinguishable through every
    /// accessor — same bytes, same labels, same counts — including the
    /// adversarial corners the fleet runs hit (`train_n = 0/1`,
    /// imbalanced specs, out-of-order batch gathers).
    #[test]
    fn lazy_store_matches_eager_bytes() {
        let specs: Vec<SynthSpec> = vec![
            SynthSpec::mnist_like(),
            SynthSpec::fmnist_like(),
            SynthSpec::mnist_like().imbalanced(&[0, 4], 0.3),
        ];
        check("lazy train store == eager", 30, |rng| {
            let spec = &specs[rng.below(specs.len())];
            let train_n = [0usize, 1, 2, 13, 97][rng.below(5)];
            let test_n = rng.below(8);
            let seed = rng.next_u64();
            let eager = spec.generate(train_n, test_n, &mut Rng::new(seed));
            let lazy = spec.generate_lazy(train_n, test_n, &mut Rng::new(seed));
            if !lazy.is_lazy() || eager.is_lazy() {
                return Err("store tags wrong".into());
            }
            if eager.train_len() != train_n || lazy.train_len() != train_n {
                return Err("train_len mismatch".into());
            }
            if eager.train_class_counts() != lazy.train_class_counts() {
                return Err("class counts mismatch".into());
            }
            if eager.test_x != lazy.test_x || eager.test_y != lazy.test_y {
                return Err("test set diverged".into());
            }
            // Random (possibly repeated, unordered) batch gather.
            if train_n > 0 {
                let idxs: Vec<usize> =
                    (0..rng.below(12)).map(|_| rng.below(train_n)).collect();
                let (mut xe, mut ye) = (Vec::new(), Vec::new());
                let (mut xl, mut yl) = (Vec::new(), Vec::new());
                eager.gather_train(&idxs, &mut xe, &mut ye);
                lazy.gather_train(&idxs, &mut xl, &mut yl);
                if ye != yl {
                    return Err("labels mismatch".into());
                }
                let be: Vec<u32> = xe.iter().map(|v| v.to_bits()).collect();
                let bl: Vec<u32> = xl.iter().map(|v| v.to_bits()).collect();
                if be != bl {
                    return Err("sample bytes mismatch".into());
                }
                for (k, &i) in idxs.iter().enumerate() {
                    if ye[k] != eager.train_label(i) || yl[k] != lazy.train_label(i) {
                        return Err("train_label inconsistent with gather".into());
                    }
                }
            }
            // The lazy footprint must be independent of train_n (only
            // prototypes + offsets are resident).
            let bigger = spec.generate_lazy(train_n * 10 + 1, test_n, &mut Rng::new(seed));
            if bigger.mem_bytes() != lazy.mem_bytes() {
                return Err(format!(
                    "lazy footprint scales with train_n: {} vs {}",
                    lazy.mem_bytes(),
                    bigger.mem_bytes()
                ));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "lazy train store")]
    fn train_sample_panics_on_lazy() {
        let ds = SynthSpec::mnist_like().generate_lazy(4, 2, &mut Rng::new(1));
        let _ = ds.train_sample(0);
    }
}
