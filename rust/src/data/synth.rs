//! Synthetic class-conditional dataset generator.
//!
//! Each class `c` has `modes` prototype vectors (sub-clusters, giving the
//! within-class variation real image classes have); a sample is a random
//! mode prototype plus isotropic Gaussian noise. The separability knob
//! (`noise / proto_scale`) is tuned per dataset so the *relative* task
//! difficulty matches the paper: MNIST-like ≫ easier than CIFAR-like.
//! This preserves the drivers of every evaluation claim (label coverage,
//! data amount, budget) while being generable offline — DESIGN.md §3.
//!
//! The training set is **virtual**: [`SynthGen`] holds only the class
//! prototypes, a per-class sample apportionment and one derived seed, and
//! [`SynthGen::sample_into`] regenerates any sample on demand into a
//! caller buffer. The eager path materializes by calling `sample_into`
//! for every index, so lazy and eager train stores are byte-identical by
//! construction (`data_mode` config knob; proptested in `data/mod.rs`).

use super::{FedDataset, TrainStore};
use crate::util::rng::Rng;

/// Same odd constant `Rng::split` uses to decorrelate labeled streams.
const SAMPLE_STREAM_MUL: u64 = 0x9E37_79B9_7F4A_7C15;

#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    /// Sub-clusters per class.
    pub modes: usize,
    /// Prototype magnitude.
    pub proto_scale: f32,
    /// Additive noise std.
    pub noise: f32,
    /// Per-class sample weight for the class-imbalanced variant
    /// (None ⇒ balanced).
    pub class_weights: Option<Vec<f64>>,
}

impl SynthSpec {
    /// MNIST stand-in: flat 784, well-separated.
    pub fn mnist_like() -> SynthSpec {
        SynthSpec {
            name: "mnist",
            input_shape: vec![784],
            num_classes: 10,
            modes: 2,
            proto_scale: 1.0,
            noise: 0.7,
            class_weights: None,
        }
    }

    /// FMNIST stand-in: 1×28×28, moderately separated.
    pub fn fmnist_like() -> SynthSpec {
        SynthSpec {
            name: "fmnist",
            input_shape: vec![1, 28, 28],
            num_classes: 10,
            modes: 3,
            proto_scale: 1.0,
            noise: 1.0,
            class_weights: None,
        }
    }

    /// CIFAR10 stand-in: 3×32×32, hardest (more modes, more noise).
    pub fn cifar_like() -> SynthSpec {
        SynthSpec {
            name: "cifar10",
            input_shape: vec![3, 32, 32],
            num_classes: 10,
            modes: 3,
            proto_scale: 1.0,
            noise: 1.2,
            class_weights: None,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<SynthSpec> {
        match name {
            "mnist" => Ok(SynthSpec::mnist_like()),
            "fmnist" => Ok(SynthSpec::fmnist_like()),
            "cifar10" => Ok(SynthSpec::cifar_like()),
            _ => anyhow::bail!("unknown dataset {name:?}"),
        }
    }

    /// §6.7 class-imbalanced variant: `rare` classes get `ratio`× the
    /// samples of the others (paper: 3 rare classes at 1 : 0.4).
    pub fn imbalanced(mut self, rare: &[usize], ratio: f64) -> SynthSpec {
        let mut w = vec![1.0f64; self.num_classes];
        for &c in rare {
            w[c] = ratio;
        }
        self.class_weights = Some(w);
        self
    }

    /// One prototype vector. Image-shaped data ([C,H,W]) gets *spatially
    /// smooth* prototypes (a coarse 4×4-block pattern): convolution +
    /// max-pooling preserves low-frequency class signal, mirroring how
    /// real image classes carry spatially-correlated structure. Flat data
    /// (MLP) keeps iid prototypes.
    fn prototype(&self, rng: &mut Rng) -> Vec<f32> {
        let dim: usize = self.input_shape.iter().product();
        if self.input_shape.len() != 3 {
            return (0..dim)
                .map(|_| rng.normal_f32(0.0, self.proto_scale))
                .collect();
        }
        let (c, h, w) = (
            self.input_shape[0],
            self.input_shape[1],
            self.input_shape[2],
        );
        let block = 4usize;
        let (gh, gw) = (h.div_ceil(block), w.div_ceil(block));
        let mut out = Vec::with_capacity(dim);
        for _ in 0..c {
            let grid: Vec<f32> = (0..gh * gw)
                .map(|_| rng.normal_f32(0.0, self.proto_scale))
                .collect();
            for y in 0..h {
                for x in 0..w {
                    out.push(grid[(y / block) * gw + x / block]);
                }
            }
        }
        out
    }

    /// Build the virtual train-set generator: prototypes (same RNG draws
    /// as always), the exact per-class apportionment of `train_n`, and
    /// one derived seed from which every sample's private stream is
    /// re-keyed. Consumes a fixed amount of `rng` regardless of
    /// `train_n`, so downstream draws (test set, partition) don't depend
    /// on the train-set size representation.
    fn plan(&self, train_n: usize, rng: &mut Rng) -> SynthGen {
        // Prototypes: [class][mode][dim]
        let protos: Vec<Vec<Vec<f32>>> = (0..self.num_classes)
            .map(|_| (0..self.modes).map(|_| self.prototype(rng)).collect())
            .collect();
        let weights: Vec<f64> = self
            .class_weights
            .clone()
            .unwrap_or_else(|| vec![1.0; self.num_classes]);
        let counts = apportion(&weights, train_n);
        let mut class_offsets = Vec::with_capacity(self.num_classes + 1);
        let mut acc = 0usize;
        class_offsets.push(0);
        for &c in &counts {
            acc += c;
            class_offsets.push(acc);
        }
        let sample_seed = rng.next_u64();
        SynthGen {
            spec: self.clone(),
            protos,
            class_offsets,
            sample_seed,
        }
    }

    /// Generate `train_n` training and `test_n` test samples with the
    /// train set fully materialized. The test set is always materialized
    /// and class-balanced so per-class accuracy (Fig. 21) is
    /// well-measured.
    pub fn generate(&self, train_n: usize, test_n: usize, rng: &mut Rng) -> FedDataset {
        self.generate_mode(train_n, test_n, rng, false)
    }

    /// Like [`SynthSpec::generate`] but the train set stays virtual: only
    /// the prototypes are stored and samples regenerate on demand.
    pub fn generate_lazy(&self, train_n: usize, test_n: usize, rng: &mut Rng) -> FedDataset {
        self.generate_mode(train_n, test_n, rng, true)
    }

    /// `lazy` selects the train-store representation; the sample bytes
    /// are identical either way (the eager store is materialized through
    /// the same [`SynthGen::sample_into`] path).
    pub fn generate_mode(
        &self,
        train_n: usize,
        test_n: usize,
        rng: &mut Rng,
        lazy: bool,
    ) -> FedDataset {
        let dim: usize = self.input_shape.iter().product();
        let synth = self.plan(train_n, rng);

        let mut test_x = Vec::with_capacity(test_n * dim);
        let mut test_y = Vec::with_capacity(test_n);
        for i in 0..test_n {
            let c = i % self.num_classes; // balanced test set
            let m = rng.below(self.modes);
            let p = &synth.protos[c][m];
            test_x.extend(p.iter().map(|&v| v + rng.normal_f32(0.0, self.noise)));
            test_y.push(c as i32);
        }

        let train = if lazy {
            TrainStore::Lazy { synth }
        } else {
            let mut x = vec![0.0f32; train_n * dim];
            let mut y = Vec::with_capacity(train_n);
            for i in 0..train_n {
                y.push(synth.sample_into(i, &mut x[i * dim..(i + 1) * dim]));
            }
            TrainStore::Eager { x, y }
        };
        FedDataset {
            input_shape: self.input_shape.clone(),
            num_classes: self.num_classes,
            train,
            test_x,
            test_y,
        }
    }
}

/// Largest-remainder apportionment of `total` samples over `weights`:
/// floor of each exact share, remainder distributed by descending
/// fractional part with ties broken by ascending class — deterministic,
/// exact (sums to `total`), and within one sample of proportional.
fn apportion(weights: &[f64], total: usize) -> Vec<usize> {
    let wsum: f64 = weights.iter().sum();
    debug_assert!(wsum > 0.0 && weights.iter().all(|&w| w >= 0.0));
    let mut counts = Vec::with_capacity(weights.len());
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (c, &w) in weights.iter().enumerate() {
        let share = w / wsum * total as f64;
        let fl = share.floor() as usize;
        counts.push(fl);
        assigned += fl;
        fracs.push((share - fl as f64, c));
    }
    fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    for &(_, c) in fracs.iter().take(total.saturating_sub(assigned)) {
        counts[c] += 1;
    }
    counts
}

/// The virtual training set: class prototypes + per-class apportionment
/// + one seed. Any sample regenerates on demand with a private RNG
/// stream keyed by its index, so random access never perturbs (or
/// depends on) any other draw — O(classes · modes · dim) resident bytes
/// for a train set of any length.
#[derive(Clone, Debug)]
pub struct SynthGen {
    spec: SynthSpec,
    /// `[class][mode][dim]` prototype vectors.
    protos: Vec<Vec<Vec<f32>>>,
    /// Class-major layout: sample `i` has the class `c` with
    /// `class_offsets[c] <= i < class_offsets[c + 1]` (len `C + 1`).
    class_offsets: Vec<usize>,
    /// Base seed for the per-sample streams.
    sample_seed: u64,
}

impl SynthGen {
    pub fn len(&self) -> usize {
        *self.class_offsets.last().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Label of sample `i` (prefix-sum lookup, no generation).
    pub fn label_of(&self, i: usize) -> i32 {
        debug_assert!(i < self.len());
        (self.class_offsets.partition_point(|&o| o <= i) - 1) as i32
    }

    /// Regenerate sample `i` into `out` (length `sample_dim`); returns
    /// its label. Each sample owns a fresh `Rng` derived from
    /// `(sample_seed, i)` — Box–Muller caches a second deviate inside the
    /// generator, so a shared stream would leak state across random
    /// accesses.
    pub fn sample_into(&self, i: usize, out: &mut [f32]) -> i32 {
        let c = self.label_of(i) as usize;
        let mut rng =
            Rng::new(self.sample_seed ^ (i as u64 + 1).wrapping_mul(SAMPLE_STREAM_MUL));
        let m = rng.below(self.spec.modes);
        let p = &self.protos[c][m];
        debug_assert_eq!(out.len(), p.len());
        for (o, &v) in out.iter_mut().zip(p) {
            *o = v + rng.normal_f32(0.0, self.spec.noise);
        }
        c as i32
    }

    /// Exact per-class sample counts (no scan).
    pub fn class_counts(&self) -> Vec<usize> {
        self.class_offsets.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// Resident heap bytes of the generator (prototypes + offsets) — the
    /// whole per-train-set footprint, independent of `len()`.
    pub fn mem_bytes(&self) -> usize {
        let proto_bytes: usize = self
            .protos
            .iter()
            .flat_map(|ms| ms.iter().map(|p| p.len() * 4))
            .sum();
        proto_bytes + self.class_offsets.len() * std::mem::size_of::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_sizes() {
        let mut rng = Rng::new(0);
        let ds = SynthSpec::cifar_like().generate(50, 30, &mut rng);
        assert_eq!(ds.sample_dim(), 3 * 32 * 32);
        assert_eq!(ds.train_len(), 50);
        assert_eq!(ds.test_len(), 30);
        assert!((0..ds.train_len()).all(|i| (0..10).contains(&ds.train_label(i))));
    }

    #[test]
    fn test_set_is_balanced() {
        let mut rng = Rng::new(1);
        let ds = SynthSpec::mnist_like().generate(10, 100, &mut rng);
        let mut counts = [0usize; 10];
        for &y in &ds.test_y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn imbalanced_classes_are_rare() {
        let mut rng = Rng::new(2);
        let ds = SynthSpec::mnist_like()
            .imbalanced(&[0, 1, 2], 0.4)
            .generate(20_000, 10, &mut rng);
        let counts = ds.train_class_counts();
        let rare: usize = counts[..3].iter().sum();
        let common: usize = counts[3..].iter().sum();
        let ratio = (rare as f64 / 3.0) / (common as f64 / 7.0);
        assert!((0.3..0.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn apportion_is_exact_and_proportional() {
        // Sums to total exactly; each class within one sample of its
        // exact share; deterministic tie-break.
        for &(total, w) in &[
            (0usize, vec![1.0, 1.0]),
            (1, vec![1.0, 1.0, 1.0]),
            (7, vec![1.0; 10]),
            (20_000, vec![0.4, 0.4, 0.4, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]),
            (13, vec![5.0, 0.0, 1.0]),
        ] {
            let counts = apportion(&w, total);
            assert_eq!(counts.iter().sum::<usize>(), total, "{w:?} × {total}");
            let wsum: f64 = w.iter().sum();
            for (c, &n) in counts.iter().enumerate() {
                let share = w[c] / wsum * total as f64;
                assert!(
                    (n as f64 - share).abs() < 1.0 + 1e-9,
                    "class {c}: {n} vs share {share}"
                );
            }
            assert_eq!(apportion(&w, total), counts);
        }
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype classification on fresh samples should beat
        // chance by a wide margin for the mnist-like spec.
        let mut rng = Rng::new(3);
        let spec = SynthSpec::mnist_like();
        let ds = spec.generate(2000, 200, &mut rng);
        // class means from train:
        let dim = ds.sample_dim();
        let mut means = vec![vec![0.0f64; dim]; 10];
        let counts = ds.train_class_counts();
        for i in 0..ds.train_len() {
            let c = ds.train_label(i) as usize;
            for (m, &v) in means[c].iter_mut().zip(ds.train_sample(i)) {
                *m += v as f64;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            for v in m.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..ds.test_len() {
            let x = ds.test_sample(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = means[a]
                        .iter()
                        .zip(x)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    let db: f64 = means[b]
                        .iter()
                        .zip(x)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.test_y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.test_len() as f64;
        assert!(acc > 0.6, "nearest-mean accuracy {acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthSpec::mnist_like().generate(10, 5, &mut Rng::new(7));
        let b = SynthSpec::mnist_like().generate(10, 5, &mut Rng::new(7));
        let mut xa = Vec::new();
        let mut xb = Vec::new();
        let (mut ya, mut yb) = (Vec::new(), Vec::new());
        let idxs: Vec<usize> = (0..10).collect();
        a.gather_train(&idxs, &mut xa, &mut ya);
        b.gather_train(&idxs, &mut xb, &mut yb);
        assert_eq!(xa.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            xb.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        assert_eq!(ya, yb);
        assert_eq!(a.test_y, b.test_y);
    }
}
