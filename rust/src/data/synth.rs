//! Synthetic class-conditional dataset generator.
//!
//! Each class `c` has `modes` prototype vectors (sub-clusters, giving the
//! within-class variation real image classes have); a sample is a random
//! mode prototype plus isotropic Gaussian noise. The separability knob
//! (`noise / proto_scale`) is tuned per dataset so the *relative* task
//! difficulty matches the paper: MNIST-like ≫ easier than CIFAR-like.
//! This preserves the drivers of every evaluation claim (label coverage,
//! data amount, budget) while being generable offline — DESIGN.md §3.

use super::FedDataset;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    /// Sub-clusters per class.
    pub modes: usize,
    /// Prototype magnitude.
    pub proto_scale: f32,
    /// Additive noise std.
    pub noise: f32,
    /// Per-class sample weight for the class-imbalanced variant
    /// (None ⇒ balanced).
    pub class_weights: Option<Vec<f64>>,
}

impl SynthSpec {
    /// MNIST stand-in: flat 784, well-separated.
    pub fn mnist_like() -> SynthSpec {
        SynthSpec {
            name: "mnist",
            input_shape: vec![784],
            num_classes: 10,
            modes: 2,
            proto_scale: 1.0,
            noise: 0.7,
            class_weights: None,
        }
    }

    /// FMNIST stand-in: 1×28×28, moderately separated.
    pub fn fmnist_like() -> SynthSpec {
        SynthSpec {
            name: "fmnist",
            input_shape: vec![1, 28, 28],
            num_classes: 10,
            modes: 3,
            proto_scale: 1.0,
            noise: 1.0,
            class_weights: None,
        }
    }

    /// CIFAR10 stand-in: 3×32×32, hardest (more modes, more noise).
    pub fn cifar_like() -> SynthSpec {
        SynthSpec {
            name: "cifar10",
            input_shape: vec![3, 32, 32],
            num_classes: 10,
            modes: 3,
            proto_scale: 1.0,
            noise: 1.2,
            class_weights: None,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<SynthSpec> {
        match name {
            "mnist" => Ok(SynthSpec::mnist_like()),
            "fmnist" => Ok(SynthSpec::fmnist_like()),
            "cifar10" => Ok(SynthSpec::cifar_like()),
            _ => anyhow::bail!("unknown dataset {name:?}"),
        }
    }

    /// §6.7 class-imbalanced variant: `rare` classes get `ratio`× the
    /// samples of the others (paper: 3 rare classes at 1 : 0.4).
    pub fn imbalanced(mut self, rare: &[usize], ratio: f64) -> SynthSpec {
        let mut w = vec![1.0f64; self.num_classes];
        for &c in rare {
            w[c] = ratio;
        }
        self.class_weights = Some(w);
        self
    }

    /// Generate `train_n` training and `test_n` test samples. The test
    /// set is always class-balanced so per-class accuracy (Fig. 21) is
    /// well-measured.
    /// One prototype vector. Image-shaped data ([C,H,W]) gets *spatially
    /// smooth* prototypes (a coarse 4×4-block pattern): convolution +
    /// max-pooling preserves low-frequency class signal, mirroring how
    /// real image classes carry spatially-correlated structure. Flat data
    /// (MLP) keeps iid prototypes.
    fn prototype(&self, rng: &mut Rng) -> Vec<f32> {
        let dim: usize = self.input_shape.iter().product();
        if self.input_shape.len() != 3 {
            return (0..dim)
                .map(|_| rng.normal_f32(0.0, self.proto_scale))
                .collect();
        }
        let (c, h, w) = (
            self.input_shape[0],
            self.input_shape[1],
            self.input_shape[2],
        );
        let block = 4usize;
        let (gh, gw) = (h.div_ceil(block), w.div_ceil(block));
        let mut out = Vec::with_capacity(dim);
        for _ in 0..c {
            let grid: Vec<f32> = (0..gh * gw)
                .map(|_| rng.normal_f32(0.0, self.proto_scale))
                .collect();
            for y in 0..h {
                for x in 0..w {
                    out.push(grid[(y / block) * gw + x / block]);
                }
            }
        }
        out
    }

    pub fn generate(&self, train_n: usize, test_n: usize, rng: &mut Rng) -> FedDataset {
        let dim: usize = self.input_shape.iter().product();
        // Prototypes: [class][mode][dim]
        let protos: Vec<Vec<Vec<f32>>> = (0..self.num_classes)
            .map(|_| (0..self.modes).map(|_| self.prototype(rng)).collect())
            .collect();

        let weights: Vec<f64> = self
            .class_weights
            .clone()
            .unwrap_or_else(|| vec![1.0; self.num_classes]);

        let mut train_x = Vec::with_capacity(train_n * dim);
        let mut train_y = Vec::with_capacity(train_n);
        for _ in 0..train_n {
            let c = rng.categorical(&weights);
            let m = rng.below(self.modes);
            let p = &protos[c][m];
            train_x.extend(p.iter().map(|&v| v + rng.normal_f32(0.0, self.noise)));
            train_y.push(c as i32);
        }
        let mut test_x = Vec::with_capacity(test_n * dim);
        let mut test_y = Vec::with_capacity(test_n);
        for i in 0..test_n {
            let c = i % self.num_classes; // balanced test set
            let m = rng.below(self.modes);
            let p = &protos[c][m];
            test_x.extend(p.iter().map(|&v| v + rng.normal_f32(0.0, self.noise)));
            test_y.push(c as i32);
        }
        FedDataset {
            input_shape: self.input_shape.clone(),
            num_classes: self.num_classes,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_sizes() {
        let mut rng = Rng::new(0);
        let ds = SynthSpec::cifar_like().generate(50, 30, &mut rng);
        assert_eq!(ds.sample_dim(), 3 * 32 * 32);
        assert_eq!(ds.train_x.len(), 50 * 3072);
        assert_eq!(ds.test_len(), 30);
        assert!(ds.train_y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn test_set_is_balanced() {
        let mut rng = Rng::new(1);
        let ds = SynthSpec::mnist_like().generate(10, 100, &mut rng);
        let mut counts = [0usize; 10];
        for &y in &ds.test_y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn imbalanced_classes_are_rare() {
        let mut rng = Rng::new(2);
        let ds = SynthSpec::mnist_like()
            .imbalanced(&[0, 1, 2], 0.4)
            .generate(20_000, 10, &mut rng);
        let counts = ds.train_class_counts();
        let rare: usize = counts[..3].iter().sum();
        let common: usize = counts[3..].iter().sum();
        let ratio = (rare as f64 / 3.0) / (common as f64 / 7.0);
        assert!((0.3..0.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype classification on fresh samples should beat
        // chance by a wide margin for the mnist-like spec.
        let mut rng = Rng::new(3);
        let spec = SynthSpec::mnist_like();
        let ds = spec.generate(2000, 200, &mut rng);
        // class means from train:
        let dim = ds.sample_dim();
        let mut means = vec![vec![0.0f64; dim]; 10];
        let counts = ds.train_class_counts();
        for i in 0..ds.train_len() {
            let c = ds.train_y[i] as usize;
            for (m, &v) in means[c].iter_mut().zip(ds.train_sample(i)) {
                *m += v as f64;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            for v in m.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..ds.test_len() {
            let x = ds.test_sample(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = means[a]
                        .iter()
                        .zip(x)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    let db: f64 = means[b]
                        .iter()
                        .zip(x)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.test_y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.test_len() as f64;
        assert!(acc > 0.6, "nearest-mean accuracy {acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthSpec::mnist_like().generate(10, 5, &mut Rng::new(7));
        let b = SynthSpec::mnist_like().generate(10, 5, &mut Rng::new(7));
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
    }
}
