//! Client data partitioners — the paper's three heterogeneity settings
//! (§6.1): IID, Non-IID-a (2–10 random classes per client), Non-IID-b
//! (exactly 3 random classes per client).
//!
//! # Large-fleet representation
//!
//! FedDD fleets have no partial participation, so the partition is held
//! for *every* client for the whole run — no per-client index heaps
//! survive at fleet scale:
//!
//! * **IID** shuffle-and-deal is one shared permutation (derived from the
//!   partition seed); client `n`'s index set is the strided view
//!   `perm[n], perm[n + N], perm[n + 2N], …` — exactly the sequence the
//!   eager deal `client_indices[i % N].push(perm[i])` used to
//!   materialize, at O(1) extra memory per client.
//! * **Non-IID-a/b** deal each class's shuffled samples round-robin over
//!   the class's claimants, so the claimant at rank `p` of class `cls`
//!   owns the strided view `by_class[cls][p], by_class[cls][p + W], …`
//!   (`W` = claimant count). A client's full sequence is the ascending-
//!   class concatenation of its ≤ `num_classes` strided segments —
//!   [`Assignment::ClassStrided`] stores the shared per-class lists once
//!   plus one flat segment table, O(claimed classes) per client instead
//!   of a `Vec<usize>` heap each. Byte-identical to the eager deal
//!   (proptested below).
//!
//! [`ClientShard`] is the per-client handle the coordinator samples from;
//! it yields identical index sequences for every representation.

use std::sync::Arc;

use super::FedDataset;
use crate::util::rng::Rng;

/// One strided segment of a class-stratified shard: the claimant at rank
/// `offset` of class `cls` owns every `stride`-th element of that class's
/// shuffled sample list.
#[derive(Clone, Copy, Debug)]
pub struct ClassSeg {
    cls: u32,
    offset: u32,
    stride: u32,
}

impl ClassSeg {
    fn len_in(&self, lists: &[Vec<usize>]) -> usize {
        strided_len(
            lists[self.cls as usize].len(),
            self.offset as usize,
            self.stride as usize,
        )
    }
}

/// One client's view of the train set: a materialized index list, a lazy
/// strided slice of the shared IID permutation, or a lazy class-stratified
/// segment run. All yield the same sequence the eager representation
/// held, element for element.
#[derive(Clone, Debug)]
pub enum ClientShard {
    /// Materialized index list (hand-built tests, explicit partitions).
    Owned(Vec<usize>),
    /// Element `j` is `perm[offset + j · stride]` (IID shuffle-and-deal:
    /// `offset` = client id, `stride` = fleet size).
    Strided {
        perm: Arc<Vec<usize>>,
        offset: usize,
        stride: usize,
    },
    /// Ascending-class concatenation of strided views over the shared
    /// per-class lists (non-IID a/b): segments `segs[start..end]` of the
    /// partition-wide table. O(1) owned heap — everything is shared.
    ClassStrided {
        lists: Arc<Vec<Vec<usize>>>,
        segs: Arc<Vec<ClassSeg>>,
        start: usize,
        end: usize,
    },
}

/// Elements of a strided view over `len` items starting at `offset` —
/// the single source of truth for the ragged-tail arithmetic, shared by
/// [`ClientShard::len`] and [`Partition::m_n`].
fn strided_len(len: usize, offset: usize, stride: usize) -> usize {
    if offset >= len {
        0
    } else {
        (len - offset - 1) / stride + 1
    }
}

impl ClientShard {
    pub fn len(&self) -> usize {
        match self {
            ClientShard::Owned(v) => v.len(),
            ClientShard::Strided { perm, offset, stride } => {
                strided_len(perm.len(), *offset, *stride)
            }
            ClientShard::ClassStrided { lists, segs, start, end } => segs[*start..*end]
                .iter()
                .map(|s| s.len_in(lists))
                .sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `j`-th train-set index of this shard.
    pub fn get(&self, j: usize) -> usize {
        match self {
            ClientShard::Owned(v) => v[j],
            ClientShard::Strided { perm, offset, stride } => perm[offset + j * stride],
            ClientShard::ClassStrided { lists, segs, start, end } => {
                let mut j = j;
                for s in &segs[*start..*end] {
                    let l = s.len_in(lists);
                    if j < l {
                        return lists[s.cls as usize]
                            [s.offset as usize + j * s.stride as usize];
                    }
                    j -= l;
                }
                panic!("shard index {j} past the final segment");
            }
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        ShardIter { shard: self, seg: 0, pos: 0, remaining: self.len() }
    }

    pub fn to_vec(&self) -> Vec<usize> {
        self.iter().collect()
    }

    /// Heap bytes owned by this shard alone (shared `Arc` storage is
    /// charged once at the [`Partition`], not per client).
    pub fn owned_bytes(&self) -> usize {
        match self {
            ClientShard::Owned(v) => v.len() * std::mem::size_of::<usize>(),
            ClientShard::Strided { .. } | ClientShard::ClassStrided { .. } => 0,
        }
    }
}

/// Sequential iterator over a shard. For the class-strided arm this walks
/// segments in place (no repeated prefix scan, unlike indexed `get`).
struct ShardIter<'a> {
    shard: &'a ClientShard,
    seg: usize,
    pos: usize,
    remaining: usize,
}

impl Iterator for ShardIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        match self.shard {
            ClientShard::Owned(v) => {
                let out = v[self.pos];
                self.pos += 1;
                Some(out)
            }
            ClientShard::Strided { perm, offset, stride } => {
                let out = perm[offset + self.pos * stride];
                self.pos += 1;
                Some(out)
            }
            ClientShard::ClassStrided { lists, segs, start, end } => {
                loop {
                    let s = &segs[start + self.seg];
                    debug_assert!(start + self.seg < *end);
                    if self.pos < s.len_in(lists) {
                        let out = lists[s.cls as usize]
                            [s.offset as usize + self.pos * s.stride as usize];
                        self.pos += 1;
                        return Some(out);
                    }
                    self.seg += 1;
                    self.pos = 0;
                }
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

/// Which samples each client owns (indices into the train set).
#[derive(Clone, Debug)]
pub struct Partition {
    pub num_classes: usize,
    assign: Assignment,
}

#[derive(Clone, Debug)]
enum Assignment {
    Explicit(Vec<Vec<usize>>),
    /// IID shuffle-and-deal: client `n` owns `perm[n], perm[n+N], …`.
    Strided { perm: Arc<Vec<usize>>, n_clients: usize },
    /// Non-IID class deal: shared per-class shuffled lists + one flat
    /// segment table; client `n` owns `segs[bounds[n]..bounds[n+1]]`.
    ClassStrided {
        lists: Arc<Vec<Vec<usize>>>,
        segs: Arc<Vec<ClassSeg>>,
        /// Per-client segment ranges, length `n_clients + 1`.
        bounds: Vec<u32>,
    },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    Iid,
    NonIidA,
    NonIidB,
}

impl PartitionKind {
    pub fn by_name(name: &str) -> anyhow::Result<PartitionKind> {
        match name {
            "iid" => Ok(PartitionKind::Iid),
            "noniid_a" | "noniid-a" => Ok(PartitionKind::NonIidA),
            "noniid_b" | "noniid-b" => Ok(PartitionKind::NonIidB),
            _ => anyhow::bail!("unknown partition {name:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionKind::Iid => "iid",
            PartitionKind::NonIidA => "noniid_a",
            PartitionKind::NonIidB => "noniid_b",
        }
    }
}

/// The seeded class-deal plan shared by the lazy and eager non-IID
/// builders: per-class shuffled sample lists and per-class claimant
/// rosters. Consuming the RNG here (and only here) is what makes the two
/// representations byte-identical.
struct ClassPlan {
    by_class: Vec<Vec<usize>>,
    claimants: Vec<Vec<usize>>,
    n_clients: usize,
}

impl ClassPlan {
    fn build(
        ds: &FedDataset,
        n_clients: usize,
        rng: &mut Rng,
        pick: impl Fn(&mut Rng) -> usize,
    ) -> ClassPlan {
        let c = ds.num_classes;
        // class -> shuffled sample indices
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); c];
        for i in 0..ds.train_len() {
            by_class[ds.train_label(i) as usize].push(i);
        }
        for v in &mut by_class {
            rng.shuffle(v);
        }
        // client -> claimed classes
        let claims: Vec<Vec<usize>> = (0..n_clients)
            .map(|_| {
                let k = pick(rng).min(c);
                rng.choose_k(c, k)
            })
            .collect();
        // class -> claimants (ascending client order)
        let mut claimants: Vec<Vec<usize>> = vec![Vec::new(); c];
        for (client, classes) in claims.iter().enumerate() {
            for &cls in classes {
                claimants[cls].push(client);
            }
        }
        ClassPlan { by_class, claimants, n_clients }
    }

    /// The lazy representation: one segment per (class, claimant) pair.
    fn into_lazy(self, num_classes: usize) -> Partition {
        assert!(self.n_clients < u32::MAX as usize, "fleet too large for u32 segments");
        let mut per_client: Vec<Vec<ClassSeg>> = vec![Vec::new(); self.n_clients];
        for (cls, owners) in self.claimants.iter().enumerate() {
            for (p, &client) in owners.iter().enumerate() {
                per_client[client].push(ClassSeg {
                    cls: cls as u32,
                    offset: p as u32,
                    stride: owners.len() as u32,
                });
            }
        }
        let mut segs = Vec::with_capacity(per_client.iter().map(Vec::len).sum());
        let mut bounds = Vec::with_capacity(self.n_clients + 1);
        bounds.push(0u32);
        for client_segs in per_client {
            segs.extend(client_segs);
            bounds.push(segs.len() as u32);
        }
        Partition {
            num_classes,
            assign: Assignment::ClassStrided {
                lists: Arc::new(self.by_class),
                segs: Arc::new(segs),
                bounds,
            },
        }
    }

    /// The materialized deal the lazy representation must reproduce
    /// (kept for the equality proptests).
    #[cfg(test)]
    fn into_eager(self, num_classes: usize) -> Partition {
        let mut client_indices = vec![Vec::new(); self.n_clients];
        for (cls, owners) in self.claimants.iter().enumerate() {
            if owners.is_empty() {
                continue; // class unseen by everyone (rare; small n_clients)
            }
            for (i, &sample) in self.by_class[cls].iter().enumerate() {
                client_indices[owners[i % owners.len()]].push(sample);
            }
        }
        Partition::explicit(client_indices, num_classes)
    }
}

impl Partition {
    pub fn build(
        kind: PartitionKind,
        ds: &FedDataset,
        n_clients: usize,
        rng: &mut Rng,
    ) -> Partition {
        match kind {
            PartitionKind::Iid => Self::iid(ds, n_clients, rng),
            PartitionKind::NonIidA => Self::by_class_counts(ds, n_clients, rng, |rng| {
                rng.int_range(2, 10)
            }),
            PartitionKind::NonIidB => {
                Self::by_class_counts(ds, n_clients, rng, |_| 3)
            }
        }
    }

    /// Uniform shuffle-and-deal, stored as the shared permutation (each
    /// client's set is derived lazily — see the module docs).
    pub fn iid(ds: &FedDataset, n_clients: usize, rng: &mut Rng) -> Partition {
        let perm = rng.permutation(ds.train_len());
        Partition {
            num_classes: ds.num_classes,
            assign: Assignment::Strided { perm: Arc::new(perm), n_clients },
        }
    }

    /// A partition from materialized per-client index lists.
    pub fn explicit(client_indices: Vec<Vec<usize>>, num_classes: usize) -> Partition {
        Partition { num_classes, assign: Assignment::Explicit(client_indices) }
    }

    /// Label-restricted partition: each client claims `k = pick(rng)`
    /// classes; each class's samples are split evenly among its claimants
    /// — stored lazily as class-strided segments.
    fn by_class_counts(
        ds: &FedDataset,
        n_clients: usize,
        rng: &mut Rng,
        pick: impl Fn(&mut Rng) -> usize,
    ) -> Partition {
        ClassPlan::build(ds, n_clients, rng, pick).into_lazy(ds.num_classes)
    }

    pub fn n_clients(&self) -> usize {
        match &self.assign {
            Assignment::Explicit(v) => v.len(),
            Assignment::Strided { n_clients, .. } => *n_clients,
            Assignment::ClassStrided { bounds, .. } => bounds.len() - 1,
        }
    }

    /// m_n — samples held by client `n` (no shard handle, no copies).
    pub fn m_n(&self, n: usize) -> usize {
        match &self.assign {
            Assignment::Explicit(v) => v[n].len(),
            Assignment::Strided { perm, n_clients } => {
                // Out-of-range ids must panic like the Explicit arm's
                // `v[n]` — the stride formula would otherwise fabricate
                // a plausible count for a client that does not exist.
                assert!(n < *n_clients, "client {n} out of range ({n_clients} clients)");
                strided_len(perm.len(), n, *n_clients)
            }
            Assignment::ClassStrided { lists, segs, bounds } => segs
                [bounds[n] as usize..bounds[n + 1] as usize]
                .iter()
                .map(|s| s.len_in(lists))
                .sum(),
        }
    }

    /// m_n — samples per client.
    pub fn sizes(&self) -> Vec<usize> {
        (0..self.n_clients()).map(|n| self.m_n(n)).collect()
    }

    /// Client `n`'s shard handle (O(1) for both lazy representations —
    /// shared storage is `Arc`-cloned, never copied).
    pub fn shard(&self, n: usize) -> ClientShard {
        match &self.assign {
            Assignment::Explicit(v) => ClientShard::Owned(v[n].clone()),
            Assignment::Strided { perm, n_clients } => {
                assert!(n < *n_clients, "client {n} out of range ({n_clients} clients)");
                ClientShard::Strided {
                    perm: Arc::clone(perm),
                    offset: n,
                    stride: *n_clients,
                }
            }
            Assignment::ClassStrided { lists, segs, bounds } => ClientShard::ClassStrided {
                lists: Arc::clone(lists),
                segs: Arc::clone(segs),
                start: bounds[n] as usize,
                end: bounds[n + 1] as usize,
            },
        }
    }

    /// Client `n`'s materialized index list (tests / diagnostics; the
    /// coordinator samples through [`Partition::shard`] instead).
    pub fn indices_of(&self, n: usize) -> Vec<usize> {
        self.shard(n).to_vec()
    }

    /// Visit every index of client `n` in shard order, without
    /// materializing a list: every arm iterates in place (the lazy arms
    /// walk their shared storage through [`ClientShard::iter`]'s
    /// segment-cursor, so diagnostics never allocate per client).
    pub fn visit_client(&self, n: usize, mut f: impl FnMut(usize)) {
        match &self.assign {
            Assignment::Explicit(v) => {
                for &i in &v[n] {
                    f(i);
                }
            }
            Assignment::Strided { .. } | Assignment::ClassStrided { .. } => {
                for i in self.shard(n).iter() {
                    f(i);
                }
            }
        }
    }

    /// dis_n^c — per-client label distribution (fractions summing to 1).
    /// The class-strided arm answers from segment lengths alone (every
    /// sample in a segment shares the segment's class) — no sample visit,
    /// no label lookup.
    pub fn label_distribution(&self, ds: &FedDataset) -> Vec<Vec<f64>> {
        (0..self.n_clients())
            .map(|n| {
                let mut counts = vec![0usize; self.num_classes];
                if let Assignment::ClassStrided { lists, segs, bounds } = &self.assign {
                    for s in &segs[bounds[n] as usize..bounds[n + 1] as usize] {
                        counts[s.cls as usize] += s.len_in(lists);
                    }
                } else {
                    self.visit_client(n, |i| counts[ds.train_label(i) as usize] += 1);
                }
                let total = self.m_n(n).max(1) as f64;
                counts.iter().map(|&k| k as f64 / total).collect()
            })
            .collect()
    }

    /// The paper's data-distribution contribution term
    /// `Σ_c min(C · dis_n^c, 1)` (§4.1-2).
    pub fn distribution_scores(&self, ds: &FedDataset) -> Vec<f64> {
        let c = self.num_classes as f64;
        self.label_distribution(ds)
            .iter()
            .map(|dis| dis.iter().map(|&d| (c * d).min(1.0)).sum())
            .collect()
    }

    /// Heap bytes of the partition's shared storage (per-client `Owned`
    /// shard copies are charged by [`ClientShard::owned_bytes`]).
    pub fn mem_bytes(&self) -> usize {
        let w = std::mem::size_of::<usize>();
        match &self.assign {
            Assignment::Explicit(v) => {
                v.iter().map(|c| c.len() * w).sum::<usize>() + v.len() * 3 * w
            }
            Assignment::Strided { perm, .. } => perm.len() * w,
            Assignment::ClassStrided { lists, segs, bounds } => {
                lists.iter().map(|c| c.len() * w).sum::<usize>()
                    + segs.len() * std::mem::size_of::<ClassSeg>()
                    + bounds.len() * 4
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::util::proptest::check;

    fn dataset(rng: &mut Rng) -> FedDataset {
        SynthSpec::mnist_like().generate(2000, 100, rng)
    }

    /// The eager shuffle-and-deal the lazy representation must reproduce.
    fn eager_iid_deal(perm: &[usize], n_clients: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); n_clients];
        for (i, &sample) in perm.iter().enumerate() {
            out[i % n_clients].push(sample);
        }
        out
    }

    #[test]
    fn partitions_are_disjoint_and_complete_iid() {
        let mut rng = Rng::new(0);
        let ds = dataset(&mut rng);
        let p = Partition::iid(&ds, 10, &mut rng);
        let mut all: Vec<usize> =
            (0..10).flat_map(|n| p.indices_of(n)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..2000).collect::<Vec<_>>());
    }

    /// Assert the lazy strided representation over a seeded permutation of
    /// `len` samples yields byte-identical per-client sequences to the
    /// eager deal, through every access path (`m_n`, `indices_of`,
    /// `shard.get`, `visit_client`), and that the shards partition the
    /// whole permutation.
    fn assert_lazy_matches_eager(len: usize, n_clients: usize, seed: u64) {
        let ctx = format!("len={len} n_clients={n_clients}");
        let mut rng = Rng::new(seed);
        let perm = rng.permutation(len);
        let eager = eager_iid_deal(&perm, n_clients);
        let p = Partition {
            num_classes: 10,
            assign: Assignment::Strided { perm: Arc::new(perm), n_clients },
        };
        assert_eq!(p.n_clients(), n_clients, "{ctx}");
        let mut total = 0usize;
        for n in 0..n_clients {
            assert_eq!(p.m_n(n), eager[n].len(), "{ctx} client {n}");
            assert_eq!(p.indices_of(n), eager[n], "{ctx} client {n}");
            let shard = p.shard(n);
            assert_eq!(shard.len(), eager[n].len(), "{ctx} client {n}");
            assert_eq!(shard.is_empty(), eager[n].is_empty(), "{ctx} client {n}");
            for (j, &want) in eager[n].iter().enumerate() {
                assert_eq!(shard.get(j), want, "{ctx} client {n} elem {j}");
            }
            let mut visited = Vec::new();
            p.visit_client(n, |i| visited.push(i));
            assert_eq!(visited, eager[n], "{ctx} client {n}");
            total += p.m_n(n);
        }
        assert_eq!(total, len, "{ctx}: shards must partition the permutation");
    }

    #[test]
    fn lazy_iid_matches_the_eager_deal_exactly() {
        // The lazy strided view must yield the exact per-client index
        // sequences the old materialized deal produced, including ragged
        // tails (train_len not divisible by n_clients).
        for (len, n_clients) in [(2000usize, 10usize), (1003, 7), (10, 16), (5, 5)] {
            assert_lazy_matches_eager(len, n_clients, 42 + len as u64);
        }
    }

    #[test]
    fn lazy_iid_adversarial_edges_match_eager() {
        // The corners the fleet sweeps can hit: `train_per_client ∈
        // {0, 1}` (so the dataset has 0 or n_clients samples), a single
        // client owning everything, prime fleet sizes (no stride
        // alignment), and more clients than samples (empty ragged tails
        // for every client past the permutation length).
        for &(len, n_clients) in &[
            (0usize, 1usize), // tpc = 0, one client: a single empty shard
            (0, 7),           // tpc = 0 across a fleet: all shards empty
            (1, 1),           // one sample, one client
            (7, 7),           // tpc = 1 at a prime fleet size
            (13, 13),         // tpc = 1 at a larger prime
            (5, 11),          // n_clients > samples (prime): 6 empty tails
            (3, 97),          // n_clients ≫ samples: 94 empty shards
            (97, 1),          // one client owns a prime-sized set
            (96, 97),         // one sample short of the fleet
            (101, 13),        // prime samples over prime clients
        ] {
            assert_lazy_matches_eager(len, n_clients, 1000 + len as u64 * 131 + n_clients as u64);
        }
    }

    #[test]
    fn lazy_iid_matches_eager_property() {
        // Random (len, n_clients) pairs biased toward the edges: empty
        // and near-empty permutations, fleets larger than the sample
        // count, and everything in between.
        check("lazy IID == eager deal", 40, |rng| {
            let n_clients = 1 + rng.below(60);
            let len = match rng.below(4) {
                0 => 0,
                1 => rng.below(2 * n_clients), // around the fleet size
                _ => rng.below(300),
            };
            let seed = 7000 + (len * 331 + n_clients) as u64;
            assert_lazy_matches_eager(len, n_clients, seed);
            Ok(())
        });
    }

    /// Assert the lazy class-strided representation equals the eager
    /// class deal built from an identical plan, through every access
    /// path, plus the segment-only `label_distribution` shortcut.
    fn assert_class_lazy_matches_eager(
        ds: &FedDataset,
        n_clients: usize,
        seed: u64,
        kind: PartitionKind,
    ) {
        let ctx = format!("n_clients={n_clients} kind={kind:?}");
        let pick = |rng: &mut Rng| match kind {
            PartitionKind::NonIidA => rng.int_range(2, 10),
            PartitionKind::NonIidB => 3,
            PartitionKind::Iid => unreachable!(),
        };
        let lazy = ClassPlan::build(ds, n_clients, &mut Rng::new(seed), &pick)
            .into_lazy(ds.num_classes);
        let eager = ClassPlan::build(ds, n_clients, &mut Rng::new(seed), &pick)
            .into_eager(ds.num_classes);
        // The builder consumed identical RNG streams, so Partition::build
        // (which is the lazy path) must agree with `lazy` too.
        let built = Partition::build(kind, ds, n_clients, &mut Rng::new(seed));
        assert!(
            matches!(built.assign, Assignment::ClassStrided { .. }),
            "{ctx}: build() must produce the lazy representation"
        );
        assert_eq!(lazy.n_clients(), n_clients, "{ctx}");
        assert_eq!(eager.n_clients(), n_clients, "{ctx}");
        for n in 0..n_clients {
            let want = eager.indices_of(n);
            assert_eq!(lazy.m_n(n), want.len(), "{ctx} client {n} m_n");
            assert_eq!(lazy.indices_of(n), want, "{ctx} client {n} indices");
            assert_eq!(built.indices_of(n), want, "{ctx} client {n} via build()");
            let shard = lazy.shard(n);
            assert_eq!(shard.len(), want.len(), "{ctx} client {n} shard len");
            assert_eq!(shard.owned_bytes(), 0, "{ctx} client {n}: lazy shard owns heap");
            for (j, &w) in want.iter().enumerate() {
                assert_eq!(shard.get(j), w, "{ctx} client {n} elem {j}");
            }
            let mut visited = Vec::new();
            lazy.visit_client(n, |i| visited.push(i));
            assert_eq!(visited, want, "{ctx} client {n} visit");
        }
        // label_distribution: the segment shortcut vs the sample scan.
        assert_eq!(
            lazy.label_distribution(ds),
            eager.label_distribution(ds),
            "{ctx}: label distributions diverge"
        );
        assert_eq!(
            lazy.distribution_scores(ds),
            eager.distribution_scores(ds),
            "{ctx}: distribution scores diverge"
        );
    }

    #[test]
    fn lazy_noniid_matches_eager_deal_exactly() {
        let mut rng = Rng::new(11);
        let ds = dataset(&mut rng);
        for kind in [PartitionKind::NonIidA, PartitionKind::NonIidB] {
            for n_clients in [1usize, 7, 20] {
                assert_class_lazy_matches_eager(&ds, n_clients, 500 + n_clients as u64, kind);
            }
        }
    }

    #[test]
    fn lazy_noniid_adversarial_edges_match_eager() {
        // n_clients ∈ {1, prime, > samples}, train_per_client ∈ {0, 1},
        // and a class-imbalanced spec — the satellite corners. With more
        // clients than samples most shards are empty; with tpc ∈ {0, 1}
        // whole classes have no samples at all.
        let mut rng = Rng::new(12);
        let tiny0 = SynthSpec::mnist_like().generate(0, 5, &mut rng); // tpc = 0
        let tiny1 = SynthSpec::mnist_like().generate(13, 5, &mut rng); // tpc = 1 at 13 clients
        let imb = SynthSpec::mnist_like()
            .imbalanced(&[0, 1, 2], 0.2)
            .generate(400, 5, &mut rng);
        for kind in [PartitionKind::NonIidA, PartitionKind::NonIidB] {
            for &(ds, n_clients) in &[
                (&tiny0, 1usize),
                (&tiny0, 7),
                (&tiny1, 13),
                (&tiny1, 97), // n_clients ≫ samples
                (&imb, 1),
                (&imb, 11),
                (&imb, 401), // n_clients > samples, prime
            ] {
                let seed = 9000 + n_clients as u64 * 17;
                assert_class_lazy_matches_eager(ds, n_clients, seed, kind);
            }
        }
    }

    #[test]
    fn lazy_noniid_matches_eager_property() {
        check("lazy non-IID == eager class deal", 15, |rng| {
            let train_n = [0usize, 1, 17, 230][rng.below(4)];
            let ds = SynthSpec::fmnist_like().generate(train_n, 5, rng);
            let n_clients = 1 + rng.below(40);
            let kind = if rng.bool(0.5) { PartitionKind::NonIidA } else { PartitionKind::NonIidB };
            assert_class_lazy_matches_eager(&ds, n_clients, rng.next_u64(), kind);
            Ok(())
        });
    }

    #[test]
    fn noniid_b_three_classes_each() {
        let mut rng = Rng::new(1);
        let ds = dataset(&mut rng);
        let p = Partition::build(PartitionKind::NonIidB, &ds, 20, &mut rng);
        for n in 0..p.n_clients() {
            let mut classes: Vec<i32> =
                p.indices_of(n).iter().map(|&i| ds.train_label(i)).collect();
            classes.sort_unstable();
            classes.dedup();
            assert!(classes.len() <= 3, "client {n} has {} classes", classes.len());
        }
    }

    #[test]
    fn noniid_a_class_counts_in_range() {
        let mut rng = Rng::new(2);
        let ds = dataset(&mut rng);
        let p = Partition::build(PartitionKind::NonIidA, &ds, 20, &mut rng);
        for n in 0..p.n_clients() {
            let mut classes: Vec<i32> =
                p.indices_of(n).iter().map(|&i| ds.train_label(i)).collect();
            classes.sort_unstable();
            classes.dedup();
            assert!((1..=10).contains(&classes.len()));
        }
    }

    #[test]
    fn partition_property_disjointness() {
        check("partitions never share samples", 10, |rng| {
            let ds = SynthSpec::fmnist_like().generate(500, 10, rng);
            for kind in [PartitionKind::Iid, PartitionKind::NonIidA, PartitionKind::NonIidB] {
                let p = Partition::build(kind, &ds, rng.int_range(2, 15), rng);
                let mut all: Vec<usize> =
                    (0..p.n_clients()).flat_map(|n| p.indices_of(n)).collect();
                let total = all.len();
                all.sort_unstable();
                all.dedup();
                if all.len() != total {
                    return Err(format!("{kind:?}: duplicated samples"));
                }
                if total > ds.train_len() {
                    return Err("more samples than dataset".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn label_distribution_sums_to_one() {
        let mut rng = Rng::new(3);
        let ds = dataset(&mut rng);
        let p = Partition::build(PartitionKind::NonIidB, &ds, 10, &mut rng);
        for dis in p.label_distribution(&ds) {
            let s: f64 = dis.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn distribution_score_favors_uniform() {
        let mut rng = Rng::new(4);
        let ds = dataset(&mut rng);
        let iid = Partition::iid(&ds, 5, &mut rng);
        let nb = Partition::build(PartitionKind::NonIidB, &ds, 5, &mut rng);
        let s_iid = iid.distribution_scores(&ds);
        let s_nb = nb.distribution_scores(&ds);
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(avg(&s_iid) > avg(&s_nb), "{s_iid:?} vs {s_nb:?}");
        // IID with plenty of data per class ≈ C * min(C * 1/C, 1) = 10
        assert!(avg(&s_iid) > 9.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn strided_m_n_rejects_out_of_range_ids() {
        // The stride formula would fabricate a plausible count for a
        // nonexistent client; it must panic like the Explicit arm.
        let mut rng = Rng::new(6);
        let ds = dataset(&mut rng);
        let p = Partition::iid(&ds, 5, &mut rng);
        let _ = p.m_n(7);
    }

    #[test]
    fn empty_and_tiny_shards_behave() {
        // 3 samples over 5 clients: clients 3 and 4 get nothing.
        let mut rng = Rng::new(5);
        let perm = rng.permutation(3);
        let p = Partition {
            num_classes: 10,
            assign: Assignment::Strided { perm: Arc::new(perm), n_clients: 5 },
        };
        assert_eq!(p.sizes().iter().sum::<usize>(), 3);
        assert!(p.shard(4).is_empty());
        assert_eq!(p.shard(4).len(), 0);
        assert_eq!(p.indices_of(4), Vec::<usize>::new());
        assert_eq!(p.shard(0).len(), 1);
    }

    #[test]
    fn noniid_partitions_hold_no_per_client_heaps() {
        // The whole point: shared lists + segment table, bounded well
        // below one usize per sample per claim, and shards own nothing.
        let mut rng = Rng::new(7);
        let ds = dataset(&mut rng);
        let p = Partition::build(PartitionKind::NonIidB, &ds, 50, &mut rng);
        let w = std::mem::size_of::<usize>();
        // shared lists ≈ train_len usizes; segments ≤ 3 per client.
        let budget = ds.train_len() * w + 50 * 3 * std::mem::size_of::<ClassSeg>() + 51 * 4 + 64;
        assert!(p.mem_bytes() <= budget, "{} > {budget}", p.mem_bytes());
        for n in 0..50 {
            assert_eq!(p.shard(n).owned_bytes(), 0);
        }
    }
}
