//! Client data partitioners — the paper's three heterogeneity settings
//! (§6.1): IID, Non-IID-a (2–10 random classes per client), Non-IID-b
//! (exactly 3 random classes per client).

use super::FedDataset;
use crate::util::rng::Rng;

/// Which samples each client owns (indices into the train set).
#[derive(Clone, Debug)]
pub struct Partition {
    pub client_indices: Vec<Vec<usize>>,
    pub num_classes: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    Iid,
    NonIidA,
    NonIidB,
}

impl PartitionKind {
    pub fn by_name(name: &str) -> anyhow::Result<PartitionKind> {
        match name {
            "iid" => Ok(PartitionKind::Iid),
            "noniid_a" | "noniid-a" => Ok(PartitionKind::NonIidA),
            "noniid_b" | "noniid-b" => Ok(PartitionKind::NonIidB),
            _ => anyhow::bail!("unknown partition {name:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionKind::Iid => "iid",
            PartitionKind::NonIidA => "noniid_a",
            PartitionKind::NonIidB => "noniid_b",
        }
    }
}

impl Partition {
    pub fn build(
        kind: PartitionKind,
        ds: &FedDataset,
        n_clients: usize,
        rng: &mut Rng,
    ) -> Partition {
        match kind {
            PartitionKind::Iid => Self::iid(ds, n_clients, rng),
            PartitionKind::NonIidA => Self::by_class_counts(ds, n_clients, rng, |rng| {
                rng.int_range(2, 10)
            }),
            PartitionKind::NonIidB => {
                Self::by_class_counts(ds, n_clients, rng, |_| 3)
            }
        }
    }

    /// Uniform shuffle-and-deal.
    pub fn iid(ds: &FedDataset, n_clients: usize, rng: &mut Rng) -> Partition {
        let mut idx = rng.permutation(ds.train_len());
        let mut client_indices = vec![Vec::new(); n_clients];
        for (i, sample) in idx.drain(..).enumerate() {
            client_indices[i % n_clients].push(sample);
        }
        Partition { client_indices, num_classes: ds.num_classes }
    }

    /// Label-restricted partition: each client claims `k = pick(rng)`
    /// classes; each class's samples are split evenly among its claimants.
    fn by_class_counts(
        ds: &FedDataset,
        n_clients: usize,
        rng: &mut Rng,
        pick: impl Fn(&mut Rng) -> usize,
    ) -> Partition {
        let c = ds.num_classes;
        // class -> shuffled sample indices
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); c];
        for i in 0..ds.train_len() {
            by_class[ds.train_y[i] as usize].push(i);
        }
        for v in &mut by_class {
            rng.shuffle(v);
        }
        // client -> claimed classes
        let claims: Vec<Vec<usize>> = (0..n_clients)
            .map(|_| {
                let k = pick(rng).min(c);
                rng.choose_k(c, k)
            })
            .collect();
        // class -> claimants
        let mut claimants: Vec<Vec<usize>> = vec![Vec::new(); c];
        for (client, classes) in claims.iter().enumerate() {
            for &cls in classes {
                claimants[cls].push(client);
            }
        }
        let mut client_indices = vec![Vec::new(); n_clients];
        for cls in 0..c {
            let owners = &claimants[cls];
            if owners.is_empty() {
                continue; // class unseen by everyone (rare; small n_clients)
            }
            for (i, &sample) in by_class[cls].iter().enumerate() {
                client_indices[owners[i % owners.len()]].push(sample);
            }
        }
        Partition { client_indices, num_classes: ds.num_classes }
    }

    pub fn n_clients(&self) -> usize {
        self.client_indices.len()
    }

    /// m_n — samples per client.
    pub fn sizes(&self) -> Vec<usize> {
        self.client_indices.iter().map(|v| v.len()).collect()
    }

    /// dis_n^c — per-client label distribution (fractions summing to 1).
    pub fn label_distribution(&self, ds: &FedDataset) -> Vec<Vec<f64>> {
        self.client_indices
            .iter()
            .map(|idxs| {
                let mut counts = vec![0usize; self.num_classes];
                for &i in idxs {
                    counts[ds.train_y[i] as usize] += 1;
                }
                let total = idxs.len().max(1) as f64;
                counts.iter().map(|&k| k as f64 / total).collect()
            })
            .collect()
    }

    /// The paper's data-distribution contribution term
    /// `Σ_c min(C · dis_n^c, 1)` (§4.1-2).
    pub fn distribution_scores(&self, ds: &FedDataset) -> Vec<f64> {
        let c = self.num_classes as f64;
        self.label_distribution(ds)
            .iter()
            .map(|dis| dis.iter().map(|&d| (c * d).min(1.0)).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::util::proptest::check;

    fn dataset(rng: &mut Rng) -> FedDataset {
        SynthSpec::mnist_like().generate(2000, 100, rng)
    }

    #[test]
    fn partitions_are_disjoint_and_complete_iid() {
        let mut rng = Rng::new(0);
        let ds = dataset(&mut rng);
        let p = Partition::iid(&ds, 10, &mut rng);
        let mut all: Vec<usize> = p.client_indices.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..2000).collect::<Vec<_>>());
    }

    #[test]
    fn noniid_b_three_classes_each() {
        let mut rng = Rng::new(1);
        let ds = dataset(&mut rng);
        let p = Partition::build(PartitionKind::NonIidB, &ds, 20, &mut rng);
        for (n, idxs) in p.client_indices.iter().enumerate() {
            let mut classes: Vec<i32> = idxs.iter().map(|&i| ds.train_y[i]).collect();
            classes.sort_unstable();
            classes.dedup();
            assert!(classes.len() <= 3, "client {n} has {} classes", classes.len());
        }
    }

    #[test]
    fn noniid_a_class_counts_in_range() {
        let mut rng = Rng::new(2);
        let ds = dataset(&mut rng);
        let p = Partition::build(PartitionKind::NonIidA, &ds, 20, &mut rng);
        for idxs in &p.client_indices {
            let mut classes: Vec<i32> = idxs.iter().map(|&i| ds.train_y[i]).collect();
            classes.sort_unstable();
            classes.dedup();
            assert!((1..=10).contains(&classes.len()));
        }
    }

    #[test]
    fn partition_property_disjointness() {
        check("partitions never share samples", 10, |rng| {
            let ds = SynthSpec::fmnist_like().generate(500, 10, rng);
            for kind in [PartitionKind::Iid, PartitionKind::NonIidA, PartitionKind::NonIidB] {
                let p = Partition::build(kind, &ds, rng.int_range(2, 15), rng);
                let mut all: Vec<usize> =
                    p.client_indices.iter().flatten().copied().collect();
                let total = all.len();
                all.sort_unstable();
                all.dedup();
                if all.len() != total {
                    return Err(format!("{kind:?}: duplicated samples"));
                }
                if total > ds.train_len() {
                    return Err("more samples than dataset".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn label_distribution_sums_to_one() {
        let mut rng = Rng::new(3);
        let ds = dataset(&mut rng);
        let p = Partition::build(PartitionKind::NonIidB, &ds, 10, &mut rng);
        for dis in p.label_distribution(&ds) {
            let s: f64 = dis.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn distribution_score_favors_uniform() {
        let mut rng = Rng::new(4);
        let ds = dataset(&mut rng);
        let iid = Partition::iid(&ds, 5, &mut rng);
        let nb = Partition::build(PartitionKind::NonIidB, &ds, 5, &mut rng);
        let s_iid = iid.distribution_scores(&ds);
        let s_nb = nb.distribution_scores(&ds);
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(avg(&s_iid) > avg(&s_nb), "{s_iid:?} vs {s_nb:?}");
        // IID with plenty of data per class ≈ C * min(C * 1/C, 1) = 10
        assert!(avg(&s_iid) > 9.0);
    }
}
