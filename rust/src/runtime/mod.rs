//! PJRT runtime: loads the AOT HLO-text artifacts (`make artifacts`) and
//! executes them from the coordinator hot path. Python is never invoked —
//! this is the only bridge between L3 and the L2/L1 computations.
//!
//! * [`registry`] — parses `artifacts/manifest.json` into typed metadata.
//! * [`pjrt`] — the `xla`-crate client wrapper: lazy compile cache,
//!   literal marshalling, and typed entry points for train / eval / the
//!   Pallas kernel artifacts (masked aggregation, importance, sgd).

mod pjrt;
mod registry;

pub use pjrt::*;
pub use registry::*;
