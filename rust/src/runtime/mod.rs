//! Artifact runtime: loads AOT artifacts and executes them from the
//! coordinator hot path. Python is never invoked — this is the only
//! bridge between L3 and the L2/L1 computations.
//!
//! * `registry` — parses `artifacts/manifest.json` into typed metadata
//!   and writes native-exec manifests (`write_native_manifest`).
//! * `pjrt` — the thread-safe runtime front-end: lazy compile cache,
//!   literal marshalling, typed entry points for train / eval / the
//!   Pallas kernel artifacts, and backend dispatch.
//! * `native` — pure-Rust executor for FC models (manifests with
//!   `"exec": "native"`); lets the threaded round engine run end-to-end
//!   on hosts without a libxla build. Its forward/backward working set
//!   comes from a per-thread buffer pool reused across calls (see the
//!   module docs), sized for the persistent worker pool's long-lived
//!   threads.

mod native;
mod pjrt;
mod registry;

pub use pjrt::*;
pub use registry::*;

/// Test support: sentinel-poison the calling thread's native-executor
/// buffer pool (NaN-fill every idle buffer in place). Part of the
/// scratch-poisoning determinism battery — see
/// `FedRun::poison_worker_scratch` and `rust/tests/pool_determinism.rs`.
pub fn poison_native_scratch() {
    native::poison_thread_scratch();
}
