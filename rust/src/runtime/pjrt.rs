//! Runtime front-end: compile-once executable cache plus typed entry
//! points for the train/eval artifacts and the flat Pallas kernels, with
//! two execution backends selected by the artifact manifest:
//!
//! * **pjrt** — the `xla`-crate PJRT CPU client executing AOT HLO text
//!   (interchange notes: see /opt/xla-example/README.md; artifacts are HLO
//!   *text* because `HloModuleProto::from_text_file` reassigns instruction
//!   ids, so text round-trips where serialized jax≥0.5 protos do not;
//!   executables were lowered with `return_tuple=True`).
//! * **native** — `"exec": "native"` manifests route the typed entry
//!   points to the pure-Rust FC executor in `super::native` (no libxla).
//!
//! The runtime is `Send + Sync`: the executable cache and the stats
//! counters sit behind mutexes so the threaded round engine can train
//! clients concurrently against one shared `Runtime`. PJRT executions
//! serialize on the cache only during compile misses; steady-state calls
//! take the lock for a map lookup.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::native::NativeExec;
use super::registry::{ArtifactMeta, Dtype, Manifest};
use crate::tensor::Tensor;

/// Cumulative execution counters (perf accounting; see EXPERIMENTS §Perf).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_seconds: f64,
    pub compile_seconds: f64,
    pub compiled: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

enum ExecBackend {
    Pjrt {
        client: PjRtClient,
        cache: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
    },
    Native(NativeExec),
}

/// Artifact runtime with a lazy executable cache (PJRT) or the native
/// executor, chosen by `manifest.exec`.
pub struct Runtime {
    backend: ExecBackend,
    manifest: Manifest,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    pub fn new(artifacts_dir: &std::path::Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = if manifest.exec == "native" {
            log::info!(
                "native runtime up ({} artifacts, FC models)",
                manifest.artifacts.len()
            );
            ExecBackend::Native(NativeExec)
        } else {
            let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
            log::info!(
                "PJRT client up: platform={} devices={} ({} artifacts)",
                client.platform_name(),
                client.device_count(),
                manifest.artifacts.len()
            );
            ExecBackend::Pjrt { client, cache: Mutex::new(HashMap::new()) }
        };
        Ok(Runtime {
            backend,
            manifest,
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Whether this runtime executes natively (no PJRT client).
    pub fn is_native(&self) -> bool {
        matches!(self.backend, ExecBackend::Native(_))
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    fn count_exec(&self, t0: Instant) {
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.exec_seconds += t0.elapsed().as_secs_f64();
    }

    /// Compile (or fetch cached) a PJRT artifact by name. Errors on the
    /// native backend — native execution goes through the typed entry
    /// points, which need no compiled handle.
    pub fn executable(&self, name: &str) -> anyhow::Result<Arc<PjRtLoadedExecutable>> {
        let ExecBackend::Pjrt { client, cache } = &self.backend else {
            anyhow::bail!("artifact {name:?}: native runtime has no PJRT executables");
        };
        if let Some(e) = cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        // Compile outside the cache lock; a racing duplicate compile is
        // benign and the first insert wins.
        let meta = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("loading {:?}: {e}", meta.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.lock().unwrap();
            s.compile_seconds += dt;
            s.compiled += 1;
        }
        log::debug!("compiled {name} in {dt:.2}s");
        let rc = Arc::new(exe);
        Ok(Arc::clone(
            cache
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert(rc),
        ))
    }

    /// Raw PJRT execute: literals in, tuple-decomposed literals out.
    pub fn execute(&self, name: &str, args: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e}"))?;
        let outs = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name}: {e}"))?;
        self.count_exec(t0);
        Ok(outs)
    }

    // ---------------- literal marshalling ----------------

    pub fn lit_f32(&self, data: &[f32], shape: &[usize]) -> anyhow::Result<Literal> {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        self.stats.lock().unwrap().h2d_bytes += (data.len() * 4) as u64;
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    pub fn lit_i32(&self, data: &[i32], shape: &[usize]) -> anyhow::Result<Literal> {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        self.stats.lock().unwrap().h2d_bytes += (data.len() * 4) as u64;
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    pub fn lit_tensor(&self, t: &Tensor) -> anyhow::Result<Literal> {
        self.lit_f32(t.data(), t.shape())
    }

    pub fn tensor_from(&self, lit: &Literal, shape: Vec<usize>) -> anyhow::Result<Tensor> {
        let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
        self.stats.lock().unwrap().d2h_bytes += (v.len() * 4) as u64;
        Ok(Tensor::new(shape, v))
    }

    // ---------------- typed entry points ----------------

    /// One local SGD step: params are updated in place; returns the loss.
    /// `x` is the flattened batch (artifact shape), `y` the labels.
    pub fn train_step(
        &self,
        artifact: &str,
        params: &mut Vec<Tensor>,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> anyhow::Result<f32> {
        let meta = self.manifest.get(artifact)?.clone();
        anyhow::ensure!(meta.kind == "train", "{artifact} is not a train artifact");
        match &self.backend {
            ExecBackend::Native(nx) => {
                let t0 = Instant::now();
                let loss = nx.train_step(&meta, params, x, y, lr)?;
                self.count_exec(t0);
                Ok(loss)
            }
            ExecBackend::Pjrt { .. } => self.exec_train_pjrt(&meta, artifact, params, x, y, lr),
        }
    }

    /// Fused multi-step (lax.scan) variant: `xs`/`ys` hold `steps` batches.
    pub fn train_scan(
        &self,
        artifact: &str,
        params: &mut Vec<Tensor>,
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> anyhow::Result<f32> {
        let meta = self.manifest.get(artifact)?.clone();
        anyhow::ensure!(
            meta.kind == "train_scan",
            "{artifact} is not a train_scan artifact"
        );
        match &self.backend {
            ExecBackend::Native(nx) => {
                let t0 = Instant::now();
                let loss = nx.train_scan(&meta, params, xs, ys, lr)?;
                self.count_exec(t0);
                Ok(loss)
            }
            ExecBackend::Pjrt { .. } => self.exec_train_pjrt(&meta, artifact, params, xs, ys, lr),
        }
    }

    fn exec_train_pjrt(
        &self,
        meta: &ArtifactMeta,
        artifact: &str,
        params: &mut Vec<Tensor>,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(
            params.len() == meta.params.len(),
            "param count mismatch for {artifact}"
        );
        let mut args = Vec::with_capacity(params.len() + 3);
        for (t, (pname, pshape)) in params.iter().zip(&meta.params) {
            anyhow::ensure!(
                t.shape() == &pshape[..],
                "shape mismatch for {artifact}:{pname}: {:?} vs {:?}",
                t.shape(),
                pshape
            );
            args.push(self.lit_tensor(t)?);
        }
        let x_meta = &meta.inputs[0];
        let y_meta = &meta.inputs[1];
        args.push(self.lit_f32(x, &x_meta.shape)?);
        debug_assert_eq!(y_meta.dtype, Dtype::I32);
        args.push(self.lit_i32(y, &y_meta.shape)?);
        args.push(self.lit_f32(&[lr], &[1])?);
        let outs = self.execute(artifact, &args)?;
        anyhow::ensure!(
            outs.len() == params.len() + 1,
            "unexpected output arity {} for {artifact}",
            outs.len()
        );
        for (i, t) in params.iter_mut().enumerate() {
            *t = self.tensor_from(&outs[i], t.shape().to_vec())?;
        }
        let loss: Vec<f32> = outs[params.len()]
            .to_vec()
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(loss[0])
    }

    /// Evaluate one batch: returns (loss_sum, per-class correct, per-class
    /// count).
    pub fn eval_batch(
        &self,
        artifact: &str,
        params: &[Tensor],
        x: &[f32],
        y: &[i32],
    ) -> anyhow::Result<(f32, Vec<f32>, Vec<f32>)> {
        let meta = self.manifest.get(artifact)?.clone();
        anyhow::ensure!(meta.kind == "eval", "{artifact} is not an eval artifact");
        if let ExecBackend::Native(nx) = &self.backend {
            let t0 = Instant::now();
            let out = nx.eval_batch(&meta, params, x, y)?;
            self.count_exec(t0);
            return Ok(out);
        }
        let mut args = Vec::with_capacity(params.len() + 2);
        for t in params {
            args.push(self.lit_tensor(t)?);
        }
        args.push(self.lit_f32(x, &meta.inputs[0].shape)?);
        args.push(self.lit_i32(y, &meta.inputs[1].shape)?);
        let outs = self.execute(artifact, &args)?;
        let loss: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
        let correct: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
        let count: Vec<f32> = outs[2].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok((loss[0], correct, count))
    }

    // ---------------- flat Pallas kernels ----------------
    //
    // The kernel artifacts operate on fixed-size chunks
    // (manifest.kernel_chunk); these helpers stream arbitrary-length flat
    // buffers through them with zero-padding on the tail chunk. On the
    // native backend they dispatch straight to the rust tensor-op mirrors
    // (the same math the Pallas kernels implement).

    fn kernel_name(&self, op: &str) -> anyhow::Result<String> {
        Ok(self.manifest.kernel(op)?.name.clone())
    }

    /// num/den += masked contribution of one client (Pallas masked_acc).
    pub fn k_masked_acc(
        &self,
        num: &mut [f32],
        den: &mut [f32],
        w: &[f32],
        mask: &[f32],
        mn: f32,
    ) -> anyhow::Result<()> {
        if let ExecBackend::Native(_) = &self.backend {
            let t0 = Instant::now();
            crate::tensor::axpy_masked(num, mn, w, mask);
            crate::tensor::axpy(den, mn, mask);
            self.count_exec(t0);
            return Ok(());
        }
        let chunk = self.manifest.kernel_chunk;
        let name = self.kernel_name("masked_acc")?;
        let mn_lit = self.lit_f32(&[mn], &[1])?;
        let n = num.len();
        let mut buf_n = vec![0.0f32; chunk];
        let mut buf_d = vec![0.0f32; chunk];
        let mut buf_w = vec![0.0f32; chunk];
        let mut buf_m = vec![0.0f32; chunk];
        let mut start = 0;
        while start < n {
            let len = chunk.min(n - start);
            buf_n[..len].copy_from_slice(&num[start..start + len]);
            buf_d[..len].copy_from_slice(&den[start..start + len]);
            buf_w[..len].copy_from_slice(&w[start..start + len]);
            buf_m[..len].copy_from_slice(&mask[start..start + len]);
            if len < chunk {
                buf_n[len..].fill(0.0);
                buf_d[len..].fill(0.0);
                buf_w[len..].fill(0.0);
                buf_m[len..].fill(0.0);
            }
            let args = vec![
                self.lit_f32(&buf_n, &[chunk])?,
                self.lit_f32(&buf_d, &[chunk])?,
                self.lit_f32(&buf_w, &[chunk])?,
                self.lit_f32(&buf_m, &[chunk])?,
                mn_lit.clone(),
            ];
            let outs = self.execute(&name, &args)?;
            let on: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
            let od: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
            num[start..start + len].copy_from_slice(&on[..len]);
            den[start..start + len].copy_from_slice(&od[..len]);
            start += len;
        }
        Ok(())
    }

    /// Finalize Eq. 4 with the zero-coverage rule (Pallas masked_fin).
    pub fn k_masked_fin(
        &self,
        num: &[f32],
        den: &[f32],
        prev: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        if let ExecBackend::Native(_) = &self.backend {
            let t0 = Instant::now();
            crate::tensor::masked_div(out, num, den, prev);
            self.count_exec(t0);
            return Ok(());
        }
        let chunk = self.manifest.kernel_chunk;
        let name = self.kernel_name("masked_fin")?;
        let n = num.len();
        let mut bn = vec![0.0f32; chunk];
        let mut bd = vec![0.0f32; chunk];
        let mut bp = vec![0.0f32; chunk];
        let mut start = 0;
        while start < n {
            let len = chunk.min(n - start);
            bn[..len].copy_from_slice(&num[start..start + len]);
            bd[..len].copy_from_slice(&den[start..start + len]);
            bp[..len].copy_from_slice(&prev[start..start + len]);
            if len < chunk {
                bn[len..].fill(0.0);
                bd[len..].fill(0.0);
                bp[len..].fill(0.0);
            }
            let args = vec![
                self.lit_f32(&bn, &[chunk])?,
                self.lit_f32(&bd, &[chunk])?,
                self.lit_f32(&bp, &[chunk])?,
            ];
            let outs = self.execute(&name, &args)?;
            let o: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
            out[start..start + len].copy_from_slice(&o[..len]);
            start += len;
        }
        Ok(())
    }

    /// Importance elementwise scores (Pallas importance kernel).
    pub fn k_importance(&self, w: &[f32], dw: &[f32], out: &mut [f32]) -> anyhow::Result<()> {
        if let ExecBackend::Native(_) = &self.backend {
            let t0 = Instant::now();
            crate::tensor::importance_scores(out, w, dw);
            self.count_exec(t0);
            return Ok(());
        }
        let chunk = self.manifest.kernel_chunk;
        let name = self.kernel_name("importance")?;
        let n = w.len();
        let mut bw = vec![0.0f32; chunk];
        let mut bd = vec![0.0f32; chunk];
        let mut start = 0;
        while start < n {
            let len = chunk.min(n - start);
            bw[..len].copy_from_slice(&w[start..start + len]);
            bd[..len].copy_from_slice(&dw[start..start + len]);
            if len < chunk {
                bw[len..].fill(1.0); // avoid 0/0 in padding
                bd[len..].fill(0.0);
            }
            let args = vec![self.lit_f32(&bw, &[chunk])?, self.lit_f32(&bd, &[chunk])?];
            let outs = self.execute(&name, &args)?;
            let o: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
            out[start..start + len].copy_from_slice(&o[..len]);
            start += len;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // PJRT execution is covered by rust/tests/runtime_goldens.rs (it needs
    // built artifacts); native execution by runtime/native.rs and
    // rust/tests/parallel_round.rs. Here: pure helpers + thread-safety.
    use super::super::registry::{default_artifacts_dir, write_native_manifest};
    use super::Runtime;

    #[test]
    fn artifacts_dir_resolution_does_not_panic() {
        let _ = default_artifacts_dir();
    }

    #[test]
    fn runtime_is_send_and_sync() {
        // The threaded round engine shares one Runtime across workers.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
    }

    #[test]
    fn native_runtime_constructs_and_runs_kernels() {
        let dir = std::env::temp_dir().join(format!(
            "feddd_native_manifest_{}_pjrt",
            std::process::id()
        ));
        write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert!(rt.is_native());
        let w = [1.0f32, 2.0, 3.0];
        let mask = [1.0f32, 0.0, 1.0];
        let mut num = [0.0f32; 3];
        let mut den = [0.0f32; 3];
        rt.k_masked_acc(&mut num, &mut den, &w, &mask, 2.0).unwrap();
        assert_eq!(num, [2.0, 0.0, 6.0]);
        assert_eq!(den, [2.0, 0.0, 2.0]);
        let mut out = [0.0f32; 3];
        rt.k_masked_fin(&num, &den, &[9.0, 9.0, 9.0], &mut out).unwrap();
        assert_eq!(out, [1.0, 9.0, 3.0]);
        assert!(rt.stats().executions >= 2);
        assert!(rt.executable("mlp_w100_train").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
