//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => anyhow::bail!("unknown dtype {s:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct InputMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    /// "train" | "train_scan" | "eval" | "kernel".
    pub kind: String,
    /// For kernels: "masked_acc" | "masked_fin" | "importance" | "sgd".
    pub op: Option<String>,
    pub model: Option<String>,
    pub width: f64,
    pub batch: usize,
    pub steps: usize,
    pub chunk: usize,
    /// Ordered parameter tensors (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
    /// Non-parameter inputs, in call order after the params.
    pub inputs: Vec<InputMeta>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct ModelGeom {
    pub name: String,
    pub width: f64,
    pub param_count: usize,
    /// (kind, in, out) per layer.
    pub layers: Vec<(String, usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub kernel_chunk: usize,
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub models: Vec<ModelGeom>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = json::from_file(&dir.join("manifest.json"))?;
        let mut artifacts = HashMap::new();
        for a in j.req_arr("artifacts")? {
            let name = a.req_str("name")?.to_string();
            let params = match a.get("params") {
                Some(Json::Arr(ps)) => ps
                    .iter()
                    .map(|p| {
                        Ok((
                            p.req_str("name")?.to_string(),
                            p.req_arr("shape")?
                                .iter()
                                .filter_map(|x| x.as_usize())
                                .collect(),
                        ))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
                _ => Vec::new(),
            };
            let inputs = match a.get("inputs") {
                Some(Json::Arr(is_)) => is_
                    .iter()
                    .filter_map(|i| {
                        // kernels list inputs as plain strings
                        i.as_str().map(|s| InputMeta {
                            name: s.to_string(),
                            shape: vec![],
                            dtype: Dtype::F32,
                        })
                    })
                    .chain(is_.iter().filter_map(|i| {
                        if i.as_str().is_some() {
                            return None;
                        }
                        Some(InputMeta {
                            name: i.req_str("name").ok()?.to_string(),
                            shape: i
                                .req_arr("shape")
                                .ok()?
                                .iter()
                                .filter_map(|x| x.as_usize())
                                .collect(),
                            dtype: Dtype::parse(
                                i.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32"),
                            )
                            .ok()?,
                        })
                    }))
                    .collect(),
                _ => Vec::new(),
            };
            let outputs = match a.get("outputs") {
                Some(Json::Arr(os)) => os
                    .iter()
                    .filter_map(|o| o.as_str().map(String::from))
                    .collect(),
                _ => Vec::new(),
            };
            let meta = ArtifactMeta {
                file: dir.join(a.req_str("file")?),
                kind: a.req_str("kind")?.to_string(),
                op: a.get("op").and_then(|x| x.as_str()).map(String::from),
                model: a.get("model").and_then(|x| x.as_str()).map(String::from),
                width: a.get("width").and_then(|x| x.as_f64()).unwrap_or(1.0),
                batch: a.get("batch").and_then(|x| x.as_usize()).unwrap_or(0),
                steps: a.get("steps").and_then(|x| x.as_usize()).unwrap_or(1),
                chunk: a.get("chunk").and_then(|x| x.as_usize()).unwrap_or(0),
                params,
                inputs,
                outputs,
                name: name.clone(),
            };
            artifacts.insert(name, meta);
        }
        let models = match j.get("models") {
            Some(Json::Arr(ms)) => ms
                .iter()
                .map(|m| {
                    Ok(ModelGeom {
                        name: m.req_str("name")?.to_string(),
                        width: m.req_f64("width")?,
                        param_count: m.req_usize("param_count")?,
                        layers: m
                            .req_arr("layers")?
                            .iter()
                            .map(|l| {
                                Ok((
                                    l.req_str("kind")?.to_string(),
                                    l.req_usize("in")?,
                                    l.req_usize("out")?,
                                ))
                            })
                            .collect::<anyhow::Result<Vec<_>>>()?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            train_batch: j.req_usize("train_batch")?,
            eval_batch: j.req_usize("eval_batch")?,
            kernel_chunk: j.req_usize("kernel_chunk")?,
            artifacts,
            models,
        })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }

    /// Find the kernel artifact for an op name.
    pub fn kernel(&self, op: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .values()
            .find(|a| a.kind == "kernel" && a.op.as_deref() == Some(op))
            .ok_or_else(|| anyhow::anyhow!("kernel op {op:?} not in manifest"))
    }
}

/// Default artifacts dir (repo-root relative), honoring FEDDD_ARTIFACTS.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FEDDD_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Walk up from cwd looking for artifacts/manifest.json (tests run from
    // target subdirs).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..5 {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_manifest_when_built() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(m.artifacts.len() >= 30);
        assert_eq!(m.kernel_chunk, 16384);
        let t = m.get("mlp_w100_train").unwrap();
        assert_eq!(t.kind, "train");
        assert_eq!(t.params.len(), 6);
        assert_eq!(t.params[0].1, vec![784, 100]);
        assert_eq!(t.inputs.len(), 3);
        assert_eq!(t.inputs[1].dtype, Dtype::I32);
        assert!(t.file.exists());
    }

    #[test]
    fn kernel_lookup() {
        let Some(m) = manifest() else { return };
        for op in ["masked_acc", "masked_fin", "importance", "sgd"] {
            let k = m.kernel(op).unwrap();
            assert_eq!(k.chunk, 16384);
        }
        assert!(m.kernel("nope").is_err());
    }

    #[test]
    fn geometry_matches_rust_registry() {
        let Some(m) = manifest() else { return };
        for g in &m.models {
            let spec =
                crate::model::ModelSpec::get(&g.name, g.width).unwrap();
            assert_eq!(
                spec.param_count(),
                g.param_count,
                "param count drift for {} w={}",
                g.name,
                g.width
            );
            assert_eq!(spec.layers.len(), g.layers.len());
            for (a, b) in spec.layers.iter().zip(&g.layers) {
                assert_eq!(a.in_dim, b.1, "{}", g.name);
                assert_eq!(a.out_dim, b.2, "{}", g.name);
            }
        }
    }
}
