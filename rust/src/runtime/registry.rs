//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => anyhow::bail!("unknown dtype {s:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct InputMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    /// "train" | "train_scan" | "eval" | "kernel".
    pub kind: String,
    /// For kernels: "masked_acc" | "masked_fin" | "importance" | "sgd".
    pub op: Option<String>,
    pub model: Option<String>,
    pub width: f64,
    pub batch: usize,
    pub steps: usize,
    pub chunk: usize,
    /// Ordered parameter tensors (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
    /// Non-parameter inputs, in call order after the params.
    pub inputs: Vec<InputMeta>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct ModelGeom {
    pub name: String,
    pub width: f64,
    pub param_count: usize,
    /// (kind, in, out) per layer.
    pub layers: Vec<(String, usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Execution backend the artifacts were built for: "pjrt" (AOT HLO
    /// text through the xla crate, the default) or "native" (pure-Rust
    /// executor in `runtime::native` — FC models only, no libxla needed).
    pub exec: String,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub kernel_chunk: usize,
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub models: Vec<ModelGeom>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = json::from_file(&dir.join("manifest.json"))?;
        let mut artifacts = HashMap::new();
        for a in j.req_arr("artifacts")? {
            let name = a.req_str("name")?.to_string();
            let params = match a.get("params") {
                Some(Json::Arr(ps)) => ps
                    .iter()
                    .map(|p| {
                        Ok((
                            p.req_str("name")?.to_string(),
                            p.req_arr("shape")?
                                .iter()
                                .filter_map(|x| x.as_usize())
                                .collect(),
                        ))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
                _ => Vec::new(),
            };
            let inputs = match a.get("inputs") {
                Some(Json::Arr(is_)) => is_
                    .iter()
                    .filter_map(|i| {
                        // kernels list inputs as plain strings
                        i.as_str().map(|s| InputMeta {
                            name: s.to_string(),
                            shape: vec![],
                            dtype: Dtype::F32,
                        })
                    })
                    .chain(is_.iter().filter_map(|i| {
                        if i.as_str().is_some() {
                            return None;
                        }
                        Some(InputMeta {
                            name: i.req_str("name").ok()?.to_string(),
                            shape: i
                                .req_arr("shape")
                                .ok()?
                                .iter()
                                .filter_map(|x| x.as_usize())
                                .collect(),
                            dtype: Dtype::parse(
                                i.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32"),
                            )
                            .ok()?,
                        })
                    }))
                    .collect(),
                _ => Vec::new(),
            };
            let outputs = match a.get("outputs") {
                Some(Json::Arr(os)) => os
                    .iter()
                    .filter_map(|o| o.as_str().map(String::from))
                    .collect(),
                _ => Vec::new(),
            };
            let meta = ArtifactMeta {
                file: dir.join(a.req_str("file")?),
                kind: a.req_str("kind")?.to_string(),
                op: a.get("op").and_then(|x| x.as_str()).map(String::from),
                model: a.get("model").and_then(|x| x.as_str()).map(String::from),
                width: a.get("width").and_then(|x| x.as_f64()).unwrap_or(1.0),
                batch: a.get("batch").and_then(|x| x.as_usize()).unwrap_or(0),
                steps: a.get("steps").and_then(|x| x.as_usize()).unwrap_or(1),
                chunk: a.get("chunk").and_then(|x| x.as_usize()).unwrap_or(0),
                params,
                inputs,
                outputs,
                name: name.clone(),
            };
            artifacts.insert(name, meta);
        }
        let models = match j.get("models") {
            Some(Json::Arr(ms)) => ms
                .iter()
                .map(|m| {
                    Ok(ModelGeom {
                        name: m.req_str("name")?.to_string(),
                        width: m.req_f64("width")?,
                        param_count: m.req_usize("param_count")?,
                        layers: m
                            .req_arr("layers")?
                            .iter()
                            .map(|l| {
                                Ok((
                                    l.req_str("kind")?.to_string(),
                                    l.req_usize("in")?,
                                    l.req_usize("out")?,
                                ))
                            })
                            .collect::<anyhow::Result<Vec<_>>>()?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            exec: j
                .get("exec")
                .and_then(|x| x.as_str())
                .unwrap_or("pjrt")
                .to_string(),
            train_batch: j.req_usize("train_batch")?,
            eval_batch: j.req_usize("eval_batch")?,
            kernel_chunk: j.req_usize("kernel_chunk")?,
            artifacts,
            models,
        })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }

    /// Find the kernel artifact for an op name.
    pub fn kernel(&self, op: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .values()
            .find(|a| a.kind == "kernel" && a.op.as_deref() == Some(op))
            .ok_or_else(|| anyhow::anyhow!("kernel op {op:?} not in manifest"))
    }
}

/// Write a `"exec": "native"` manifest (plus marker files) into `dir` for
/// the given `(model, width)` pairs — train + eval artifacts per model and
/// the four flat kernels. This replaces `make artifacts` on hosts without
/// a JAX/XLA toolchain: the resulting manifest drives the pure-Rust
/// executor in `super::native`, which supports FC models (the `mlp`
/// family). Used by the parallel-round tests and the round bench.
pub fn write_native_manifest(
    dir: &Path,
    models: &[(&str, f64)],
    train_batch: usize,
    eval_batch: usize,
) -> anyhow::Result<()> {
    use crate::model::{LayerKind, ModelSpec};

    std::fs::create_dir_all(dir)?;
    let mut artifacts: Vec<Json> = Vec::new();
    let mut geoms: Vec<Json> = Vec::new();
    for &(name, width) in models {
        let spec = ModelSpec::get(name, width)?;
        let tag = spec.id.tag();
        let params_json: Vec<Json> = spec
            .param_shapes()
            .into_iter()
            .map(|(pname, shape)| {
                Json::obj(vec![
                    ("name", Json::s(&pname)),
                    ("shape", Json::arr_usize(&shape)),
                ])
            })
            .collect();
        for (kind, batch) in [("train", train_batch), ("eval", eval_batch)] {
            let aname = format!("{tag}_{kind}");
            let fname = format!("{aname}.native.txt");
            std::fs::write(
                dir.join(&fname),
                format!("native-exec artifact {aname}: no HLO; executed by rust/src/runtime/native.rs\n"),
            )?;
            let mut x_shape = vec![batch];
            x_shape.extend(&spec.input_shape);
            let mut inputs = vec![
                Json::obj(vec![
                    ("name", Json::s("x")),
                    ("shape", Json::arr_usize(&x_shape)),
                    ("dtype", Json::s("f32")),
                ]),
                Json::obj(vec![
                    ("name", Json::s("y")),
                    ("shape", Json::arr_usize(&[batch])),
                    ("dtype", Json::s("i32")),
                ]),
            ];
            if kind == "train" {
                inputs.push(Json::obj(vec![
                    ("name", Json::s("lr")),
                    ("shape", Json::arr_usize(&[1])),
                    ("dtype", Json::s("f32")),
                ]));
            }
            artifacts.push(Json::obj(vec![
                ("name", Json::s(&aname)),
                ("file", Json::s(&fname)),
                ("kind", Json::s(kind)),
                ("model", Json::s(name)),
                ("width", Json::Num(width)),
                ("batch", Json::Num(batch as f64)),
                ("params", Json::Arr(params_json.clone())),
                ("inputs", Json::Arr(inputs)),
                ("outputs", Json::Arr(Vec::new())),
            ]));
        }
        geoms.push(Json::obj(vec![
            ("name", Json::s(name)),
            ("width", Json::Num(width)),
            ("param_count", Json::Num(spec.param_count() as f64)),
            (
                "layers",
                Json::Arr(
                    spec.layers
                        .iter()
                        .map(|l| {
                            let kind = match l.kind {
                                LayerKind::Conv { .. } => "conv",
                                LayerKind::Fc => "fc",
                            };
                            Json::obj(vec![
                                ("kind", Json::s(kind)),
                                ("in", Json::Num(l.in_dim as f64)),
                                ("out", Json::Num(l.out_dim as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    for op in ["masked_acc", "masked_fin", "importance", "sgd"] {
        let aname = format!("kernel_{op}");
        let fname = format!("{aname}.native.txt");
        std::fs::write(
            dir.join(&fname),
            format!("native-exec kernel {op}: mirrored by rust tensor ops\n"),
        )?;
        artifacts.push(Json::obj(vec![
            ("name", Json::s(&aname)),
            ("file", Json::s(&fname)),
            ("kind", Json::s("kernel")),
            ("op", Json::s(op)),
            ("chunk", Json::Num(16384.0)),
        ]));
    }
    let manifest = Json::obj(vec![
        ("exec", Json::s("native")),
        ("train_batch", Json::Num(train_batch as f64)),
        ("eval_batch", Json::Num(eval_batch as f64)),
        ("kernel_chunk", Json::Num(16384.0)),
        ("artifacts", Json::Arr(artifacts)),
        ("models", Json::Arr(geoms)),
    ]);
    json::to_file(&dir.join("manifest.json"), &manifest)
}

/// Default artifacts dir (repo-root relative), honoring FEDDD_ARTIFACTS.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FEDDD_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Walk up from cwd looking for artifacts/manifest.json (tests run from
    // target subdirs).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..5 {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_manifest_when_built() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(m.artifacts.len() >= 30);
        assert_eq!(m.kernel_chunk, 16384);
        let t = m.get("mlp_w100_train").unwrap();
        assert_eq!(t.kind, "train");
        assert_eq!(t.params.len(), 6);
        assert_eq!(t.params[0].1, vec![784, 100]);
        assert_eq!(t.inputs.len(), 3);
        assert_eq!(t.inputs[1].dtype, Dtype::I32);
        assert!(t.file.exists());
    }

    #[test]
    fn native_manifest_roundtrips() {
        let dir = std::env::temp_dir().join(format!(
            "feddd_native_manifest_{}_registry",
            std::process::id()
        ));
        write_native_manifest(&dir, &[("mlp", 1.0), ("mlp", 0.25)], 16, 64).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.exec, "native");
        assert_eq!(m.train_batch, 16);
        assert_eq!(m.eval_batch, 64);
        assert_eq!(m.kernel_chunk, 16384);
        let t = m.get("mlp_w100_train").unwrap();
        assert_eq!(t.kind, "train");
        assert_eq!(t.model.as_deref(), Some("mlp"));
        assert_eq!(t.batch, 16);
        assert_eq!(t.params.len(), 6);
        assert_eq!(t.params[0].1, vec![784, 100]);
        assert_eq!(t.inputs.len(), 3);
        assert_eq!(t.inputs[1].dtype, Dtype::I32);
        assert!(t.file.exists());
        let e = m.get("mlp_w25_eval").unwrap();
        assert_eq!(e.batch, 64);
        for op in ["masked_acc", "masked_fin", "importance", "sgd"] {
            assert_eq!(m.kernel(op).unwrap().chunk, 16384);
        }
        assert_eq!(m.models.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kernel_lookup() {
        let Some(m) = manifest() else { return };
        for op in ["masked_acc", "masked_fin", "importance", "sgd"] {
            let k = m.kernel(op).unwrap();
            assert_eq!(k.chunk, 16384);
        }
        assert!(m.kernel("nope").is_err());
    }

    #[test]
    fn geometry_matches_rust_registry() {
        let Some(m) = manifest() else { return };
        for g in &m.models {
            let spec =
                crate::model::ModelSpec::get(&g.name, g.width).unwrap();
            assert_eq!(
                spec.param_count(),
                g.param_count,
                "param count drift for {} w={}",
                g.name,
                g.width
            );
            assert_eq!(spec.layers.len(), g.layers.len());
            for (a, b) in spec.layers.iter().zip(&g.layers) {
                assert_eq!(a.in_dim, b.1, "{}", g.name);
                assert_eq!(a.out_dim, b.2, "{}", g.name);
            }
        }
    }
}
