//! Native CPU executor for artifact manifests declaring `"exec": "native"`.
//!
//! The PJRT path executes AOT HLO artifacts; this module is the pure-Rust
//! mirror for fully-connected models (the `mlp` family): forward, softmax
//! cross-entropy, backward and the SGD update, matching the math of
//! `python/compile/model.py` (`forward` / `loss_fn` / `train_step` /
//! `eval_batch`). It exists so the coordinator — including the threaded
//! round engine and its determinism tests — can run end-to-end on hosts
//! without a libxla build. Conv models still require PJRT artifacts and
//! fail with an explicit error here.
//!
//! Everything is plain `f32` loops with a fixed accumulation order, so a
//! given (params, batch) pair produces bit-identical results no matter
//! which worker thread executes it — the property the parallel round
//! engine's `workers=N ≡ workers=1` guarantee rests on.
//!
//! # Blocked kernels (DESIGN.md §Kernels)
//!
//! The FC forward/backward run as blocked kernels: batch rows are
//! processed in blocks of [`MR`] so a weight row loaded from memory is
//! reused across the block (the W matrix streams through the cache once
//! per MR samples instead of once per sample), and the per-row inner
//! loops are elementwise axpys over contiguous slices, tiled in
//! fixed-size [`NR`]-wide chunks ([`axpy`]) that the autovectorizer
//! turns into SIMD lanes. The blocking never touches numerics: it only
//! reorders *independent* output elements, while the reduction chain
//! feeding each individual element keeps its original order (forward
//! output `o[i,k]`: j ascending; weight gradient `dw[j,k]`: i ascending;
//! input gradient dot products: k ascending, single accumulator) — so
//! the blocked kernels are bitwise-identical to the scalar loops they
//! replaced, and the `workers=N ≡ workers=1` battery holds unchanged.
//!
//! # Per-thread buffer pool
//!
//! The forward/backward working set (activations, logit gradients, dW /
//! db / upstream deltas) is drawn from a thread-local pool of `Vec<f32>`
//! buffers instead of freshly allocated per step: on the persistent
//! worker pool the same ~7 buffers serve every micro-batch and round of
//! a run. Each take either zero-fills (`take_zeroed`) or copy-fills
//! (`take_copy`) the full length it hands out, so reuse is bitwise
//! invisible — `rust/tests/pool_determinism.rs` sentinel-poisons the
//! pool between rounds to prove it.

use std::cell::RefCell;

use crate::model::{LayerKind, ModelSpec};
use crate::tensor::Tensor;

use super::registry::ArtifactMeta;

thread_local! {
    /// Idle f32 buffers of this thread's executor (capacity is retained
    /// across jobs; contents are dead until re-filled by a take).
    static BUF_POOL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Take a buffer of exactly `n` zeros from the pool (or allocate one).
fn take_zeroed(n: usize) -> Vec<f32> {
    let mut v = BUF_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    v.clear();
    v.resize(n, 0.0);
    v
}

/// Take a buffer holding a copy of `src` from the pool (or allocate one).
fn take_copy(src: &[f32]) -> Vec<f32> {
    let mut v = BUF_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    v.clear();
    v.extend_from_slice(src);
    v
}

/// Return a buffer to this thread's pool for reuse.
fn give_back(v: Vec<f32>) {
    BUF_POOL.with(|p| p.borrow_mut().push(v));
}

/// Batch-row block of the kernels: weight rows loaded once serve MR
/// samples. Small enough that MR delta/activation rows stay cache-hot.
const MR: usize = 4;

/// Inner-tile width of [`axpy`]: fixed-size chunks with compile-time
/// bounds let the autovectorizer emit full-width SIMD adds/FMAs.
const NR: usize = 8;

/// `acc[k] += a · xs[k]` — elementwise, so any tiling is bitwise-neutral
/// (each element owns its accumulation chain; nothing is reassociated).
/// The fixed NR-wide exact chunks vectorize; the tail runs scalar.
#[inline]
fn axpy(acc: &mut [f32], a: f32, xs: &[f32]) {
    debug_assert_eq!(acc.len(), xs.len());
    let mut ac = acc.chunks_exact_mut(NR);
    let mut xc = xs.chunks_exact(NR);
    for (at, xt) in (&mut ac).zip(&mut xc) {
        for t in 0..NR {
            at[t] += a * xt[t];
        }
    }
    for (o, &v) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += a * v;
    }
}

/// Strict-order dot product: a single accumulator walked k-ascending.
/// Deliberately *not* lane-split — the reduction order is part of the
/// executor's bitwise contract (see the module docs).
#[inline]
fn dot_ordered(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// Test support: fill every idle pooled buffer with NaN sentinels (in
/// place, lengths kept). Exposed as `runtime::poison_native_scratch` and
/// broadcast to every worker by `FedRun::poison_worker_scratch`; any
/// take that failed to overwrite its full length would surface as NaN
/// losses or parameters.
pub(crate) fn poison_thread_scratch() {
    BUF_POOL.with(|p| {
        for v in p.borrow_mut().iter_mut() {
            v.fill(f32::NAN);
        }
    });
}

/// Stateless native executor (all state lives in the caller's tensors).
pub(crate) struct NativeExec;

impl NativeExec {
    /// Resolve an artifact's model into an FC layer-dimension chain
    /// `[in, h1, …, out]`; errors for conv models.
    fn fc_dims(meta: &ArtifactMeta) -> anyhow::Result<Vec<usize>> {
        let model = meta
            .model
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("artifact {:?} names no model", meta.name))?;
        let spec = ModelSpec::get(model, meta.width)?;
        let mut dims = Vec::with_capacity(spec.layers.len() + 1);
        for (i, layer) in spec.layers.iter().enumerate() {
            anyhow::ensure!(
                matches!(layer.kind, LayerKind::Fc),
                "native executor supports FC models only; {model:?} layer {i} is conv \
                 (build the XLA artifacts for conv models)"
            );
            if i == 0 {
                dims.push(layer.in_dim);
            } else {
                anyhow::ensure!(
                    layer.in_dim == *dims.last().unwrap(),
                    "{model:?} layer {i} input dim mismatch"
                );
            }
            dims.push(layer.out_dim);
        }
        Ok(dims)
    }

    fn check_io(
        meta: &ArtifactMeta,
        dims: &[usize],
        n_params: usize,
        x_len: usize,
        y_len: usize,
    ) -> anyhow::Result<usize> {
        let b = meta.batch.max(1);
        anyhow::ensure!(
            n_params == 2 * (dims.len() - 1),
            "param arity {} for {:?} (want {})",
            n_params,
            meta.name,
            2 * (dims.len() - 1)
        );
        anyhow::ensure!(
            x_len == b * dims[0],
            "x len {} for {:?} (want {} × {})",
            x_len,
            meta.name,
            b,
            dims[0]
        );
        anyhow::ensure!(y_len == b, "y len {} for {:?} (want {})", y_len, meta.name, b);
        Ok(b)
    }

    /// One SGD step; params updated in place; returns the mean batch loss.
    pub fn train_step(
        &self,
        meta: &ArtifactMeta,
        params: &mut [Tensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> anyhow::Result<f32> {
        let dims = Self::fc_dims(meta)?;
        let b = Self::check_io(meta, &dims, params.len(), x.len(), y.len())?;
        let acts = forward(&dims, params, x, b);
        let k = *dims.last().unwrap();
        let (loss_sum, mut delta) = softmax_ce_grad(acts.last().unwrap(), y, b, k)?;

        // Backward + SGD, layer by layer from the top. Each layer's input
        // gradient is computed against its pre-update weights. Blocked
        // over batch rows (MR): within a block, dW runs j-outer so each
        // contiguous dw row is the axpy target for every row of the
        // block — the chain feeding any dw[j,k] is still i ascending
        // (blocks ascending, rows ascending within a block), bitwise
        // what the row-outer scalar loop produced.
        let n_layers = dims.len() - 1;
        for l in (0..n_layers).rev() {
            let (d_in, d_out) = (dims[l], dims[l + 1]);
            let input = &acts[l];
            let mut dw = take_zeroed(d_in * d_out);
            let mut db = take_zeroed(d_out);
            for ib in (0..b).step_by(MR) {
                let ie = (ib + MR).min(b);
                for i in ib..ie {
                    let drow = &delta[i * d_out..(i + 1) * d_out];
                    for (dbv, &dv) in db.iter_mut().zip(drow) {
                        *dbv += dv;
                    }
                }
                for j in 0..d_in {
                    let dwrow = &mut dw[j * d_out..(j + 1) * d_out];
                    for i in ib..ie {
                        let xv = input[i * d_in + j];
                        // Skipped zero activations (sparse post-ReLU
                        // inputs) contribute nothing; the skip is the
                        // sparsity fast path, same as the forward.
                        if xv == 0.0 {
                            continue;
                        }
                        axpy(dwrow, xv, &delta[i * d_out..(i + 1) * d_out]);
                    }
                }
            }
            if l > 0 {
                // dprev = (delta @ Wᵀ) ⊙ relu'(input); relu' from the
                // post-relu activation (0 ⇔ inactive unit). j-outer so a
                // loaded weight row serves the whole row block; each dot
                // keeps its strict k-ascending single-accumulator order.
                let w = params[2 * l].data();
                let mut dprev = take_zeroed(b * d_in);
                for ib in (0..b).step_by(MR) {
                    let ie = (ib + MR).min(b);
                    for j in 0..d_in {
                        let wrow = &w[j * d_out..(j + 1) * d_out];
                        for i in ib..ie {
                            if input[i * d_in + j] <= 0.0 {
                                continue;
                            }
                            dprev[i * d_in + j] =
                                dot_ordered(wrow, &delta[i * d_out..(i + 1) * d_out]);
                        }
                    }
                }
                give_back(std::mem::replace(&mut delta, dprev));
            }
            let wt = params[2 * l].data_mut();
            for (wv, &gv) in wt.iter_mut().zip(&dw) {
                *wv -= lr * gv;
            }
            let bt = params[2 * l + 1].data_mut();
            for (bv, &gv) in bt.iter_mut().zip(&db) {
                *bv -= lr * gv;
            }
            give_back(dw);
            give_back(db);
        }
        give_back(delta);
        for a in acts {
            give_back(a);
        }
        Ok(loss_sum / b as f32)
    }

    /// Fused multi-step: `steps` sequential SGD steps over stacked
    /// batches; returns the mean of the per-step losses.
    pub fn train_scan(
        &self,
        meta: &ArtifactMeta,
        params: &mut [Tensor],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> anyhow::Result<f32> {
        let dims = Self::fc_dims(meta)?;
        let b = meta.batch.max(1);
        let steps = meta.steps.max(1);
        anyhow::ensure!(
            xs.len() == steps * b * dims[0] && ys.len() == steps * b,
            "scan input lengths for {:?}",
            meta.name
        );
        let mut loss_sum = 0.0f32;
        for s in 0..steps {
            let x = &xs[s * b * dims[0]..(s + 1) * b * dims[0]];
            let y = &ys[s * b..(s + 1) * b];
            loss_sum += self.train_step(meta, params, x, y, lr)?;
        }
        Ok(loss_sum / steps as f32)
    }

    /// Forward + per-class eval stats: (nll sum, correct[10], count[10]).
    pub fn eval_batch(
        &self,
        meta: &ArtifactMeta,
        params: &[Tensor],
        x: &[f32],
        y: &[i32],
    ) -> anyhow::Result<(f32, Vec<f32>, Vec<f32>)> {
        let dims = Self::fc_dims(meta)?;
        let b = Self::check_io(meta, &dims, params.len(), x.len(), y.len())?;
        let acts = forward(&dims, params, x, b);
        let k = *dims.last().unwrap();
        let logits = acts.last().unwrap();
        let mut loss_sum = 0.0f32;
        let mut correct = vec![0.0f32; k];
        let mut count = vec![0.0f32; k];
        for i in 0..b {
            let row = &logits[i * k..(i + 1) * k];
            let yi = y[i] as usize;
            anyhow::ensure!(yi < k, "label {} out of range 0..{k}", y[i]);
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let lse: f32 = row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
            loss_sum += lse - row[yi];
            let mut am = 0;
            for j in 1..k {
                if row[j] > row[am] {
                    am = j; // strict > keeps the first max, like jnp.argmax
                }
            }
            count[yi] += 1.0;
            if am == yi {
                correct[yi] += 1.0;
            }
        }
        for a in acts {
            give_back(a);
        }
        Ok((loss_sum, correct, count))
    }
}

/// Per-layer activations: `acts[0] = x`, `acts[l+1]` = output of layer `l`
/// (post-ReLU except the final logits). Buffers come from the thread's
/// pool; the caller returns them with `give_back` when done.
fn forward(dims: &[usize], params: &[Tensor], x: &[f32], b: usize) -> Vec<Vec<f32>> {
    let n_layers = dims.len() - 1;
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
    acts.push(take_copy(x));
    for l in 0..n_layers {
        let (d_in, d_out) = (dims[l], dims[l + 1]);
        let w = params[2 * l].data();
        let bias = params[2 * l + 1].data();
        let mut out = take_zeroed(b * d_out);
        {
            // Blocked matmul: batch rows in MR-row blocks, j-outer within
            // a block so one loaded weight row feeds every row of the
            // block via a contiguous NR-tiled axpy. Each output element's
            // accumulation chain is still bias-init then j ascending —
            // bitwise identical to the row-at-a-time scalar loop.
            let input = &acts[l];
            for ib in (0..b).step_by(MR) {
                let ie = (ib + MR).min(b);
                for i in ib..ie {
                    out[i * d_out..(i + 1) * d_out].copy_from_slice(bias);
                }
                for j in 0..d_in {
                    let wrow = &w[j * d_out..(j + 1) * d_out];
                    for i in ib..ie {
                        let xv = input[i * d_in + j];
                        // Post-ReLU inputs are sparse; skipping exact
                        // zeros is the dominant fast path.
                        if xv == 0.0 {
                            continue;
                        }
                        axpy(&mut out[i * d_out..(i + 1) * d_out], xv, wrow);
                    }
                }
            }
        }
        if l + 1 < n_layers {
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        acts.push(out);
    }
    acts
}

/// Mean softmax cross-entropy over the batch plus dL/dlogits (already
/// scaled by 1/B). Returns the *sum* of per-sample NLLs; callers divide.
fn softmax_ce_grad(
    logits: &[f32],
    y: &[i32],
    b: usize,
    k: usize,
) -> anyhow::Result<(f32, Vec<f32>)> {
    let mut loss_sum = 0.0f32;
    let mut dlogits = take_zeroed(b * k);
    for i in 0..b {
        let row = &logits[i * k..(i + 1) * k];
        let yi = y[i] as usize;
        anyhow::ensure!(yi < k, "label {} out of range 0..{k}", y[i]);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let drow = &mut dlogits[i * k..(i + 1) * k];
        let mut sum = 0.0f32;
        for (d, &v) in drow.iter_mut().zip(row) {
            let e = (v - m).exp();
            *d = e;
            sum += e;
        }
        loss_sum += sum.ln() + m - row[yi];
        let inv = 1.0 / sum;
        for d in drow.iter_mut() {
            *d *= inv;
        }
        drow[yi] -= 1.0;
    }
    let scale = 1.0 / b as f32;
    for d in dlogits.iter_mut() {
        *d *= scale;
    }
    Ok((loss_sum, dlogits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::ArtifactMeta;
    use crate::util::rng::Rng;

    fn mlp_meta(kind: &str, batch: usize) -> ArtifactMeta {
        ArtifactMeta {
            name: format!("mlp_w100_{kind}"),
            file: std::path::PathBuf::from("unused"),
            kind: kind.to_string(),
            op: None,
            model: Some("mlp".to_string()),
            width: 1.0,
            batch,
            steps: 1,
            chunk: 0,
            params: Vec::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
        }
    }

    fn batch(rng: &mut Rng, b: usize) -> (Vec<f32>, Vec<i32>) {
        let x: Vec<f32> = (0..b * 784).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
        (x, y)
    }

    #[test]
    fn train_reduces_loss_on_fixed_batch() {
        // Full-batch descent on one fixed batch must overfit it: with
        // correct gradients the loss falls well below the ln(10) ≈ 2.30
        // chance level; with broken gradients it stalls or diverges.
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(0);
        let mut params = spec.init_params(&mut rng);
        let (x, y) = batch(&mut rng, 16);
        let nx = NativeExec;
        let meta = mlp_meta("train", 16);
        let first = nx.train_step(&meta, &mut params, &x, &y, 0.05).unwrap();
        let mut last = first;
        for _ in 0..80 {
            last = nx.train_step(&meta, &mut params, &x, &y, 0.05).unwrap();
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(
            last < first && last < 2.0,
            "loss did not fall on a fixed batch: {first} -> {last}"
        );
    }

    /// f64 mirror of forward + mean CE loss, used as the finite-difference
    /// oracle (f32 central differences drown in rounding noise).
    fn loss_f64(dims: &[usize], params: &[Vec<f64>], x: &[f32], y: &[i32], b: usize) -> f64 {
        let n_layers = dims.len() - 1;
        let mut act: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        for l in 0..n_layers {
            let (d_in, d_out) = (dims[l], dims[l + 1]);
            let w = &params[2 * l];
            let bias = &params[2 * l + 1];
            let mut out = vec![0.0f64; b * d_out];
            for i in 0..b {
                let orow = &mut out[i * d_out..(i + 1) * d_out];
                orow.copy_from_slice(bias);
                for j in 0..d_in {
                    let xv = act[i * d_in + j];
                    for (o, &wv) in orow.iter_mut().zip(&w[j * d_out..(j + 1) * d_out]) {
                        *o += xv * wv;
                    }
                }
            }
            if l + 1 < n_layers {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            act = out;
        }
        let k = dims[n_layers];
        let mut loss = 0.0f64;
        for i in 0..b {
            let row = &act[i * k..(i + 1) * k];
            let m = row.iter().fold(f64::NEG_INFINITY, |a, &v| a.max(v));
            let lse = row.iter().map(|&v| (v - m).exp()).sum::<f64>().ln() + m;
            loss += lse - row[y[i] as usize];
        }
        loss / b as f64
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // Spot-check dL/dθ for a few coordinates of every tensor against
        // an f64 central difference.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(1);
        let params0 = spec.init_params(&mut rng);
        let b = 4;
        let d0 = spec.layers[0].in_dim;
        let x: Vec<f32> = (0..b * d0).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
        let meta = mlp_meta("train", b);
        let dims = NativeExec::fc_dims(&meta).unwrap();

        // Analytic gradient via one unit-lr step: g = p_before - p_after.
        let mut stepped = params0.clone();
        NativeExec.train_step(&meta, &mut stepped, &x, &y, 1.0).unwrap();

        let p64: Vec<Vec<f64>> = params0
            .iter()
            .map(|t| t.data().iter().map(|&v| v as f64).collect())
            .collect();
        let eps = 1e-5f64;
        for ti in 0..params0.len() {
            for probe in 0..3 {
                let idx = (probe * 37) % params0[ti].numel();
                let analytic =
                    (params0[ti].data()[idx] - stepped[ti].data()[idx]) as f64;
                let mut plus = p64.clone();
                plus[ti][idx] += eps;
                let mut minus = p64.clone();
                minus[ti][idx] -= eps;
                let numeric = (loss_f64(&dims, &plus, &x, &y, b)
                    - loss_f64(&dims, &minus, &x, &y, b))
                    / (2.0 * eps);
                assert!(
                    (analytic - numeric).abs()
                        <= 1e-2 * analytic.abs().max(numeric.abs()) + 1e-4,
                    "tensor {ti} idx {idx}: analytic {analytic} vs numeric {numeric}"
                );
            }
        }
    }

    #[test]
    fn eval_counts_are_consistent() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(2);
        let params = spec.init_params(&mut rng);
        let (x, y) = batch(&mut rng, 32);
        let meta = mlp_meta("eval", 32);
        let (loss, correct, count) = NativeExec.eval_batch(&meta, &params, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(count.iter().sum::<f32>(), 32.0);
        for (c, n) in correct.iter().zip(&count) {
            assert!(c <= n, "correct {c} > count {n}");
        }
    }

    #[test]
    fn conv_models_are_rejected() {
        let meta = ArtifactMeta { model: Some("cnn1".to_string()), ..mlp_meta("train", 4) };
        let spec = ModelSpec::get("cnn1", 1.0).unwrap();
        let mut rng = Rng::new(3);
        let mut params = spec.init_params(&mut rng);
        let err = NativeExec
            .train_step(&meta, &mut params, &[0.0; 4 * 784], &[0i32; 4], 0.1)
            .unwrap_err();
        assert!(err.to_string().contains("FC models only"), "{err}");
    }

    #[test]
    fn pooled_buffers_and_poisoning_do_not_change_bits() {
        // The buffer pool's correctness contract: takes fully overwrite
        // what they hand out, so a run after sentinel-poisoning the idle
        // pool is bit-identical to the first run (which populated it).
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(5);
        let base = spec.init_params(&mut rng);
        let (x, y) = batch(&mut rng, 16);
        let train = mlp_meta("train", 16);
        let eval = mlp_meta("eval", 16);
        let run = || {
            let mut p = base.clone();
            let mut loss_bits = Vec::new();
            for _ in 0..3 {
                let l = NativeExec.train_step(&train, &mut p, &x, &y, 0.05).unwrap();
                loss_bits.push(l.to_bits());
            }
            let (el, ec, en) = NativeExec.eval_batch(&eval, &p, &x, &y).unwrap();
            (loss_bits, el.to_bits(), ec, en, p)
        };
        let a = run();
        poison_thread_scratch();
        let b = run();
        assert_eq!(a.0, b.0, "train losses drifted after pool poisoning");
        assert_eq!(a.1, b.1, "eval loss drifted after pool poisoning");
        assert_eq!(a.2, b.2);
        assert_eq!(a.3, b.3);
        for (i, (ta, tb)) in a.4.iter().zip(&b.4).enumerate() {
            assert_eq!(ta.data(), tb.data(), "param tensor {i} drifted");
        }
    }

    #[test]
    fn identical_inputs_produce_identical_bits() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(4);
        let base = spec.init_params(&mut rng);
        let (x, y) = batch(&mut rng, 16);
        let meta = mlp_meta("train", 16);
        let run = || {
            let mut p = base.clone();
            let loss = NativeExec.train_step(&meta, &mut p, &x, &y, 0.05).unwrap();
            (loss.to_bits(), p)
        };
        let (l1, p1) = run();
        let (l2, p2) = run();
        assert_eq!(l1, l2);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.data(), b.data());
        }
    }
}
