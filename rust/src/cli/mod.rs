//! CLI argument-parsing substrate (clap is unavailable offline).
//!
//! Grammar: `feddd <command> [positional...] [--key value | --flag]`.
//! `--key=value` is also accepted. Unknown keys are the caller's problem
//! (most of them are forwarded to `ExpConfig::set`).

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                args.command = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    anyhow::bail!("bare `--` not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str) -> anyhow::Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> anyhow::Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Apply all `--key value` options to an ExpConfig, starting from a
    /// `--preset` if given. Keys that the config doesn't know are left to
    /// the caller via the returned leftover list.
    pub fn to_config(&self) -> anyhow::Result<(crate::config::ExpConfig, Vec<String>)> {
        let mut cfg = match self.get("preset") {
            Some(p) => crate::config::ExpConfig::preset(p)?,
            None => crate::config::ExpConfig::smoke(),
        };
        if let Some(path) = self.get("config") {
            cfg = crate::config::ExpConfig::load(std::path::Path::new(path))?;
        }
        let mut leftover = Vec::new();
        for (k, v) in &self.options {
            if k == "preset" || k == "config" || k == "out" {
                continue;
            }
            if cfg.set(k, v).is_err() {
                leftover.push(k.clone());
            }
        }
        Ok((cfg, leftover))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("figure fig7 --rounds 20");
        assert_eq!(a.command, "figure");
        assert_eq!(a.positionals, vec!["fig7"]);
        assert_eq!(a.get("rounds"), Some("20"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("train --lr=0.1 --verbose --n_clients 5");
        assert_eq!(a.get("lr"), Some("0.1"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_usize("n_clients").unwrap(), Some(5));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("train --quick");
        assert!(a.has_flag("quick"));
    }

    #[test]
    fn to_config_applies_overrides() {
        let a = parse("train --preset smoke --rounds 3 --scheme fedavg --notakey 1");
        let (cfg, leftover) = a.to_config().unwrap();
        assert_eq!(cfg.rounds, 3);
        assert_eq!(cfg.scheme, "fedavg");
        assert_eq!(leftover, vec!["notakey".to_string()]);
    }

    #[test]
    fn to_config_knows_round_mode_knobs() {
        let a = parse(
            "train --round_mode semi_async --quorum 0.7 --deadline_s 45 --staleness_beta 1.5",
        );
        let (cfg, leftover) = a.to_config().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(cfg.round_mode, "semi_async");
        assert_eq!(cfg.quorum, 0.7);
        assert_eq!(cfg.deadline_s, 45.0);
        assert_eq!(cfg.staleness_beta, 1.5);
        cfg.validate().unwrap();
    }

    #[test]
    fn to_config_knows_codec_knob() {
        let a = parse("train --codec coo");
        let (cfg, leftover) = a.to_config().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(cfg.codec, "coo");
        cfg.validate().unwrap();
    }

    #[test]
    fn to_config_knows_virtualization_knobs() {
        let a = parse("train --data_mode eager --snapshot_ring_cap 4");
        let (cfg, leftover) = a.to_config().unwrap();
        assert!(leftover.is_empty());
        assert_eq!(cfg.data_mode, "eager");
        assert_eq!(cfg.snapshot_ring_cap, 4);
        cfg.validate().unwrap();
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --rounds abc");
        assert!(a.get_usize("rounds").is_err());
    }
}
