//! Sparse-upload wire codec: the bytes a FedDD client actually puts on
//! the uplink (DESIGN.md §8).
//!
//! `ChannelMask` says *which* units a client uploads; this module decides
//! *how* they are laid out on the wire and what that really costs. Three
//! per-layer layouts:
//!
//! * **dense**  — every unit's value group in unit order, no index
//!   overhead (only representable when the layer is fully kept);
//! * **bitmap** — `ceil(out_dim/8)` bytes of per-unit presence bits, then
//!   the kept units' value groups in ascending unit order;
//! * **COO**    — one `u32` unit index per kept unit, then the value
//!   groups (wins when fewer than ~`out_dim/32` units survive).
//!
//! [`encode_upload`] gathers the masked values (a unit's value group is
//! its incoming weights followed by its bias) and auto-picks the smallest
//! layout per layer; [`WireUpload::wire_len`] is the realized byte count
//! the simnet charges `t_up` for — a measurement, replacing the
//! `upload_bytes` estimate. [`WireUpload::to_bytes`] /
//! [`WireUpload::from_bytes`] give the self-describing serialized form:
//! a magic/version header, per-layer geometry records and a trailing
//! FNV-1a 64 checksum over everything before it.
//!
//! Orthogonally to the index layout, each layer carries a **value
//! plane** ([`ValuePlane`]): its kept values travel as f32 (4 B/value,
//! the default), IEEE half floats (2 B) or scaled int8 (1 B + one f32
//! scale in the layer header). Quantization is applied at *encode*
//! time — `values` always holds the already-dequantized f32s the
//! aggregator folds — so the f32 plane is bitwise-identical to the
//! pre-plane codec and lossy planes round-trip the wire byte for byte
//! ([`encode_upload_planes`], `PlaneMode::Auto` picks the smallest
//! plane whose realized error stays under a relative bound).
//!
//! The aggregation side never re-densifies: `Aggregator::absorb_wire`
//! folds bitmap/COO payloads straight into the Eq. 4 num/den partials
//! (see `aggregation`), bitwise-identical to the dense mask path.

use std::sync::Mutex;

use crate::model::{Layer, LayerKind, ModelSpec};
use crate::selection::ChannelMask;
use crate::tensor::Tensor;

/// Recycling pool for decoded upload buffers: the `units`/`values` pairs
/// a [`WireUpload`] owns. An upload is encoded on a pool worker, folded
/// once by `Aggregator::absorb_wire` on the coordinator thread, and then
/// dropped — at fleet scale that is two short-lived heap allocations per
/// client per round. The engine returns folded uploads here
/// ([`recycle_wire_upload`]) and [`encode_upload_with`] draws from the
/// pool before allocating fresh.
///
/// Determinism-safe by construction: a drawn buffer is cleared and then
/// fully rewritten (`extend` over exactly the kept units), every byte
/// accounting is length-based, and the wire form never sees capacity —
/// so pool hits and misses produce identical uploads (asserted by
/// `recycled_buffers_encode_identically` below and the cross-worker
/// fleet battery).
static WIRE_SCRATCH: Mutex<Vec<(Vec<u32>, Vec<f32>)>> = Mutex::new(Vec::new());

/// Freelist size cap: enough for every layer of a full micro-batch of
/// in-flight uploads, small enough that the pool itself stays O(workers),
/// never O(fleet).
const WIRE_SCRATCH_CAP: usize = 1024;

fn take_wire_buffers() -> (Vec<u32>, Vec<f32>) {
    let mut pool = WIRE_SCRATCH.lock().unwrap_or_else(|e| e.into_inner());
    pool.pop().unwrap_or_default()
}

/// Return a folded upload's owned buffers to the encode freelist. Call
/// after `absorb_wire` has consumed the upload; the buffers are cleared
/// here and fully overwritten by their next encode.
pub fn recycle_wire_upload(up: WireUpload) {
    let mut pool = WIRE_SCRATCH.lock().unwrap_or_else(|e| e.into_inner());
    for mut lw in up.layers {
        if pool.len() >= WIRE_SCRATCH_CAP {
            break;
        }
        lw.units.clear();
        lw.values.clear();
        pool.push((lw.units, lw.values));
    }
}

/// Buffer pairs currently parked in the encode freelist (observability).
pub fn wire_scratch_len() -> usize {
    WIRE_SCRATCH.lock().unwrap_or_else(|e| e.into_inner()).len()
}

/// Serialized-form magic bytes ("FedDD Wire Upload").
pub const WIRE_MAGIC: [u8; 4] = *b"FDWU";
/// Serialized-form version (2 since the value-plane record was added).
pub const WIRE_VERSION: u16 = 2;
/// Global header: magic + version (u16) + layer count (u16).
pub const GLOBAL_HEADER_BYTES: usize = 8;
/// Per-layer header: encoding tag (u8) + plane tag (u8) +
/// in_dim/out_dim/n_sel/group (u32) + plane scale (f32; 0.0 unless i8).
pub const LAYER_HEADER_BYTES: usize = 22;
/// Trailing FNV-1a 64 checksum.
pub const CHECKSUM_BYTES: usize = 8;

/// Wire layout of one layer's kept units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    Dense,
    Bitmap,
    Coo,
}

impl Encoding {
    fn tag(self) -> u8 {
        match self {
            Encoding::Dense => 0,
            Encoding::Bitmap => 1,
            Encoding::Coo => 2,
        }
    }

    fn from_tag(tag: u8) -> anyhow::Result<Encoding> {
        Ok(match tag {
            0 => Encoding::Dense,
            1 => Encoding::Bitmap,
            2 => Encoding::Coo,
            t => anyhow::bail!("unknown encoding tag {t}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Encoding::Dense => "dense",
            Encoding::Bitmap => "bitmap",
            Encoding::Coo => "coo",
        }
    }
}

/// Per-layout layer counts — the "encoding mix" column of round records
/// and bench JSON.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EncodingMix {
    pub dense: usize,
    pub bitmap: usize,
    pub coo: usize,
}

impl EncodingMix {
    pub fn count(&mut self, enc: Encoding) {
        match enc {
            Encoding::Dense => self.dense += 1,
            Encoding::Bitmap => self.bitmap += 1,
            Encoding::Coo => self.coo += 1,
        }
    }

    pub fn merge(&mut self, other: EncodingMix) {
        self.dense += other.dense;
        self.bitmap += other.bitmap;
        self.coo += other.coo;
    }

    pub fn total(&self) -> usize {
        self.dense + self.bitmap + self.coo
    }
}

/// How one layer's kept values travel on the wire, orthogonal to the
/// index layout. `values` in the decoded [`LayerWire`] always holds the
/// **dequantized f32s** (quantize→dequantize happens at encode time), so
/// aggregation never sees a plane — only the serialized width and the
/// layer header differ.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ValuePlane {
    /// Full-precision f32 values, 4 B each (the default; bitwise
    /// identical to the pre-plane wire, modulo the version bump).
    F32,
    /// IEEE binary16 values, 2 B each. Encode rounds to nearest-even and
    /// saturates overflow to ±65504 (never injects infinities); the
    /// stored f32s are exactly f16-representable, so re-encoding is
    /// idempotent.
    F16,
    /// Scaled int8: `q = round(v / scale)` clamped to ±127, 1 B each;
    /// `scale = max|v| / 127` travels in the layer header. Stored f32s
    /// are `q · scale`, so re-quantizing with the carried scale
    /// reproduces every `q` exactly.
    I8 { scale: f32 },
}

impl ValuePlane {
    /// Serialized bytes per value under this plane.
    pub fn width(self) -> usize {
        match self {
            ValuePlane::F32 => 4,
            ValuePlane::F16 => 2,
            ValuePlane::I8 { .. } => 1,
        }
    }

    fn tag(self) -> u8 {
        match self {
            ValuePlane::F32 => 0,
            ValuePlane::F16 => 1,
            ValuePlane::I8 { .. } => 2,
        }
    }

    fn scale(self) -> f32 {
        match self {
            ValuePlane::I8 { scale } => scale,
            _ => 0.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ValuePlane::F32 => "f32",
            ValuePlane::F16 => "f16",
            ValuePlane::I8 { .. } => "i8",
        }
    }
}

/// Per-plane layer counts and serialized value bytes — the plane-mix
/// column of round records and the bench JSON (`wire_f32/f16/i8_bytes`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlaneMix {
    pub f32_layers: usize,
    pub f16_layers: usize,
    pub i8_layers: usize,
    /// Serialized value bytes per plane (excluding indices and headers).
    pub f32_bytes: usize,
    pub f16_bytes: usize,
    pub i8_bytes: usize,
}

impl PlaneMix {
    pub fn count(&mut self, plane: ValuePlane, n_values: usize) {
        match plane {
            ValuePlane::F32 => {
                self.f32_layers += 1;
                self.f32_bytes += n_values * 4;
            }
            ValuePlane::F16 => {
                self.f16_layers += 1;
                self.f16_bytes += n_values * 2;
            }
            ValuePlane::I8 { .. } => {
                self.i8_layers += 1;
                self.i8_bytes += n_values;
            }
        }
    }

    pub fn merge(&mut self, other: PlaneMix) {
        self.f32_layers += other.f32_layers;
        self.f16_layers += other.f16_layers;
        self.i8_layers += other.i8_layers;
        self.f32_bytes += other.f32_bytes;
        self.f16_bytes += other.f16_bytes;
        self.i8_bytes += other.i8_bytes;
    }

    pub fn total_layers(&self) -> usize {
        self.f32_layers + self.f16_layers + self.i8_layers
    }
}

/// Value-plane policy (`value_plane` config knob): force one plane on
/// every layer, or `Auto` — the smallest plane whose *realized* max
/// quantization error stays within `plane_error · max|v|` per layer
/// (tried in width order i8 → f16 → f32; f32 always qualifies).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaneMode {
    F32,
    F16,
    I8,
    Auto,
}

impl PlaneMode {
    pub fn by_name(name: &str) -> anyhow::Result<PlaneMode> {
        Ok(match name {
            "f32" => PlaneMode::F32,
            "f16" => PlaneMode::F16,
            "i8" => PlaneMode::I8,
            "auto" => PlaneMode::Auto,
            _ => anyhow::bail!("unknown value plane {name:?} (f32|f16|i8|auto)"),
        })
    }

    /// Widest bytes-per-value this mode can realize — what the
    /// `upload_bound` estimate must budget for (`Auto` may fall back to
    /// f32 on any layer).
    pub fn bound_width(self) -> usize {
        match self {
            PlaneMode::F32 | PlaneMode::Auto => 4,
            PlaneMode::F16 => 2,
            PlaneMode::I8 => 1,
        }
    }
}

/// f32 → IEEE binary16 bits, round-to-nearest-even. Overflow (and ±inf)
/// saturates to the max finite half ±65504 so a forced f16 plane never
/// injects infinities into the model; NaN becomes the canonical quiet
/// NaN. No `half` crate — the conversion must be dependency-free and
/// bit-stable across hosts.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        return if mant != 0 { 0x7e00 } else { sign | 0x7bff };
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7bff;
    }
    if e >= -14 {
        // Normal half: keep 10 mantissa bits, round to nearest even. The
        // round-up may carry into the exponent — correct for RN — but a
        // carry past the largest finite half saturates instead.
        let mant16 = mant >> 13;
        let rest = mant & 0x1fff;
        let mut h = sign as u32 | (((e + 15) as u32) << 10) | mant16;
        if rest > 0x1000 || (rest == 0x1000 && mant16 & 1 == 1) {
            h += 1;
        }
        if h & 0x7fff >= 0x7c00 {
            h = sign as u32 | 0x7bff;
        }
        return h as u16;
    }
    if e >= -24 {
        // Subnormal half.
        let m = mant | 0x0080_0000;
        let shift = (13 - 14 - e) as u32; // 13 + (-14 - e), in 14..=23
        let mant16 = m >> shift;
        let rest = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign as u32 | mant16;
        if rest > half || (rest == half && mant16 & 1 == 1) {
            h += 1;
        }
        return h as u16;
    }
    sign
}

/// IEEE binary16 bits → f32 (exact; every half is f32-representable).
pub fn f16_bits_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = ((bits >> 10) & 0x1f) as u32;
    let mant = (bits & 0x3ff) as u32;
    let out = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal half: normalize into an f32 exponent.
            let mut e = 113u32; // f32 biased exponent of 2^-14
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(out)
}

/// Encoder policy: `Auto` picks the smallest layout per layer (always
/// dense for fully-kept layers); `Bitmap`/`Coo` force that index layout
/// on every layer (benches/ablations — dense cannot represent a partial
/// layer, so it is not a forcible mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecMode {
    Auto,
    Bitmap,
    Coo,
}

impl CodecMode {
    pub fn by_name(name: &str) -> anyhow::Result<CodecMode> {
        Ok(match name {
            "auto" => CodecMode::Auto,
            "bitmap" => CodecMode::Bitmap,
            "coo" => CodecMode::Coo,
            _ => anyhow::bail!("unknown codec mode {name:?} (auto|bitmap|coo)"),
        })
    }
}

/// Weights owned by one unit of `layer` (excluding its bias): the conv
/// kernel block `in·k·k`, or the FC input column `in`.
pub fn unit_group(layer: &Layer) -> usize {
    match layer.kind {
        LayerKind::Conv { kernel, .. } => layer.in_dim * kernel * kernel,
        LayerKind::Fc => layer.in_dim,
    }
}

/// Gather the value groups of the listed units of one layer into the
/// canonical wire layout: per unit (ascending), its [`unit_group`]
/// incoming weights then its bias. Shared by the upload encoder and the
/// client-state residuals (`coordinator::state`), so both sides agree on
/// the layout byte for byte.
pub fn gather_unit_values(layer: &Layer, w: &[f32], b: &[f32], units: &[u32]) -> Vec<f32> {
    let mut values = Vec::with_capacity(units.len() * (unit_group(layer) + 1));
    gather_unit_values_into(layer, w, b, units, &mut values);
    values
}

/// Append-into form of [`gather_unit_values`]: writes the value groups
/// onto the end of `values` (callers clear first when reusing a recycled
/// buffer). The wire layout is identical to the allocating form.
pub fn gather_unit_values_into(
    layer: &Layer,
    w: &[f32],
    b: &[f32],
    units: &[u32],
    values: &mut Vec<f32>,
) {
    let group = unit_group(layer);
    values.reserve(units.len() * (group + 1));
    match layer.kind {
        LayerKind::Conv { .. } => {
            for &k in units {
                let k = k as usize;
                values.extend_from_slice(&w[k * group..(k + 1) * group]);
                values.push(b[k]);
            }
        }
        LayerKind::Fc => {
            let n_out = layer.out_dim;
            for &k in units {
                let k = k as usize;
                for j in 0..layer.in_dim {
                    values.push(w[j * n_out + k]);
                }
                values.push(b[k]);
            }
        }
    }
}

/// Scatter value groups laid out by [`gather_unit_values`] back into
/// dense layer tensors: the exact inverse for the listed units; every
/// other position is left untouched.
pub fn scatter_unit_values(
    layer: &Layer,
    w: &mut [f32],
    b: &mut [f32],
    units: &[u32],
    values: &[f32],
) {
    let group = unit_group(layer);
    let chunk = group + 1;
    debug_assert_eq!(values.len(), units.len() * chunk, "value/unit arity");
    match layer.kind {
        LayerKind::Conv { .. } => {
            for (ui, &k) in units.iter().enumerate() {
                let k = k as usize;
                let vals = &values[ui * chunk..(ui + 1) * chunk];
                w[k * group..(k + 1) * group].copy_from_slice(&vals[..group]);
                b[k] = vals[group];
            }
        }
        LayerKind::Fc => {
            let out = layer.out_dim;
            for (ui, &k) in units.iter().enumerate() {
                let k = k as usize;
                let vals = &values[ui * chunk..(ui + 1) * chunk];
                for j in 0..layer.in_dim {
                    w[j * out + k] = vals[j];
                }
                b[k] = vals[group];
            }
        }
    }
}

/// Index overhead (bytes) of the cheaper index layout for `n_sel` of
/// `out_dim` units: bitmap vs COO.
pub fn index_overhead(out_dim: usize, n_sel: usize) -> usize {
    out_dim.div_ceil(8).min(4 * n_sel)
}

/// Upper bound on `encode_upload(mask, ..).wire_len()`: headers + masked
/// values + the cheaper index overhead per layer, *whether or not* the
/// layer is fully kept (a fully-kept layer encodes dense, with zero index
/// overhead, so the bound is not tight there). `ChannelMask::upload_bytes`
/// delegates here; `encode_upload` debug-asserts the bound. f32 values
/// assumed — see [`upload_bound_with`] for other value planes.
pub fn upload_bound(mask: &ChannelMask, spec: &ModelSpec) -> usize {
    upload_bound_with(mask, spec, 4)
}

/// [`upload_bound`] with an explicit serialized width per value
/// (`PlaneMode::bound_width()`): fp16 halves, int8 quarters the value
/// term; headers and index overhead are plane-independent.
pub fn upload_bound_with(mask: &ChannelMask, spec: &ModelSpec, bytes_per_value: usize) -> usize {
    let mut total = GLOBAL_HEADER_BYTES + CHECKSUM_BYTES;
    for (layer, sel) in spec.layers.iter().zip(&mask.per_layer) {
        let n_sel = sel.iter().filter(|&&b| b).count();
        total += LAYER_HEADER_BYTES
            + n_sel * (unit_group(layer) + 1) * bytes_per_value
            + index_overhead(layer.out_dim, n_sel);
    }
    total
}

/// One layer of a [`WireUpload`] in structured (decoded) form. The
/// `encoding` decides the serialized layout and the byte accounting;
/// `units`/`values` are the layout-independent content: ascending kept
/// unit ids and, per unit, its `group` incoming weights followed by its
/// bias.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerWire {
    pub encoding: Encoding,
    /// How the values serialize ([`ValuePlane`]). `values` always holds
    /// the already-dequantized f32s regardless of the plane.
    pub plane: ValuePlane,
    /// Client-side layer input dimension (conv in-channels / FC inputs).
    pub in_dim: usize,
    /// Client-side unit count of the layer.
    pub out_dim: usize,
    /// Weights per unit excluding the bias ([`unit_group`]).
    pub group: usize,
    /// Kept unit ids, strictly ascending.
    pub units: Vec<u32>,
    /// `units.len() · (group + 1)` values; bias last within each chunk.
    pub values: Vec<f32>,
}

impl LayerWire {
    pub fn n_sel(&self) -> usize {
        self.units.len()
    }

    /// Serialized body bytes of this layer under its encoding and plane.
    pub fn body_bytes(&self) -> usize {
        let vals = self.values.len() * self.plane.width();
        match self.encoding {
            Encoding::Dense => vals,
            Encoding::Bitmap => self.out_dim.div_ceil(8) + vals,
            Encoding::Coo => self.units.len() * 4 + vals,
        }
    }
}

/// A client's encoded upload: what actually travels on the uplink.
#[derive(Clone, Debug, PartialEq)]
pub struct WireUpload {
    pub layers: Vec<LayerWire>,
}

impl WireUpload {
    /// Realized wire bytes (headers + index overhead + values +
    /// checksum) — exactly `to_bytes().len()`. This is what the simnet
    /// charges the uplink for.
    pub fn wire_len(&self) -> usize {
        let body: usize = self.layers.iter().map(|l| LAYER_HEADER_BYTES + l.body_bytes()).sum();
        GLOBAL_HEADER_BYTES + CHECKSUM_BYTES + body
    }

    /// Bytes of the masked values alone as serialized (no indices, no
    /// headers) — the budget-accounting payload. Matches
    /// `ChannelMask::payload_bytes` on the f32 plane; lossy planes
    /// shrink it by their width ratio.
    pub fn payload_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.values.len() * l.plane.width())
            .sum()
    }

    /// Heap bytes of the *decoded* upload held in memory (unit ids +
    /// values) — what a server buffering this upload actually stores,
    /// as opposed to the serialized [`WireUpload::wire_len`], whose
    /// bitmap layout can index many units in few wire bytes. The
    /// semi-async pending-state accounting charges this.
    pub fn mem_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.units.len() * 4 + l.values.len() * 4)
            .sum()
    }

    /// Per-layout layer counts of this upload.
    pub fn mix(&self) -> EncodingMix {
        let mut mix = EncodingMix::default();
        for l in &self.layers {
            mix.count(l.encoding);
        }
        mix
    }

    /// Per-plane layer counts and serialized value bytes of this upload.
    pub fn plane_mix(&self) -> PlaneMix {
        let mut mix = PlaneMix::default();
        for l in &self.layers {
            mix.count(l.plane, l.values.len());
        }
        mix
    }

    /// Serialize to the self-describing wire form (DESIGN.md §8).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        out.extend_from_slice(&WIRE_MAGIC);
        out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.layers.len() as u16).to_le_bytes());
        for l in &self.layers {
            out.push(l.encoding.tag());
            out.push(l.plane.tag());
            out.extend_from_slice(&(l.in_dim as u32).to_le_bytes());
            out.extend_from_slice(&(l.out_dim as u32).to_le_bytes());
            out.extend_from_slice(&(l.units.len() as u32).to_le_bytes());
            out.extend_from_slice(&(l.group as u32).to_le_bytes());
            out.extend_from_slice(&l.plane.scale().to_le_bytes());
        }
        for l in &self.layers {
            match l.encoding {
                Encoding::Dense => {}
                Encoding::Bitmap => {
                    let mut bits = vec![0u8; l.out_dim.div_ceil(8)];
                    for &k in &l.units {
                        bits[k as usize / 8] |= 1 << (k as usize % 8);
                    }
                    out.extend_from_slice(&bits);
                }
                Encoding::Coo => {
                    for &k in &l.units {
                        out.extend_from_slice(&k.to_le_bytes());
                    }
                }
            }
            // `values` holds already-dequantized f32s: re-quantizing with
            // the stored plane parameters is exact (f16 values are
            // f16-representable; i8 values are q·scale, and
            // round(q·scale/scale) == q at f32 precision), so
            // encode→decode→encode is byte-identical.
            match l.plane {
                ValuePlane::F32 => {
                    for &v in &l.values {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                ValuePlane::F16 => {
                    for &v in &l.values {
                        out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
                    }
                }
                ValuePlane::I8 { scale } => {
                    for &v in &l.values {
                        let q = (v / scale).round().clamp(-127.0, 127.0) as i8;
                        out.push(q as u8);
                    }
                }
            }
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        debug_assert_eq!(out.len(), self.wire_len());
        out
    }

    /// Parse and validate the wire form: magic, version, geometry sanity,
    /// strictly-ascending unit ids, and the trailing checksum (any bit
    /// flip anywhere in the message is rejected).
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<WireUpload> {
        anyhow::ensure!(
            bytes.len() >= GLOBAL_HEADER_BYTES + CHECKSUM_BYTES,
            "wire message too short ({} bytes)",
            bytes.len()
        );
        let body_end = bytes.len() - CHECKSUM_BYTES;
        let want = u64::from_le_bytes(bytes[body_end..].try_into().unwrap());
        let got = fnv1a64(&bytes[..body_end]);
        anyhow::ensure!(got == want, "wire checksum mismatch ({got:#x} != {want:#x})");
        anyhow::ensure!(bytes[..4] == WIRE_MAGIC, "bad wire magic");
        let mut off = 4;
        let version = read_u16(bytes, &mut off)?;
        anyhow::ensure!(version == WIRE_VERSION, "unsupported wire version {version}");
        let n_layers = read_u16(bytes, &mut off)? as usize;
        let mut heads = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            anyhow::ensure!(off + 1 < body_end, "layer {l}: truncated header");
            let enc = Encoding::from_tag(bytes[off])?;
            let plane_tag = bytes[off + 1];
            off += 2;
            let in_dim = read_u32(bytes, &mut off)? as usize;
            let out_dim = read_u32(bytes, &mut off)? as usize;
            let n_sel = read_u32(bytes, &mut off)? as usize;
            let group = read_u32(bytes, &mut off)? as usize;
            anyhow::ensure!(off + 4 <= body_end, "layer {l}: truncated header");
            let scale = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
            // The scale field is canonical: exactly +0.0 unless the
            // plane is i8 (so re-encoding a decoded upload reproduces
            // the original bytes), finite and positive when it is.
            let plane = match plane_tag {
                0 | 1 => {
                    anyhow::ensure!(
                        scale.to_bits() == 0,
                        "layer {l}: nonzero scale on a non-i8 plane"
                    );
                    if plane_tag == 0 { ValuePlane::F32 } else { ValuePlane::F16 }
                }
                2 => {
                    anyhow::ensure!(
                        scale.is_finite() && scale > 0.0,
                        "layer {l}: bad i8 scale {scale}"
                    );
                    ValuePlane::I8 { scale }
                }
                t => anyhow::bail!("layer {l}: unknown value-plane tag {t}"),
            };
            anyhow::ensure!(out_dim >= 1, "layer {l}: zero out_dim");
            anyhow::ensure!(in_dim >= 1, "layer {l}: zero in_dim");
            anyhow::ensure!(n_sel <= out_dim, "layer {l}: n_sel {n_sel} > out_dim {out_dim}");
            anyhow::ensure!(group >= in_dim, "layer {l}: group {group} < in_dim {in_dim}");
            anyhow::ensure!(
                enc != Encoding::Dense || n_sel == out_dim,
                "layer {l}: dense encoding with partial selection"
            );
            heads.push((enc, plane, in_dim, out_dim, n_sel, group));
        }
        // Bound every allocation by the actual message size before
        // trusting any header geometry: the declared bodies must tile the
        // body region exactly. (The checksum is not cryptographic, so a
        // crafted header could otherwise demand multi-GB unit/value
        // buffers from a tiny message.)
        let mut expected: usize = 0;
        for (l, &(enc, plane, _, out_dim, n_sel, group)) in heads.iter().enumerate() {
            let val_bytes = n_sel
                .checked_mul(group + 1)
                .and_then(|n| n.checked_mul(plane.width()))
                .ok_or_else(|| anyhow::anyhow!("layer {l}: value byte count overflows"))?;
            let idx_bytes = match enc {
                Encoding::Dense => 0,
                Encoding::Bitmap => out_dim.div_ceil(8),
                Encoding::Coo => n_sel * 4,
            };
            expected = expected
                .checked_add(val_bytes)
                .and_then(|e| e.checked_add(idx_bytes))
                .ok_or_else(|| anyhow::anyhow!("layer {l}: body size overflows"))?;
        }
        anyhow::ensure!(
            off <= body_end && expected == body_end - off,
            "declared bodies ({expected} bytes) do not tile the message body"
        );
        let mut layers = Vec::with_capacity(n_layers);
        for (l, (enc, plane, in_dim, out_dim, n_sel, group)) in heads.into_iter().enumerate() {
            let units: Vec<u32> = match enc {
                Encoding::Dense => (0..out_dim as u32).collect(),
                Encoding::Bitmap => {
                    let nb = out_dim.div_ceil(8);
                    anyhow::ensure!(off + nb <= body_end, "layer {l}: truncated bitmap");
                    let bits = &bytes[off..off + nb];
                    off += nb;
                    let mut units = Vec::with_capacity(n_sel);
                    for k in 0..out_dim {
                        if bits[k / 8] & (1 << (k % 8)) != 0 {
                            units.push(k as u32);
                        }
                    }
                    for (byte, &b) in bits.iter().enumerate() {
                        for bit in 0..8 {
                            let k = byte * 8 + bit;
                            anyhow::ensure!(
                                k < out_dim || b & (1 << bit) == 0,
                                "layer {l}: bitmap bit {k} beyond out_dim {out_dim}"
                            );
                        }
                    }
                    units
                }
                Encoding::Coo => {
                    let mut units = Vec::with_capacity(n_sel);
                    for _ in 0..n_sel {
                        units.push(read_u32(bytes, &mut off)?);
                    }
                    units
                }
            };
            anyhow::ensure!(
                units.len() == n_sel,
                "layer {l}: {} indexed units, header says {n_sel}",
                units.len()
            );
            for w in units.windows(2) {
                anyhow::ensure!(w[0] < w[1], "layer {l}: unit ids not strictly ascending");
            }
            if let Some(&last) = units.last() {
                anyhow::ensure!(
                    (last as usize) < out_dim,
                    "layer {l}: unit {last} >= out_dim {out_dim}"
                );
            }
            let n_vals = n_sel
                .checked_mul(group + 1)
                .ok_or_else(|| anyhow::anyhow!("layer {l}: value count overflows"))?;
            let val_bytes = n_vals
                .checked_mul(plane.width())
                .ok_or_else(|| anyhow::anyhow!("layer {l}: value byte count overflows"))?;
            anyhow::ensure!(
                off <= body_end && body_end - off >= val_bytes,
                "layer {l}: truncated values"
            );
            let mut values = Vec::with_capacity(n_vals);
            match plane {
                ValuePlane::F32 => {
                    for _ in 0..n_vals {
                        values.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
                        off += 4;
                    }
                }
                ValuePlane::F16 => {
                    for _ in 0..n_vals {
                        let h = u16::from_le_bytes(bytes[off..off + 2].try_into().unwrap());
                        values.push(f16_bits_to_f32(h));
                        off += 2;
                    }
                }
                ValuePlane::I8 { scale } => {
                    for _ in 0..n_vals {
                        let q = bytes[off] as i8;
                        // The encoder clamps to ±127; -128 has no
                        // round-trippable preimage, so reject it.
                        anyhow::ensure!(q != i8::MIN, "layer {l}: out-of-range i8 value");
                        values.push(q as f32 * scale);
                        off += 1;
                    }
                }
            }
            layers.push(LayerWire { encoding: enc, plane, in_dim, out_dim, group, units, values });
        }
        anyhow::ensure!(off == body_end, "trailing bytes after last layer");
        Ok(WireUpload { layers })
    }
}

/// Encode a client's masked upload with the auto-pick rule: dense when a
/// layer is fully kept, else the cheaper of bitmap and COO. f32 values.
pub fn encode_upload(mask: &ChannelMask, params: &[Tensor], spec: &ModelSpec) -> WireUpload {
    encode_upload_with(mask, params, spec, CodecMode::Auto)
}

/// Encode with an explicit [`CodecMode`] (benches/ablations force an
/// index layout; `Auto` is the production rule). f32 values — the plane
/// generalisation is [`encode_upload_planes`].
pub fn encode_upload_with(
    mask: &ChannelMask,
    params: &[Tensor],
    spec: &ModelSpec,
    mode: CodecMode,
) -> WireUpload {
    encode_upload_planes(mask, params, spec, mode, PlaneMode::F32, 0.0)
}

/// Scaled-int8 trial for one layer's gathered values: the carried scale
/// and the realized max absolute quantization error (both 0-cost to
/// compute; nothing is mutated). Empty or all-zero layers get the exact
/// scale 1.0.
fn i8_trial(values: &[f32]) -> (f32, f32) {
    let mut max_abs = 0.0f32;
    for &v in values {
        let a = v.abs();
        if a > max_abs {
            max_abs = a;
        }
    }
    let scale = if max_abs > 0.0 && max_abs.is_finite() { max_abs / 127.0 } else { 1.0 };
    let mut max_err = 0.0f32;
    for &v in values {
        let q = (v / scale).round().clamp(-127.0, 127.0);
        let err = (q * scale - v).abs();
        if !err.is_finite() {
            return (scale, f32::INFINITY); // NaN/inf input fails the trial
        }
        if err > max_err {
            max_err = err;
        }
    }
    (scale, max_err)
}

/// f16 trial: realized max absolute round-trip error, nothing mutated.
fn f16_trial(values: &[f32]) -> f32 {
    let mut max_err = 0.0f32;
    for &v in values {
        let err = (f16_bits_to_f32(f32_to_f16_bits(v)) - v).abs();
        if !err.is_finite() {
            return f32::INFINITY;
        }
        if err > max_err {
            max_err = err;
        }
    }
    max_err
}

/// Quantize→dequantize one layer's values in place for the chosen
/// plane, so the in-memory f32s are exactly what the decoder will
/// reconstruct (and aggregation on both ends folds identical numbers).
fn apply_plane(plane: ValuePlane, values: &mut [f32]) {
    match plane {
        ValuePlane::F32 => {}
        ValuePlane::F16 => {
            for v in values {
                *v = f16_bits_to_f32(f32_to_f16_bits(*v));
            }
        }
        ValuePlane::I8 { scale } => {
            for v in values {
                let q = (*v / scale).round().clamp(-127.0, 127.0);
                *v = q as f32 * scale;
            }
        }
    }
}

/// Full encoder: index layout per [`CodecMode`], value plane per
/// [`PlaneMode`]. `Auto` picks, per layer, the narrowest plane whose
/// realized max quantization error is ≤ `plane_error · max|v|` of that
/// layer (tried i8 → f16 → f32; non-finite values fail every trial and
/// fall back to f32). Forced lossy planes apply unconditionally.
/// `plane_error` is ignored outside `Auto`.
pub fn encode_upload_planes(
    mask: &ChannelMask,
    params: &[Tensor],
    spec: &ModelSpec,
    mode: CodecMode,
    plane_mode: PlaneMode,
    plane_error: f64,
) -> WireUpload {
    assert_eq!(params.len(), spec.layers.len() * 2, "params arity");
    assert_eq!(mask.per_layer.len(), spec.layers.len(), "mask arity");
    let mut layers = Vec::with_capacity(spec.layers.len());
    for (l, layer) in spec.layers.iter().enumerate() {
        let sel = &mask.per_layer[l];
        assert_eq!(sel.len(), layer.out_dim, "layer {l} mask length");
        let group = unit_group(layer);
        let w = params[2 * l].data();
        let b = params[2 * l + 1].data();
        assert_eq!(w.len(), layer.out_dim * group, "layer {l} weight numel");
        assert_eq!(b.len(), layer.out_dim, "layer {l} bias numel");
        let (mut units, mut values) = take_wire_buffers();
        units.clear();
        units.extend(
            sel.iter()
                .enumerate()
                .filter(|(_, &s)| s)
                .map(|(k, _)| k as u32),
        );
        values.clear();
        gather_unit_values_into(layer, w, b, &units, &mut values);
        let n_sel = units.len();
        let encoding = match mode {
            CodecMode::Bitmap => Encoding::Bitmap,
            CodecMode::Coo => Encoding::Coo,
            CodecMode::Auto => {
                if n_sel == layer.out_dim {
                    Encoding::Dense
                } else if layer.out_dim.div_ceil(8) <= 4 * n_sel {
                    Encoding::Bitmap
                } else {
                    Encoding::Coo
                }
            }
        };
        let plane = match plane_mode {
            PlaneMode::F32 => ValuePlane::F32,
            PlaneMode::F16 => ValuePlane::F16,
            PlaneMode::I8 => {
                let (scale, _) = i8_trial(&values);
                ValuePlane::I8 { scale }
            }
            PlaneMode::Auto => {
                let mut max_abs = 0.0f32;
                for &v in &values {
                    let a = v.abs();
                    if a > max_abs {
                        max_abs = a;
                    }
                }
                let bound = plane_error as f32 * max_abs;
                let (scale, i8_err) = i8_trial(&values);
                if i8_err <= bound {
                    ValuePlane::I8 { scale }
                } else if f16_trial(&values) <= bound {
                    ValuePlane::F16
                } else {
                    ValuePlane::F32
                }
            }
        };
        apply_plane(plane, &mut values);
        layers.push(LayerWire {
            encoding,
            plane,
            in_dim: layer.in_dim,
            out_dim: layer.out_dim,
            group,
            units,
            values,
        });
    }
    let up = WireUpload { layers };
    // The upload_bytes bound covers the auto index pick only: forcing
    // the dearer index layout (e.g. COO on a fully-kept layer) can
    // exceed it by construction. The f32-width bound stays valid for
    // every plane mode — planes only ever shrink the value term.
    debug_assert!(
        mode != CodecMode::Auto || up.wire_len() <= mask.upload_bytes(spec),
        "auto-picked wire_len {} exceeds the upload_bytes bound {}",
        up.wire_len(),
        mask.upload_bytes(spec)
    );
    up
}

/// Reconstruct the channel mask and the client-shaped masked parameters
/// (zeros at dropped positions) from a wire upload — the decoder side of
/// [`encode_upload`], used by round-trip tests and debugging tools.
pub fn decode_upload(
    up: &WireUpload,
    spec: &ModelSpec,
) -> anyhow::Result<(ChannelMask, Vec<Tensor>)> {
    anyhow::ensure!(
        up.layers.len() == spec.layers.len(),
        "wire has {} layers, spec has {}",
        up.layers.len(),
        spec.layers.len()
    );
    let mut per_layer = Vec::with_capacity(spec.layers.len());
    let mut params = Vec::with_capacity(spec.layers.len() * 2);
    for (l, (lw, layer)) in up.layers.iter().zip(&spec.layers).enumerate() {
        let group = unit_group(layer);
        anyhow::ensure!(lw.in_dim == layer.in_dim, "layer {l}: in_dim mismatch");
        anyhow::ensure!(lw.out_dim == layer.out_dim, "layer {l}: out_dim mismatch");
        anyhow::ensure!(lw.group == group, "layer {l}: group mismatch");
        let chunk = group + 1;
        anyhow::ensure!(
            lw.values.len() == lw.units.len() * chunk,
            "layer {l}: {} values for {} units",
            lw.values.len(),
            lw.units.len()
        );
        let out = layer.out_dim;
        let mut wdat = vec![0.0f32; out * group];
        let mut bdat = vec![0.0f32; out];
        let mut sel = vec![false; out];
        for &k in &lw.units {
            let k = k as usize;
            anyhow::ensure!(k < out, "layer {l}: unit {k} >= out_dim {out}");
            anyhow::ensure!(!sel[k], "layer {l}: duplicate unit {k}");
            sel[k] = true;
        }
        scatter_unit_values(layer, &mut wdat, &mut bdat, &lw.units, &lw.values);
        let wshape = match layer.kind {
            LayerKind::Conv { kernel, .. } => vec![out, layer.in_dim, kernel, kernel],
            LayerKind::Fc => vec![layer.in_dim, out],
        };
        params.push(Tensor::new(wshape, wdat));
        params.push(Tensor::new(vec![out], bdat));
        per_layer.push(sel);
    }
    Ok((ChannelMask { per_layer }, params))
}

/// FNV-1a 64 over a byte slice (the wire checksum).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn read_u16(bytes: &[u8], off: &mut usize) -> anyhow::Result<u16> {
    anyhow::ensure!(*off + 2 <= bytes.len(), "truncated u16 at offset {off}");
    let v = u16::from_le_bytes(bytes[*off..*off + 2].try_into().unwrap());
    *off += 2;
    Ok(v)
}

fn read_u32(bytes: &[u8], off: &mut usize) -> anyhow::Result<u32> {
    anyhow::ensure!(*off + 4 <= bytes.len(), "truncated u32 at offset {off}");
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::{select_mask, Policy};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn mask_with(spec: &ModelSpec, keep: &[&[usize]]) -> ChannelMask {
        ChannelMask {
            per_layer: spec
                .layers
                .iter()
                .zip(keep)
                .map(|(layer, ks)| {
                    let mut v = vec![false; layer.out_dim];
                    for &k in ks.iter() {
                        v[k] = true;
                    }
                    v
                })
                .collect(),
        }
    }

    #[test]
    fn full_mask_encodes_dense_and_costs_headers_only_extra() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(0);
        let params = spec.init_params(&mut rng);
        let up = encode_upload(&ChannelMask::full(&spec), &params, &spec);
        assert_eq!(up.mix(), EncodingMix { dense: 3, bitmap: 0, coo: 0 });
        assert_eq!(up.payload_bytes(), spec.size_bytes());
        let overhead =
            GLOBAL_HEADER_BYTES + CHECKSUM_BYTES + spec.layers.len() * LAYER_HEADER_BYTES;
        assert_eq!(up.wire_len(), spec.size_bytes() + overhead);
    }

    #[test]
    fn recycled_buffers_encode_identically() {
        // Encode, recycle, re-encode: the second pass draws parked
        // buffers from the freelist and must produce the same upload
        // bit for bit (and the same serialized wire bytes).
        let spec = ModelSpec::get("mlp", 0.5).unwrap();
        let mut rng = Rng::new(7);
        let params = spec.init_params(&mut rng);
        let half: Vec<usize> = (0..spec.layers[0].out_dim / 2).collect();
        let one = [3usize];
        let tail: Vec<usize> = (0..spec.layers[2].out_dim).collect();
        let m = mask_with(&spec, &[&half[..], &one[..], &tail[..]]);
        let want = encode_upload(&m, &params, &spec);
        recycle_wire_upload(want.clone());
        let got = encode_upload(&m, &params, &spec);
        assert_eq!(got, want);
        assert_eq!(got.to_bytes(), want.to_bytes());
        assert_eq!(got.wire_len(), want.wire_len());
        assert_eq!(got.mem_bytes(), want.mem_bytes());
    }

    #[test]
    fn auto_pick_chooses_the_smallest_layout() {
        // mlp layer 0 has 100 units: half kept -> bitmap (13 <= 200
        // bytes); 1 kept -> COO (4 < 13); all kept -> dense.
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(1);
        let params = spec.init_params(&mut rng);
        let half: Vec<usize> = (0..50).collect();
        let all1: Vec<usize> = (0..100).collect();
        let all2: Vec<usize> = (0..64).collect();
        let all3: Vec<usize> = (0..10).collect();
        let one = [7usize];
        let m = mask_with(&spec, &[&half[..], &all2[..], &all3[..]]);
        let up = encode_upload(&m, &params, &spec);
        assert_eq!(up.layers[0].encoding, Encoding::Bitmap);
        assert_eq!(up.layers[1].encoding, Encoding::Dense);
        assert_eq!(up.layers[2].encoding, Encoding::Dense);
        let m = mask_with(&spec, &[&one[..], &all2[..], &all3[..]]);
        let up = encode_upload(&m, &params, &spec);
        assert_eq!(up.layers[0].encoding, Encoding::Coo);
        assert_eq!(up.layers[0].units, vec![7]);
        let m = mask_with(&spec, &[&all1[..], &all2[..], &all3[..]]);
        let up = encode_upload(&m, &params, &spec);
        assert_eq!(up.mix().dense, 3);
        // the chosen layout is never beaten by an alternative
        for lw in &up.layers {
            let vals = lw.values.len() * 4;
            let alt_bitmap = lw.out_dim.div_ceil(8) + vals;
            let alt_coo = lw.units.len() * 4 + vals;
            assert!(lw.body_bytes() <= alt_bitmap);
            assert!(lw.body_bytes() <= alt_coo);
        }
    }

    #[test]
    fn forced_modes_apply_to_every_layer() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(2);
        let params = spec.init_params(&mut rng);
        let m = ChannelMask::full(&spec);
        let up = encode_upload_with(&m, &params, &spec, CodecMode::Bitmap);
        assert_eq!(up.mix(), EncodingMix { dense: 0, bitmap: 3, coo: 0 });
        let up = encode_upload_with(&m, &params, &spec, CodecMode::Coo);
        assert_eq!(up.mix(), EncodingMix { dense: 0, bitmap: 0, coo: 3 });
        // round-trips still hold under forced layouts
        let back = WireUpload::from_bytes(&up.to_bytes()).unwrap();
        assert_eq!(back, up);
    }

    #[test]
    fn wire_len_matches_serialized_length_and_bound() {
        check("wire_len == to_bytes().len() <= upload_bytes", 12, |rng| {
            for name in ["mlp", "cnn1"] {
                let spec = ModelSpec::get(name, 0.5).unwrap();
                let before = spec.init_params(rng);
                let after = spec.init_params(rng);
                let d = rng.range_f64(0.0, 0.95);
                let m = select_mask(Policy::Random, &spec, &before, &after, None, d, rng);
                let up = encode_upload(&m, &after, &spec);
                let bytes = up.to_bytes();
                if bytes.len() != up.wire_len() {
                    return Err(format!("{} != wire_len {}", bytes.len(), up.wire_len()));
                }
                if up.wire_len() > m.upload_bytes(&spec) {
                    return Err(format!(
                        "wire_len {} > bound {}",
                        up.wire_len(),
                        m.upload_bytes(&spec)
                    ));
                }
                if up.payload_bytes() != m.payload_bytes(&spec) {
                    return Err("payload accounting mismatch".into());
                }
                // the in-memory size covers values + unit ids exactly
                let units: usize = up.layers.iter().map(|l| l.units.len()).sum();
                if up.mem_bytes() != up.payload_bytes() + units * 4 {
                    return Err("mem_bytes accounting mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_all_layouts_bitwise() {
        // Sparse (COO), half (bitmap) and full (dense) layers round-trip
        // through bytes with bitwise value equality, and the decoded
        // params equal p ⊙ m exactly.
        check("wire round-trip", 15, |rng| {
            for name in ["mlp", "cnn1"] {
                let spec = ModelSpec::get(name, 1.0).unwrap();
                let params = spec.init_params(rng);
                let per_layer: Vec<Vec<bool>> = spec
                    .layers
                    .iter()
                    .map(|layer| {
                        // style 0: dense; 1: bitmap-ish; 2: coo-ish
                        let style = rng.below(3);
                        (0..layer.out_dim)
                            .map(|k| match style {
                                0 => true,
                                1 => rng.bool(0.5),
                                _ => k == 0 || rng.bool(0.02),
                            })
                            .collect()
                    })
                    .collect();
                let m = ChannelMask { per_layer };
                let up = encode_upload(&m, &params, &spec);
                let back = WireUpload::from_bytes(&up.to_bytes()).unwrap();
                if back != up {
                    return Err("struct round-trip mismatch".into());
                }
                let (m2, masked) = decode_upload(&back, &spec).unwrap();
                if m2 != m {
                    return Err("mask round-trip mismatch".into());
                }
                let elems = m.to_elementwise(&spec);
                for i in 0..masked.len() {
                    for j in 0..masked[i].numel() {
                        let want = params[i].data()[j] * elems[i].data()[j];
                        let got = masked[i].data()[j];
                        if got.to_bits() != want.to_bits() {
                            return Err(format!("tensor {i} pos {j}: {got} != {want}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn corruption_anywhere_is_detected() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(3);
        let before = spec.init_params(&mut rng);
        let after = spec.init_params(&mut rng);
        let m = select_mask(Policy::Random, &spec, &before, &after, None, 0.5, &mut rng);
        let up = encode_upload(&m, &after, &spec);
        let bytes = up.to_bytes();
        assert!(WireUpload::from_bytes(&bytes).is_ok());
        // flip one bit in the header, the body and the checksum itself
        for &pos in &[0usize, 5, GLOBAL_HEADER_BYTES + 2, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            assert!(WireUpload::from_bytes(&bad).is_err(), "flip at byte {pos} undetected");
        }
        // truncation is rejected too
        assert!(WireUpload::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(WireUpload::from_bytes(&[]).is_err());
    }

    #[test]
    fn dropout_always_beats_the_dense_payload() {
        // Acceptance: for every d > 0 that actually drops a unit, the
        // chosen encodings are strictly smaller than the dense payload
        // (the full-mask wire form *and* the raw full-model bytes).
        let mut rng = Rng::new(4);
        for name in ["mlp", "cnn1"] {
            let spec = ModelSpec::get(name, 1.0).unwrap();
            let before = spec.init_params(&mut rng);
            let after = spec.init_params(&mut rng);
            let dense = encode_upload(&ChannelMask::full(&spec), &after, &spec);
            for policy in [Policy::Importance, Policy::Random, Policy::Max, Policy::Delta] {
                for d in [0.1, 0.3, 0.5, 0.7, 0.9] {
                    let m = select_mask(policy, &spec, &before, &after, None, d, &mut rng);
                    let up = encode_upload(&m, &after, &spec);
                    assert!(
                        up.wire_len() < dense.wire_len(),
                        "{name} {policy:?} d={d}: {} !< {}",
                        up.wire_len(),
                        dense.wire_len()
                    );
                    assert!(
                        up.wire_len() < spec.size_bytes(),
                        "{name} {policy:?} d={d}: {} !< model {}",
                        up.wire_len(),
                        spec.size_bytes()
                    );
                }
            }
        }
    }

    #[test]
    fn fnv_vector() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn f16_conversion_vectors_and_exhaustive_roundtrip() {
        // Spot vectors.
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        // Overflow and infinities saturate to the max finite half.
        assert_eq!(f32_to_f16_bits(65536.0), 0x7bff);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7bff);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfbff);
        assert_eq!(f32_to_f16_bits(f32::NAN), 0x7e00);
        // Smallest subnormal half and below.
        assert_eq!(f32_to_f16_bits(5.960_464_5e-8), 0x0001);
        assert_eq!(f32_to_f16_bits(1.0e-9), 0x0000);
        // Round-to-nearest-even at the halfway point: 1 + 2^-11 is
        // exactly between 1.0 and the next half 1.0009766 -> even (1.0);
        // 1 + 3·2^-12 rounds up to odd-neighbour's even.
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_488_281_25), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_976_562_5), 0x3c01);
        // Every finite half round-trips bit for bit through f32.
        for h in 0u16..=0xffff {
            if (h >> 10) & 0x1f == 0x1f {
                continue; // inf/NaN payloads do not round-trip by design
            }
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(back, h, "half {h:#06x} failed the round-trip");
        }
    }

    #[test]
    fn f32_plane_is_bitwise_identical_to_legacy_encode() {
        let spec = ModelSpec::get("mlp", 0.5).unwrap();
        let mut rng = Rng::new(11);
        let before = spec.init_params(&mut rng);
        let after = spec.init_params(&mut rng);
        let m = select_mask(Policy::Random, &spec, &before, &after, None, 0.4, &mut rng);
        let legacy = encode_upload(&m, &after, &spec);
        let planes = encode_upload_planes(&m, &after, &spec, CodecMode::Auto, PlaneMode::F32, 0.5);
        assert_eq!(planes, legacy);
        assert_eq!(planes.to_bytes(), legacy.to_bytes());
        let mix = planes.plane_mix();
        assert_eq!(mix.f32_layers, spec.layers.len());
        assert_eq!(mix.f16_layers + mix.i8_layers, 0);
        assert_eq!(mix.f32_bytes, planes.payload_bytes());
    }

    #[test]
    fn lossy_planes_roundtrip_bitwise_and_reencode_identically() {
        // For every plane mode: decode(bytes) equals the encoded struct
        // exactly (values are dequantized at encode time), and
        // re-serializing the decoded upload reproduces the bytes — the
        // quantizers are idempotent.
        check("plane round-trip", 10, |rng| {
            for name in ["mlp", "cnn1"] {
                let spec = ModelSpec::get(name, 0.5).unwrap();
                let before = spec.init_params(rng);
                let after = spec.init_params(rng);
                let d = rng.range_f64(0.0, 0.9);
                let m = select_mask(Policy::Random, &spec, &before, &after, None, d, rng);
                for pm in [PlaneMode::F32, PlaneMode::F16, PlaneMode::I8, PlaneMode::Auto] {
                    let up =
                        encode_upload_planes(&m, &after, &spec, CodecMode::Auto, pm, 0.005);
                    let bytes = up.to_bytes();
                    if bytes.len() != up.wire_len() {
                        return Err(format!("{pm:?}: wire_len != serialized length"));
                    }
                    let back = WireUpload::from_bytes(&bytes)
                        .map_err(|e| format!("{pm:?}: decode failed: {e}"))?;
                    if back != up {
                        return Err(format!("{pm:?}: struct round-trip mismatch"));
                    }
                    if back.to_bytes() != bytes {
                        return Err(format!("{pm:?}: re-encode not idempotent"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantization_error_stays_within_the_bound() {
        // Forced i8: realized error ≤ max_abs/254 + slack per layer.
        // Auto: realized error ≤ plane_error · max_abs by construction.
        let spec = ModelSpec::get("mlp", 0.5).unwrap();
        let mut rng = Rng::new(12);
        let params = spec.init_params(&mut rng);
        let m = ChannelMask::full(&spec);
        let exact = encode_upload(&m, &params, &spec);
        let bound = 0.005f32;
        let auto = encode_upload_planes(&m, &params, &spec, CodecMode::Auto, PlaneMode::Auto, 0.005);
        for (lq, lx) in auto.layers.iter().zip(&exact.layers) {
            let max_abs = lx.values.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            for (q, x) in lq.values.iter().zip(&lx.values) {
                assert!(
                    (q - x).abs() <= bound * max_abs,
                    "auto plane error {} beyond {}",
                    (q - x).abs(),
                    bound * max_abs
                );
            }
        }
        // The default bound admits i8 on every layer (guaranteed i8
        // error ≤ max_abs/254 ≈ 0.0039·max_abs < 0.005·max_abs).
        assert_eq!(auto.plane_mix().i8_layers, spec.layers.len());
        // A zero bound forces f32 everywhere (random weights never
        // quantize exactly).
        let strict =
            encode_upload_planes(&m, &params, &spec, CodecMode::Auto, PlaneMode::Auto, 0.0);
        assert_eq!(strict, exact);
    }

    #[test]
    fn quantized_planes_shrink_payload_and_wire() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(13);
        let params = spec.init_params(&mut rng);
        let m = ChannelMask::full(&spec);
        let f32p = encode_upload_planes(&m, &params, &spec, CodecMode::Auto, PlaneMode::F32, 0.0);
        let f16p = encode_upload_planes(&m, &params, &spec, CodecMode::Auto, PlaneMode::F16, 0.0);
        let i8p = encode_upload_planes(&m, &params, &spec, CodecMode::Auto, PlaneMode::I8, 0.0);
        assert_eq!(f16p.payload_bytes() * 2, f32p.payload_bytes());
        assert_eq!(i8p.payload_bytes() * 4, f32p.payload_bytes());
        assert!(i8p.wire_len() < f16p.wire_len());
        assert!(f16p.wire_len() < f32p.wire_len());
        // The plane-width bound tracks the narrower planes.
        assert!(f16p.wire_len() <= upload_bound_with(&m, &spec, 2));
        assert!(i8p.wire_len() <= upload_bound_with(&m, &spec, 1));
        // mem_bytes is plane-independent: the decoded form is f32.
        assert_eq!(i8p.mem_bytes(), f32p.mem_bytes());
    }

    #[test]
    fn corruption_in_quantized_planes_is_detected() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(14);
        let before = spec.init_params(&mut rng);
        let after = spec.init_params(&mut rng);
        let m = select_mask(Policy::Random, &spec, &before, &after, None, 0.5, &mut rng);
        for pm in [PlaneMode::F16, PlaneMode::I8] {
            let up = encode_upload_planes(&m, &after, &spec, CodecMode::Auto, pm, 0.0);
            let bytes = up.to_bytes();
            assert!(WireUpload::from_bytes(&bytes).is_ok());
            // Flip a byte squarely inside the value planes (the message
            // tail before the checksum is value data).
            let mut bad = bytes.clone();
            let pos = bytes.len() - CHECKSUM_BYTES - 2;
            bad[pos] ^= 0x04;
            assert!(
                WireUpload::from_bytes(&bad).is_err(),
                "{pm:?}: flipped value byte undetected"
            );
        }
    }

    #[test]
    fn non_canonical_plane_headers_are_rejected() {
        // A nonzero scale on an f32/f16 plane, a bad i8 scale, or an
        // unknown plane tag must be rejected even when re-checksummed —
        // canonical headers are what make re-encoding byte-stable.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(15);
        let params = spec.init_params(&mut rng);
        let m = ChannelMask::full(&spec);
        let up = encode_upload_planes(&m, &params, &spec, CodecMode::Auto, PlaneMode::F32, 0.0);
        let bytes = up.to_bytes();
        let reseal = |mut b: Vec<u8>| {
            let end = b.len() - CHECKSUM_BYTES;
            let sum = fnv1a64(&b[..end]);
            b[end..].copy_from_slice(&sum.to_le_bytes());
            b
        };
        // Layer 0 header: enc tag, plane tag, 4×u32, scale f32.
        let plane_off = GLOBAL_HEADER_BYTES + 1;
        let scale_off = GLOBAL_HEADER_BYTES + 2 + 16;
        let mut bad = bytes.clone();
        bad[scale_off..scale_off + 4].copy_from_slice(&1.0f32.to_le_bytes());
        assert!(WireUpload::from_bytes(&reseal(bad)).is_err(), "nonzero f32 scale accepted");
        let mut bad = bytes.clone();
        bad[plane_off] = 9;
        assert!(WireUpload::from_bytes(&reseal(bad)).is_err(), "unknown plane tag accepted");
        let mut bad = bytes.clone();
        bad[plane_off] = 2; // i8 with the zero scale still in the header
        assert!(WireUpload::from_bytes(&reseal(bad)).is_err(), "zero i8 scale accepted");
    }
}
