//! Sparse-upload wire codec: the bytes a FedDD client actually puts on
//! the uplink (DESIGN.md §8).
//!
//! `ChannelMask` says *which* units a client uploads; this module decides
//! *how* they are laid out on the wire and what that really costs. Three
//! per-layer layouts:
//!
//! * **dense**  — every unit's value group in unit order, no index
//!   overhead (only representable when the layer is fully kept);
//! * **bitmap** — `ceil(out_dim/8)` bytes of per-unit presence bits, then
//!   the kept units' value groups in ascending unit order;
//! * **COO**    — one `u32` unit index per kept unit, then the value
//!   groups (wins when fewer than ~`out_dim/32` units survive).
//!
//! [`encode_upload`] gathers the masked values (a unit's value group is
//! its incoming weights followed by its bias) and auto-picks the smallest
//! layout per layer; [`WireUpload::wire_len`] is the realized byte count
//! the simnet charges `t_up` for — a measurement, replacing the
//! `upload_bytes` estimate. [`WireUpload::to_bytes`] /
//! [`WireUpload::from_bytes`] give the self-describing serialized form:
//! a magic/version header, per-layer geometry records and a trailing
//! FNV-1a 64 checksum over everything before it.
//!
//! The aggregation side never re-densifies: `Aggregator::absorb_wire`
//! folds bitmap/COO payloads straight into the Eq. 4 num/den partials
//! (see `aggregation`), bitwise-identical to the dense mask path.

use std::sync::Mutex;

use crate::model::{Layer, LayerKind, ModelSpec};
use crate::selection::ChannelMask;
use crate::tensor::Tensor;

/// Recycling pool for decoded upload buffers: the `units`/`values` pairs
/// a [`WireUpload`] owns. An upload is encoded on a pool worker, folded
/// once by `Aggregator::absorb_wire` on the coordinator thread, and then
/// dropped — at fleet scale that is two short-lived heap allocations per
/// client per round. The engine returns folded uploads here
/// ([`recycle_wire_upload`]) and [`encode_upload_with`] draws from the
/// pool before allocating fresh.
///
/// Determinism-safe by construction: a drawn buffer is cleared and then
/// fully rewritten (`extend` over exactly the kept units), every byte
/// accounting is length-based, and the wire form never sees capacity —
/// so pool hits and misses produce identical uploads (asserted by
/// `recycled_buffers_encode_identically` below and the cross-worker
/// fleet battery).
static WIRE_SCRATCH: Mutex<Vec<(Vec<u32>, Vec<f32>)>> = Mutex::new(Vec::new());

/// Freelist size cap: enough for every layer of a full micro-batch of
/// in-flight uploads, small enough that the pool itself stays O(workers),
/// never O(fleet).
const WIRE_SCRATCH_CAP: usize = 1024;

fn take_wire_buffers() -> (Vec<u32>, Vec<f32>) {
    let mut pool = WIRE_SCRATCH.lock().unwrap_or_else(|e| e.into_inner());
    pool.pop().unwrap_or_default()
}

/// Return a folded upload's owned buffers to the encode freelist. Call
/// after `absorb_wire` has consumed the upload; the buffers are cleared
/// here and fully overwritten by their next encode.
pub fn recycle_wire_upload(up: WireUpload) {
    let mut pool = WIRE_SCRATCH.lock().unwrap_or_else(|e| e.into_inner());
    for mut lw in up.layers {
        if pool.len() >= WIRE_SCRATCH_CAP {
            break;
        }
        lw.units.clear();
        lw.values.clear();
        pool.push((lw.units, lw.values));
    }
}

/// Buffer pairs currently parked in the encode freelist (observability).
pub fn wire_scratch_len() -> usize {
    WIRE_SCRATCH.lock().unwrap_or_else(|e| e.into_inner()).len()
}

/// Serialized-form magic bytes ("FedDD Wire Upload").
pub const WIRE_MAGIC: [u8; 4] = *b"FDWU";
/// Serialized-form version.
pub const WIRE_VERSION: u16 = 1;
/// Global header: magic + version (u16) + layer count (u16).
pub const GLOBAL_HEADER_BYTES: usize = 8;
/// Per-layer header: encoding tag (u8) + in_dim/out_dim/n_sel/group (u32).
pub const LAYER_HEADER_BYTES: usize = 17;
/// Trailing FNV-1a 64 checksum.
pub const CHECKSUM_BYTES: usize = 8;

/// Wire layout of one layer's kept units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    Dense,
    Bitmap,
    Coo,
}

impl Encoding {
    fn tag(self) -> u8 {
        match self {
            Encoding::Dense => 0,
            Encoding::Bitmap => 1,
            Encoding::Coo => 2,
        }
    }

    fn from_tag(tag: u8) -> anyhow::Result<Encoding> {
        Ok(match tag {
            0 => Encoding::Dense,
            1 => Encoding::Bitmap,
            2 => Encoding::Coo,
            t => anyhow::bail!("unknown encoding tag {t}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Encoding::Dense => "dense",
            Encoding::Bitmap => "bitmap",
            Encoding::Coo => "coo",
        }
    }
}

/// Per-layout layer counts — the "encoding mix" column of round records
/// and bench JSON.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EncodingMix {
    pub dense: usize,
    pub bitmap: usize,
    pub coo: usize,
}

impl EncodingMix {
    pub fn count(&mut self, enc: Encoding) {
        match enc {
            Encoding::Dense => self.dense += 1,
            Encoding::Bitmap => self.bitmap += 1,
            Encoding::Coo => self.coo += 1,
        }
    }

    pub fn merge(&mut self, other: EncodingMix) {
        self.dense += other.dense;
        self.bitmap += other.bitmap;
        self.coo += other.coo;
    }

    pub fn total(&self) -> usize {
        self.dense + self.bitmap + self.coo
    }
}

/// Encoder policy: `Auto` picks the smallest layout per layer (always
/// dense for fully-kept layers); `Bitmap`/`Coo` force that index layout
/// on every layer (benches/ablations — dense cannot represent a partial
/// layer, so it is not a forcible mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecMode {
    Auto,
    Bitmap,
    Coo,
}

impl CodecMode {
    pub fn by_name(name: &str) -> anyhow::Result<CodecMode> {
        Ok(match name {
            "auto" => CodecMode::Auto,
            "bitmap" => CodecMode::Bitmap,
            "coo" => CodecMode::Coo,
            _ => anyhow::bail!("unknown codec mode {name:?} (auto|bitmap|coo)"),
        })
    }
}

/// Weights owned by one unit of `layer` (excluding its bias): the conv
/// kernel block `in·k·k`, or the FC input column `in`.
pub fn unit_group(layer: &Layer) -> usize {
    match layer.kind {
        LayerKind::Conv { kernel, .. } => layer.in_dim * kernel * kernel,
        LayerKind::Fc => layer.in_dim,
    }
}

/// Gather the value groups of the listed units of one layer into the
/// canonical wire layout: per unit (ascending), its [`unit_group`]
/// incoming weights then its bias. Shared by the upload encoder and the
/// client-state residuals (`coordinator::state`), so both sides agree on
/// the layout byte for byte.
pub fn gather_unit_values(layer: &Layer, w: &[f32], b: &[f32], units: &[u32]) -> Vec<f32> {
    let mut values = Vec::with_capacity(units.len() * (unit_group(layer) + 1));
    gather_unit_values_into(layer, w, b, units, &mut values);
    values
}

/// Append-into form of [`gather_unit_values`]: writes the value groups
/// onto the end of `values` (callers clear first when reusing a recycled
/// buffer). The wire layout is identical to the allocating form.
pub fn gather_unit_values_into(
    layer: &Layer,
    w: &[f32],
    b: &[f32],
    units: &[u32],
    values: &mut Vec<f32>,
) {
    let group = unit_group(layer);
    values.reserve(units.len() * (group + 1));
    match layer.kind {
        LayerKind::Conv { .. } => {
            for &k in units {
                let k = k as usize;
                values.extend_from_slice(&w[k * group..(k + 1) * group]);
                values.push(b[k]);
            }
        }
        LayerKind::Fc => {
            let n_out = layer.out_dim;
            for &k in units {
                let k = k as usize;
                for j in 0..layer.in_dim {
                    values.push(w[j * n_out + k]);
                }
                values.push(b[k]);
            }
        }
    }
}

/// Scatter value groups laid out by [`gather_unit_values`] back into
/// dense layer tensors: the exact inverse for the listed units; every
/// other position is left untouched.
pub fn scatter_unit_values(
    layer: &Layer,
    w: &mut [f32],
    b: &mut [f32],
    units: &[u32],
    values: &[f32],
) {
    let group = unit_group(layer);
    let chunk = group + 1;
    debug_assert_eq!(values.len(), units.len() * chunk, "value/unit arity");
    match layer.kind {
        LayerKind::Conv { .. } => {
            for (ui, &k) in units.iter().enumerate() {
                let k = k as usize;
                let vals = &values[ui * chunk..(ui + 1) * chunk];
                w[k * group..(k + 1) * group].copy_from_slice(&vals[..group]);
                b[k] = vals[group];
            }
        }
        LayerKind::Fc => {
            let out = layer.out_dim;
            for (ui, &k) in units.iter().enumerate() {
                let k = k as usize;
                let vals = &values[ui * chunk..(ui + 1) * chunk];
                for j in 0..layer.in_dim {
                    w[j * out + k] = vals[j];
                }
                b[k] = vals[group];
            }
        }
    }
}

/// Index overhead (bytes) of the cheaper index layout for `n_sel` of
/// `out_dim` units: bitmap vs COO.
pub fn index_overhead(out_dim: usize, n_sel: usize) -> usize {
    out_dim.div_ceil(8).min(4 * n_sel)
}

/// Upper bound on `encode_upload(mask, ..).wire_len()`: headers + masked
/// values + the cheaper index overhead per layer, *whether or not* the
/// layer is fully kept (a fully-kept layer encodes dense, with zero index
/// overhead, so the bound is not tight there). `ChannelMask::upload_bytes`
/// delegates here; `encode_upload` debug-asserts the bound.
pub fn upload_bound(mask: &ChannelMask, spec: &ModelSpec) -> usize {
    let mut total = GLOBAL_HEADER_BYTES + CHECKSUM_BYTES;
    for (layer, sel) in spec.layers.iter().zip(&mask.per_layer) {
        let n_sel = sel.iter().filter(|&&b| b).count();
        total += LAYER_HEADER_BYTES
            + n_sel * (unit_group(layer) + 1) * 4
            + index_overhead(layer.out_dim, n_sel);
    }
    total
}

/// One layer of a [`WireUpload`] in structured (decoded) form. The
/// `encoding` decides the serialized layout and the byte accounting;
/// `units`/`values` are the layout-independent content: ascending kept
/// unit ids and, per unit, its `group` incoming weights followed by its
/// bias.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerWire {
    pub encoding: Encoding,
    /// Client-side layer input dimension (conv in-channels / FC inputs).
    pub in_dim: usize,
    /// Client-side unit count of the layer.
    pub out_dim: usize,
    /// Weights per unit excluding the bias ([`unit_group`]).
    pub group: usize,
    /// Kept unit ids, strictly ascending.
    pub units: Vec<u32>,
    /// `units.len() · (group + 1)` values; bias last within each chunk.
    pub values: Vec<f32>,
}

impl LayerWire {
    pub fn n_sel(&self) -> usize {
        self.units.len()
    }

    /// Serialized body bytes of this layer under its encoding.
    pub fn body_bytes(&self) -> usize {
        let vals = self.values.len() * 4;
        match self.encoding {
            Encoding::Dense => vals,
            Encoding::Bitmap => self.out_dim.div_ceil(8) + vals,
            Encoding::Coo => self.units.len() * 4 + vals,
        }
    }
}

/// A client's encoded upload: what actually travels on the uplink.
#[derive(Clone, Debug, PartialEq)]
pub struct WireUpload {
    pub layers: Vec<LayerWire>,
}

impl WireUpload {
    /// Realized wire bytes (headers + index overhead + values +
    /// checksum) — exactly `to_bytes().len()`. This is what the simnet
    /// charges the uplink for.
    pub fn wire_len(&self) -> usize {
        let body: usize = self.layers.iter().map(|l| LAYER_HEADER_BYTES + l.body_bytes()).sum();
        GLOBAL_HEADER_BYTES + CHECKSUM_BYTES + body
    }

    /// Bytes of the masked f32 values alone (no indices, no headers) —
    /// the budget-accounting payload, `ChannelMask::payload_bytes`.
    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.values.len() * 4).sum()
    }

    /// Heap bytes of the *decoded* upload held in memory (unit ids +
    /// values) — what a server buffering this upload actually stores,
    /// as opposed to the serialized [`WireUpload::wire_len`], whose
    /// bitmap layout can index many units in few wire bytes. The
    /// semi-async pending-state accounting charges this.
    pub fn mem_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.units.len() * 4 + l.values.len() * 4)
            .sum()
    }

    /// Per-layout layer counts of this upload.
    pub fn mix(&self) -> EncodingMix {
        let mut mix = EncodingMix::default();
        for l in &self.layers {
            mix.count(l.encoding);
        }
        mix
    }

    /// Serialize to the self-describing wire form (DESIGN.md §8).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        out.extend_from_slice(&WIRE_MAGIC);
        out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.layers.len() as u16).to_le_bytes());
        for l in &self.layers {
            out.push(l.encoding.tag());
            out.extend_from_slice(&(l.in_dim as u32).to_le_bytes());
            out.extend_from_slice(&(l.out_dim as u32).to_le_bytes());
            out.extend_from_slice(&(l.units.len() as u32).to_le_bytes());
            out.extend_from_slice(&(l.group as u32).to_le_bytes());
        }
        for l in &self.layers {
            match l.encoding {
                Encoding::Dense => {}
                Encoding::Bitmap => {
                    let mut bits = vec![0u8; l.out_dim.div_ceil(8)];
                    for &k in &l.units {
                        bits[k as usize / 8] |= 1 << (k as usize % 8);
                    }
                    out.extend_from_slice(&bits);
                }
                Encoding::Coo => {
                    for &k in &l.units {
                        out.extend_from_slice(&k.to_le_bytes());
                    }
                }
            }
            for &v in &l.values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        debug_assert_eq!(out.len(), self.wire_len());
        out
    }

    /// Parse and validate the wire form: magic, version, geometry sanity,
    /// strictly-ascending unit ids, and the trailing checksum (any bit
    /// flip anywhere in the message is rejected).
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<WireUpload> {
        anyhow::ensure!(
            bytes.len() >= GLOBAL_HEADER_BYTES + CHECKSUM_BYTES,
            "wire message too short ({} bytes)",
            bytes.len()
        );
        let body_end = bytes.len() - CHECKSUM_BYTES;
        let want = u64::from_le_bytes(bytes[body_end..].try_into().unwrap());
        let got = fnv1a64(&bytes[..body_end]);
        anyhow::ensure!(got == want, "wire checksum mismatch ({got:#x} != {want:#x})");
        anyhow::ensure!(bytes[..4] == WIRE_MAGIC, "bad wire magic");
        let mut off = 4;
        let version = read_u16(bytes, &mut off)?;
        anyhow::ensure!(version == WIRE_VERSION, "unsupported wire version {version}");
        let n_layers = read_u16(bytes, &mut off)? as usize;
        let mut heads = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            anyhow::ensure!(off < body_end, "layer {l}: truncated header");
            let enc = Encoding::from_tag(bytes[off])?;
            off += 1;
            let in_dim = read_u32(bytes, &mut off)? as usize;
            let out_dim = read_u32(bytes, &mut off)? as usize;
            let n_sel = read_u32(bytes, &mut off)? as usize;
            let group = read_u32(bytes, &mut off)? as usize;
            anyhow::ensure!(out_dim >= 1, "layer {l}: zero out_dim");
            anyhow::ensure!(in_dim >= 1, "layer {l}: zero in_dim");
            anyhow::ensure!(n_sel <= out_dim, "layer {l}: n_sel {n_sel} > out_dim {out_dim}");
            anyhow::ensure!(group >= in_dim, "layer {l}: group {group} < in_dim {in_dim}");
            anyhow::ensure!(
                enc != Encoding::Dense || n_sel == out_dim,
                "layer {l}: dense encoding with partial selection"
            );
            heads.push((enc, in_dim, out_dim, n_sel, group));
        }
        // Bound every allocation by the actual message size before
        // trusting any header geometry: the declared bodies must tile the
        // body region exactly. (The checksum is not cryptographic, so a
        // crafted header could otherwise demand multi-GB unit/value
        // buffers from a tiny message.)
        let mut expected: usize = 0;
        for (l, &(enc, _, out_dim, n_sel, group)) in heads.iter().enumerate() {
            let val_bytes = n_sel
                .checked_mul(group + 1)
                .and_then(|n| n.checked_mul(4))
                .ok_or_else(|| anyhow::anyhow!("layer {l}: value byte count overflows"))?;
            let idx_bytes = match enc {
                Encoding::Dense => 0,
                Encoding::Bitmap => out_dim.div_ceil(8),
                Encoding::Coo => n_sel * 4,
            };
            expected = expected
                .checked_add(val_bytes)
                .and_then(|e| e.checked_add(idx_bytes))
                .ok_or_else(|| anyhow::anyhow!("layer {l}: body size overflows"))?;
        }
        anyhow::ensure!(
            off <= body_end && expected == body_end - off,
            "declared bodies ({expected} bytes) do not tile the message body"
        );
        let mut layers = Vec::with_capacity(n_layers);
        for (l, (enc, in_dim, out_dim, n_sel, group)) in heads.into_iter().enumerate() {
            let units: Vec<u32> = match enc {
                Encoding::Dense => (0..out_dim as u32).collect(),
                Encoding::Bitmap => {
                    let nb = out_dim.div_ceil(8);
                    anyhow::ensure!(off + nb <= body_end, "layer {l}: truncated bitmap");
                    let bits = &bytes[off..off + nb];
                    off += nb;
                    let mut units = Vec::with_capacity(n_sel);
                    for k in 0..out_dim {
                        if bits[k / 8] & (1 << (k % 8)) != 0 {
                            units.push(k as u32);
                        }
                    }
                    for (byte, &b) in bits.iter().enumerate() {
                        for bit in 0..8 {
                            let k = byte * 8 + bit;
                            anyhow::ensure!(
                                k < out_dim || b & (1 << bit) == 0,
                                "layer {l}: bitmap bit {k} beyond out_dim {out_dim}"
                            );
                        }
                    }
                    units
                }
                Encoding::Coo => {
                    let mut units = Vec::with_capacity(n_sel);
                    for _ in 0..n_sel {
                        units.push(read_u32(bytes, &mut off)?);
                    }
                    units
                }
            };
            anyhow::ensure!(
                units.len() == n_sel,
                "layer {l}: {} indexed units, header says {n_sel}",
                units.len()
            );
            for w in units.windows(2) {
                anyhow::ensure!(w[0] < w[1], "layer {l}: unit ids not strictly ascending");
            }
            if let Some(&last) = units.last() {
                anyhow::ensure!(
                    (last as usize) < out_dim,
                    "layer {l}: unit {last} >= out_dim {out_dim}"
                );
            }
            let n_vals = n_sel
                .checked_mul(group + 1)
                .ok_or_else(|| anyhow::anyhow!("layer {l}: value count overflows"))?;
            let val_bytes = n_vals
                .checked_mul(4)
                .ok_or_else(|| anyhow::anyhow!("layer {l}: value byte count overflows"))?;
            anyhow::ensure!(
                off <= body_end && body_end - off >= val_bytes,
                "layer {l}: truncated values"
            );
            let mut values = Vec::with_capacity(n_vals);
            for _ in 0..n_vals {
                values.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
                off += 4;
            }
            layers.push(LayerWire { encoding: enc, in_dim, out_dim, group, units, values });
        }
        anyhow::ensure!(off == body_end, "trailing bytes after last layer");
        Ok(WireUpload { layers })
    }
}

/// Encode a client's masked upload with the auto-pick rule: dense when a
/// layer is fully kept, else the cheaper of bitmap and COO.
pub fn encode_upload(mask: &ChannelMask, params: &[Tensor], spec: &ModelSpec) -> WireUpload {
    encode_upload_with(mask, params, spec, CodecMode::Auto)
}

/// Encode with an explicit [`CodecMode`] (benches/ablations force an
/// index layout; `Auto` is the production rule).
pub fn encode_upload_with(
    mask: &ChannelMask,
    params: &[Tensor],
    spec: &ModelSpec,
    mode: CodecMode,
) -> WireUpload {
    assert_eq!(params.len(), spec.layers.len() * 2, "params arity");
    assert_eq!(mask.per_layer.len(), spec.layers.len(), "mask arity");
    let mut layers = Vec::with_capacity(spec.layers.len());
    for (l, layer) in spec.layers.iter().enumerate() {
        let sel = &mask.per_layer[l];
        assert_eq!(sel.len(), layer.out_dim, "layer {l} mask length");
        let group = unit_group(layer);
        let w = params[2 * l].data();
        let b = params[2 * l + 1].data();
        assert_eq!(w.len(), layer.out_dim * group, "layer {l} weight numel");
        assert_eq!(b.len(), layer.out_dim, "layer {l} bias numel");
        let (mut units, mut values) = take_wire_buffers();
        units.clear();
        units.extend(
            sel.iter()
                .enumerate()
                .filter(|(_, &s)| s)
                .map(|(k, _)| k as u32),
        );
        values.clear();
        gather_unit_values_into(layer, w, b, &units, &mut values);
        let n_sel = units.len();
        let encoding = match mode {
            CodecMode::Bitmap => Encoding::Bitmap,
            CodecMode::Coo => Encoding::Coo,
            CodecMode::Auto => {
                if n_sel == layer.out_dim {
                    Encoding::Dense
                } else if layer.out_dim.div_ceil(8) <= 4 * n_sel {
                    Encoding::Bitmap
                } else {
                    Encoding::Coo
                }
            }
        };
        layers.push(LayerWire {
            encoding,
            in_dim: layer.in_dim,
            out_dim: layer.out_dim,
            group,
            units,
            values,
        });
    }
    let up = WireUpload { layers };
    // The upload_bytes bound covers the auto-pick only: forcing the
    // dearer index layout (e.g. COO on a fully-kept layer) can exceed it
    // by construction.
    debug_assert!(
        mode != CodecMode::Auto || up.wire_len() <= mask.upload_bytes(spec),
        "auto-picked wire_len {} exceeds the upload_bytes bound {}",
        up.wire_len(),
        mask.upload_bytes(spec)
    );
    up
}

/// Reconstruct the channel mask and the client-shaped masked parameters
/// (zeros at dropped positions) from a wire upload — the decoder side of
/// [`encode_upload`], used by round-trip tests and debugging tools.
pub fn decode_upload(
    up: &WireUpload,
    spec: &ModelSpec,
) -> anyhow::Result<(ChannelMask, Vec<Tensor>)> {
    anyhow::ensure!(
        up.layers.len() == spec.layers.len(),
        "wire has {} layers, spec has {}",
        up.layers.len(),
        spec.layers.len()
    );
    let mut per_layer = Vec::with_capacity(spec.layers.len());
    let mut params = Vec::with_capacity(spec.layers.len() * 2);
    for (l, (lw, layer)) in up.layers.iter().zip(&spec.layers).enumerate() {
        let group = unit_group(layer);
        anyhow::ensure!(lw.in_dim == layer.in_dim, "layer {l}: in_dim mismatch");
        anyhow::ensure!(lw.out_dim == layer.out_dim, "layer {l}: out_dim mismatch");
        anyhow::ensure!(lw.group == group, "layer {l}: group mismatch");
        let chunk = group + 1;
        anyhow::ensure!(
            lw.values.len() == lw.units.len() * chunk,
            "layer {l}: {} values for {} units",
            lw.values.len(),
            lw.units.len()
        );
        let out = layer.out_dim;
        let mut wdat = vec![0.0f32; out * group];
        let mut bdat = vec![0.0f32; out];
        let mut sel = vec![false; out];
        for &k in &lw.units {
            let k = k as usize;
            anyhow::ensure!(k < out, "layer {l}: unit {k} >= out_dim {out}");
            anyhow::ensure!(!sel[k], "layer {l}: duplicate unit {k}");
            sel[k] = true;
        }
        scatter_unit_values(layer, &mut wdat, &mut bdat, &lw.units, &lw.values);
        let wshape = match layer.kind {
            LayerKind::Conv { kernel, .. } => vec![out, layer.in_dim, kernel, kernel],
            LayerKind::Fc => vec![layer.in_dim, out],
        };
        params.push(Tensor::new(wshape, wdat));
        params.push(Tensor::new(vec![out], bdat));
        per_layer.push(sel);
    }
    Ok((ChannelMask { per_layer }, params))
}

/// FNV-1a 64 over a byte slice (the wire checksum).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn read_u16(bytes: &[u8], off: &mut usize) -> anyhow::Result<u16> {
    anyhow::ensure!(*off + 2 <= bytes.len(), "truncated u16 at offset {off}");
    let v = u16::from_le_bytes(bytes[*off..*off + 2].try_into().unwrap());
    *off += 2;
    Ok(v)
}

fn read_u32(bytes: &[u8], off: &mut usize) -> anyhow::Result<u32> {
    anyhow::ensure!(*off + 4 <= bytes.len(), "truncated u32 at offset {off}");
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::{select_mask, Policy};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn mask_with(spec: &ModelSpec, keep: &[&[usize]]) -> ChannelMask {
        ChannelMask {
            per_layer: spec
                .layers
                .iter()
                .zip(keep)
                .map(|(layer, ks)| {
                    let mut v = vec![false; layer.out_dim];
                    for &k in ks.iter() {
                        v[k] = true;
                    }
                    v
                })
                .collect(),
        }
    }

    #[test]
    fn full_mask_encodes_dense_and_costs_headers_only_extra() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(0);
        let params = spec.init_params(&mut rng);
        let up = encode_upload(&ChannelMask::full(&spec), &params, &spec);
        assert_eq!(up.mix(), EncodingMix { dense: 3, bitmap: 0, coo: 0 });
        assert_eq!(up.payload_bytes(), spec.size_bytes());
        let overhead =
            GLOBAL_HEADER_BYTES + CHECKSUM_BYTES + spec.layers.len() * LAYER_HEADER_BYTES;
        assert_eq!(up.wire_len(), spec.size_bytes() + overhead);
    }

    #[test]
    fn recycled_buffers_encode_identically() {
        // Encode, recycle, re-encode: the second pass draws parked
        // buffers from the freelist and must produce the same upload
        // bit for bit (and the same serialized wire bytes).
        let spec = ModelSpec::get("mlp", 0.5).unwrap();
        let mut rng = Rng::new(7);
        let params = spec.init_params(&mut rng);
        let half: Vec<usize> = (0..spec.layers[0].out_dim / 2).collect();
        let one = [3usize];
        let tail: Vec<usize> = (0..spec.layers[2].out_dim).collect();
        let m = mask_with(&spec, &[&half[..], &one[..], &tail[..]]);
        let want = encode_upload(&m, &params, &spec);
        recycle_wire_upload(want.clone());
        let got = encode_upload(&m, &params, &spec);
        assert_eq!(got, want);
        assert_eq!(got.to_bytes(), want.to_bytes());
        assert_eq!(got.wire_len(), want.wire_len());
        assert_eq!(got.mem_bytes(), want.mem_bytes());
    }

    #[test]
    fn auto_pick_chooses_the_smallest_layout() {
        // mlp layer 0 has 100 units: half kept -> bitmap (13 <= 200
        // bytes); 1 kept -> COO (4 < 13); all kept -> dense.
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(1);
        let params = spec.init_params(&mut rng);
        let half: Vec<usize> = (0..50).collect();
        let all1: Vec<usize> = (0..100).collect();
        let all2: Vec<usize> = (0..64).collect();
        let all3: Vec<usize> = (0..10).collect();
        let one = [7usize];
        let m = mask_with(&spec, &[&half[..], &all2[..], &all3[..]]);
        let up = encode_upload(&m, &params, &spec);
        assert_eq!(up.layers[0].encoding, Encoding::Bitmap);
        assert_eq!(up.layers[1].encoding, Encoding::Dense);
        assert_eq!(up.layers[2].encoding, Encoding::Dense);
        let m = mask_with(&spec, &[&one[..], &all2[..], &all3[..]]);
        let up = encode_upload(&m, &params, &spec);
        assert_eq!(up.layers[0].encoding, Encoding::Coo);
        assert_eq!(up.layers[0].units, vec![7]);
        let m = mask_with(&spec, &[&all1[..], &all2[..], &all3[..]]);
        let up = encode_upload(&m, &params, &spec);
        assert_eq!(up.mix().dense, 3);
        // the chosen layout is never beaten by an alternative
        for lw in &up.layers {
            let vals = lw.values.len() * 4;
            let alt_bitmap = lw.out_dim.div_ceil(8) + vals;
            let alt_coo = lw.units.len() * 4 + vals;
            assert!(lw.body_bytes() <= alt_bitmap);
            assert!(lw.body_bytes() <= alt_coo);
        }
    }

    #[test]
    fn forced_modes_apply_to_every_layer() {
        let spec = ModelSpec::get("mlp", 1.0).unwrap();
        let mut rng = Rng::new(2);
        let params = spec.init_params(&mut rng);
        let m = ChannelMask::full(&spec);
        let up = encode_upload_with(&m, &params, &spec, CodecMode::Bitmap);
        assert_eq!(up.mix(), EncodingMix { dense: 0, bitmap: 3, coo: 0 });
        let up = encode_upload_with(&m, &params, &spec, CodecMode::Coo);
        assert_eq!(up.mix(), EncodingMix { dense: 0, bitmap: 0, coo: 3 });
        // round-trips still hold under forced layouts
        let back = WireUpload::from_bytes(&up.to_bytes()).unwrap();
        assert_eq!(back, up);
    }

    #[test]
    fn wire_len_matches_serialized_length_and_bound() {
        check("wire_len == to_bytes().len() <= upload_bytes", 12, |rng| {
            for name in ["mlp", "cnn1"] {
                let spec = ModelSpec::get(name, 0.5).unwrap();
                let before = spec.init_params(rng);
                let after = spec.init_params(rng);
                let d = rng.range_f64(0.0, 0.95);
                let m = select_mask(Policy::Random, &spec, &before, &after, None, d, rng);
                let up = encode_upload(&m, &after, &spec);
                let bytes = up.to_bytes();
                if bytes.len() != up.wire_len() {
                    return Err(format!("{} != wire_len {}", bytes.len(), up.wire_len()));
                }
                if up.wire_len() > m.upload_bytes(&spec) {
                    return Err(format!(
                        "wire_len {} > bound {}",
                        up.wire_len(),
                        m.upload_bytes(&spec)
                    ));
                }
                if up.payload_bytes() != m.payload_bytes(&spec) {
                    return Err("payload accounting mismatch".into());
                }
                // the in-memory size covers values + unit ids exactly
                let units: usize = up.layers.iter().map(|l| l.units.len()).sum();
                if up.mem_bytes() != up.payload_bytes() + units * 4 {
                    return Err("mem_bytes accounting mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_all_layouts_bitwise() {
        // Sparse (COO), half (bitmap) and full (dense) layers round-trip
        // through bytes with bitwise value equality, and the decoded
        // params equal p ⊙ m exactly.
        check("wire round-trip", 15, |rng| {
            for name in ["mlp", "cnn1"] {
                let spec = ModelSpec::get(name, 1.0).unwrap();
                let params = spec.init_params(rng);
                let per_layer: Vec<Vec<bool>> = spec
                    .layers
                    .iter()
                    .map(|layer| {
                        // style 0: dense; 1: bitmap-ish; 2: coo-ish
                        let style = rng.below(3);
                        (0..layer.out_dim)
                            .map(|k| match style {
                                0 => true,
                                1 => rng.bool(0.5),
                                _ => k == 0 || rng.bool(0.02),
                            })
                            .collect()
                    })
                    .collect();
                let m = ChannelMask { per_layer };
                let up = encode_upload(&m, &params, &spec);
                let back = WireUpload::from_bytes(&up.to_bytes()).unwrap();
                if back != up {
                    return Err("struct round-trip mismatch".into());
                }
                let (m2, masked) = decode_upload(&back, &spec).unwrap();
                if m2 != m {
                    return Err("mask round-trip mismatch".into());
                }
                let elems = m.to_elementwise(&spec);
                for i in 0..masked.len() {
                    for j in 0..masked[i].numel() {
                        let want = params[i].data()[j] * elems[i].data()[j];
                        let got = masked[i].data()[j];
                        if got.to_bits() != want.to_bits() {
                            return Err(format!("tensor {i} pos {j}: {got} != {want}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn corruption_anywhere_is_detected() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(3);
        let before = spec.init_params(&mut rng);
        let after = spec.init_params(&mut rng);
        let m = select_mask(Policy::Random, &spec, &before, &after, None, 0.5, &mut rng);
        let up = encode_upload(&m, &after, &spec);
        let bytes = up.to_bytes();
        assert!(WireUpload::from_bytes(&bytes).is_ok());
        // flip one bit in the header, the body and the checksum itself
        for &pos in &[0usize, 5, GLOBAL_HEADER_BYTES + 2, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            assert!(WireUpload::from_bytes(&bad).is_err(), "flip at byte {pos} undetected");
        }
        // truncation is rejected too
        assert!(WireUpload::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(WireUpload::from_bytes(&[]).is_err());
    }

    #[test]
    fn dropout_always_beats_the_dense_payload() {
        // Acceptance: for every d > 0 that actually drops a unit, the
        // chosen encodings are strictly smaller than the dense payload
        // (the full-mask wire form *and* the raw full-model bytes).
        let mut rng = Rng::new(4);
        for name in ["mlp", "cnn1"] {
            let spec = ModelSpec::get(name, 1.0).unwrap();
            let before = spec.init_params(&mut rng);
            let after = spec.init_params(&mut rng);
            let dense = encode_upload(&ChannelMask::full(&spec), &after, &spec);
            for policy in [Policy::Importance, Policy::Random, Policy::Max, Policy::Delta] {
                for d in [0.1, 0.3, 0.5, 0.7, 0.9] {
                    let m = select_mask(policy, &spec, &before, &after, None, d, &mut rng);
                    let up = encode_upload(&m, &after, &spec);
                    assert!(
                        up.wire_len() < dense.wire_len(),
                        "{name} {policy:?} d={d}: {} !< {}",
                        up.wire_len(),
                        dense.wire_len()
                    );
                    assert!(
                        up.wire_len() < spec.size_bytes(),
                        "{name} {policy:?} d={d}: {} !< model {}",
                        up.wire_len(),
                        spec.size_bytes()
                    );
                }
            }
        }
    }

    #[test]
    fn fnv_vector() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
