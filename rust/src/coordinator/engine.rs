//! The synchronous round engine (Algorithm 1) with scheme dispatch.
//!
//! One [`FedRun`] owns the fleet, the datasets, the runtime and the
//! global model; [`FedRun::run`] executes the configured number of rounds
//! and returns a [`RunResult`] with the full round/eval history.
//!
//! # Parallel round execution
//!
//! FedDD's round body is embarrassingly parallel across clients: local
//! training, Algorithm-2 mask selection and the Eq. 4 masked contribution
//! are all per-client. [`FedRun::step_round`] fans these phases out over
//! `cfg.workers` threads ([`ThreadPool::scoped_map`]) in two stages:
//!
//! 1. **per-client stage** — each participant (a disjoint `&mut
//!    ClientState`) trains, selects its upload mask with its own RNG
//!    stream, and expands the mask; outputs are collected in ascending
//!    client order.
//! 2. **sharded aggregation** — participants are chunked into at most
//!    [`AGG_SHARDS`] contiguous shards; each shard accumulates its
//!    clients (in order) into a private [`Aggregator`], and the shard
//!    partials are merged pairwise in fixed shard order
//!    ([`Aggregator::merge`]) before `finalize`.
//!
//! Because the shard partition depends only on the participant list —
//! never on the worker count or thread schedule — and every f32/f64
//! accumulation happens in a fixed order, a round is **bitwise identical
//! for every `workers` value** (asserted by `rust/tests/parallel_round.rs`
//! and benchmarked by `rust/benches/round.rs`).

use std::time::Instant;

use crate::aggregation::{sparse_merge, AggBackend, Aggregator};
use crate::baselines;
use crate::config::ExpConfig;
use crate::data::{FedDataset, Partition, PartitionKind, SynthSpec};
use crate::metrics::{EvalAccumulator, EvalRecord, RoundRecord, RunResult};
use crate::model::{coverage_rates, extract_params, ModelId, ModelSpec};
use crate::runtime::Runtime;
use crate::selection::{select_mask, ChannelMask, Policy};
use crate::simnet::{Fleet, RoundTiming, VirtualClock};
use crate::solver::{allocate_fast, AllocInput, AllocParams};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::client::ClientState;

/// Upper bound on aggregation shards per round. Fixed (worker-independent)
/// so the merge tree — and therefore the f32 summation order — is a pure
/// function of the participant list.
pub const AGG_SHARDS: usize = 8;

/// Per-participant output of the parallel stage (client order). Holds the
/// compact channel mask only; the model-sized elementwise expansion is
/// recomputed per client inside the aggregation stage so at most one
/// expansion per worker is alive at a time.
struct ClientRoundOutput {
    /// Client index.
    slot: usize,
    loss: f64,
    uploaded: usize,
    mask: ChannelMask,
}

/// Outcome of a single round (for tests / tracing).
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub duration: f64,
    pub mean_loss: f64,
    pub uploaded_bytes: usize,
    pub participants: usize,
}

pub struct FedRun {
    pub cfg: ExpConfig,
    pub runtime: Runtime,
    pub ds: FedDataset,
    pub clients: Vec<ClientState>,
    pub global_spec: ModelSpec,
    pub global_params: Vec<Tensor>,
    pub clock: VirtualClock,
    /// Coverage rates CR(k) per (layer, unit) of the global model.
    pub cr: Vec<Vec<f32>>,
    pub eval_artifact: String,
    rng: Rng,
    round: usize,
    /// Masks used in the current round (for the Eq. 5 sparse download).
    last_masks: Vec<Option<ChannelMask>>,
    policy: Policy,
    backend: AggBackend,
    /// Worker pool for the per-client round phases (`cfg.workers`).
    pool: ThreadPool,
}

impl FedRun {
    /// Build the full experiment from a config: dataset, partition, fleet,
    /// clients, global model, runtime.
    pub fn new(cfg: ExpConfig) -> anyhow::Result<FedRun> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        // Dataset (with optional §6.7 class imbalance).
        let mut synth = SynthSpec::by_name(&cfg.dataset)?;
        if !cfg.rare_classes.is_empty() {
            synth = synth.imbalanced(&cfg.rare_classes, cfg.rare_ratio);
        }
        let test_n = (cfg.test_n / 64).max(1) * 64; // eval batch alignment
        let mut data_rng = rng.split(1);
        let ds = synth.generate(cfg.train_per_client * cfg.n_clients, test_n, &mut data_rng);
        // Partition.
        let kind = PartitionKind::by_name(&cfg.partition)?;
        let mut part_rng = rng.split(2);
        let part = Partition::build(kind, &ds, cfg.n_clients, &mut part_rng);
        let dis_scores = part.distribution_scores(&ds);
        // Fleet.
        let mut fleet_rng = rng.split(3);
        let fleet = match cfg.fleet.as_str() {
            "testbed" => Fleet::testbed(&mut fleet_rng),
            _ => Fleet::simulated(cfg.n_clients, &mut fleet_rng),
        };
        anyhow::ensure!(
            fleet.len() >= cfg.n_clients,
            "fleet {} smaller than n_clients {}",
            fleet.len(),
            cfg.n_clients
        );
        // Runtime + global model.
        let runtime = Runtime::new(std::path::Path::new(&cfg.artifacts_dir))?;
        let global_name = if cfg.is_hetero() {
            format!("{}_1", cfg.model)
        } else {
            cfg.model.clone()
        };
        let global_spec = ModelSpec::get(&global_name, cfg.width_pct as f64 / 100.0)?;
        let mut init_rng = rng.split(4);
        let global_params = global_spec.init_params(&mut init_rng);
        // Clients: local model = global restricted to their sub-model.
        let mut clients = Vec::with_capacity(cfg.n_clients);
        for n in 0..cfg.n_clients {
            let name = cfg.client_model_name(n);
            let model_id = ModelId::new(&name, cfg.width_pct);
            let spec = ModelSpec::get(&name, cfg.width_pct as f64 / 100.0)?;
            let params = extract_params(&global_params, &spec);
            let train_artifact = format!("{}_train", model_id.tag());
            runtime.manifest().get(&train_artifact)?; // fail fast
            let scan_name = format!("{}_train_scan", model_id.tag());
            let scan_artifact = runtime
                .manifest()
                .get(&scan_name)
                .ok()
                .map(|m| (scan_name, m.steps));
            clients.push(ClientState {
                id: n,
                spec,
                params,
                data: part.client_indices[n].clone(),
                profile: fleet.profiles[n].clone(),
                dis_score: dis_scores[n],
                last_loss: 1.0,
                participations: 0,
                rng: rng.split(100 + n as u64),
                train_artifact,
                scan_artifact,
                model_id,
            });
        }
        let cr = {
            let specs: Vec<&ModelSpec> = clients.iter().map(|c| &c.spec).collect();
            coverage_rates(&specs, &global_spec)
        };
        let eval_artifact = format!(
            "{}_eval",
            ModelId::new(&global_name, cfg.width_pct).tag()
        );
        runtime.manifest().get(&eval_artifact)?;
        let policy = Policy::by_name(&cfg.selection)?;
        let backend = AggBackend::by_name(&cfg.agg_backend)?;
        let pool = ThreadPool::new(cfg.workers);
        let n = clients.len();
        Ok(FedRun {
            cfg,
            runtime,
            ds,
            clients,
            global_spec,
            global_params,
            clock: VirtualClock::new(),
            cr,
            eval_artifact,
            rng,
            round: 0,
            last_masks: vec![None; n],
            policy,
            backend,
            pool,
        })
    }

    /// Per-round byte budget A_server · Σ U_n.
    pub fn budget_bytes(&self) -> usize {
        let total: usize = self.clients.iter().map(|c| c.u_bytes()).sum();
        (self.cfg.a_server * total as f64).round() as usize
    }

    /// Evaluate the global model on the test set.
    pub fn evaluate(&self) -> anyhow::Result<(f64, f64, Vec<f64>)> {
        let eb = self.runtime.manifest().eval_batch;
        let dim = self.ds.sample_dim();
        let mut acc = EvalAccumulator::new(self.ds.num_classes);
        let mut x = vec![0.0f32; eb * dim];
        let mut y = vec![0i32; eb];
        let nb = self.ds.test_len() / eb;
        for b in 0..nb {
            for i in 0..eb {
                let s = b * eb + i;
                x[i * dim..(i + 1) * dim].copy_from_slice(self.ds.test_sample(s));
                y[i] = self.ds.test_y[s];
            }
            let (loss, correct, count) =
                self.runtime
                    .eval_batch(&self.eval_artifact, &self.global_params, &x, &y)?;
            acc.add_batch(loss, &correct, &count);
        }
        Ok((acc.accuracy(), acc.mean_loss(), acc.per_class_accuracy()))
    }

    /// Execute one synchronous round (Algorithm 1 body).
    pub fn step_round(&mut self) -> anyhow::Result<RoundOutcome> {
        self.round += 1;
        let t = self.round;
        let cfg = self.cfg.clone();
        let full_broadcast = t % cfg.h == 0 || cfg.scheme != "feddd";

        // ---- 0. participants + dropout rates ----
        let (participants, dropout): (Vec<usize>, Vec<f64>) = match cfg.scheme.as_str() {
            "feddd" => {
                let all: Vec<usize> = (0..self.clients.len()).collect();
                let d = if t == 1 {
                    vec![0.0; self.clients.len()] // Algorithm 1: D^1 = 0
                } else {
                    self.allocate_dropout()?
                };
                (all, d)
            }
            "fedavg" => {
                let all: Vec<usize> = (0..self.clients.len()).collect();
                let d = vec![0.0; self.clients.len()];
                (all, d)
            }
            "fedcs" => {
                let sel = baselines::fedcs_select(
                    &self.clients,
                    &cfg,
                    self.budget_bytes(),
                );
                let d = vec![0.0; self.clients.len()];
                (sel, d)
            }
            "oort" => {
                let sel = baselines::oort_select(
                    &self.clients,
                    &cfg,
                    self.budget_bytes(),
                    t,
                    &mut self.rng,
                );
                let d = vec![0.0; self.clients.len()];
                (sel, d)
            }
            s => anyhow::bail!("unknown scheme {s:?}"),
        };

        // ---- 1. download phase (server -> clients) ----
        // FedDD round t>1, t-1 not broadcast: clients already merged the
        // sparse download at the end of the previous round. Baselines and
        // broadcast rounds: participants sync to the full global model.
        for &n in &participants {
            if cfg.scheme != "feddd" {
                let c = &mut self.clients[n];
                c.params = extract_params(&self.global_params, &c.spec);
            }
        }

        // ---- 2. local training + selection (parallel per client) ----
        //
        // Every participant is an independent work item: it owns a
        // disjoint `&mut ClientState` (its params, RNG stream, loss
        // bookkeeping), trains against the shared thread-safe runtime,
        // then selects + expands its upload mask. `scoped_map` returns
        // outputs in input (= ascending client) order, so the f64 loss
        // sum below accumulates in the same order for every worker count.
        let is_feddd = cfg.scheme == "feddd";
        let hetero = cfg.is_hetero();
        let round_label = t as u64;
        let rt = &self.runtime;
        let ds = &self.ds;
        let cr = &self.cr;
        let policy = self.policy;
        let cfg_ref = &cfg;
        let dropout_ref = &dropout;
        let mut in_round = vec![false; self.clients.len()];
        for &n in &participants {
            in_round[n] = true;
        }
        let items: Vec<(usize, &mut ClientState)> = self
            .clients
            .iter_mut()
            .enumerate()
            .filter(|(n, _)| in_round[*n])
            .collect();
        let outs: Vec<ClientRoundOutput> = self.pool.scoped_try_map(
            items,
            |(n, c): (usize, &mut ClientState)| -> anyhow::Result<ClientRoundOutput> {
                // Per-item batch buffers: one ~batch×dim alloc per client
                // per round, dwarfed by the training matmuls. True
                // per-worker reuse needs a persistent worker pool
                // (scoped_map spawns per call) — noted follow-up.
                let mut scratch_x = Vec::new();
                let mut scratch_y = Vec::new();
                let before = if is_feddd { Some(c.params.clone()) } else { None };
                let loss = c.train_local(
                    rt,
                    ds,
                    cfg_ref.local_steps,
                    cfg_ref.batch,
                    cfg_ref.lr,
                    &mut scratch_x,
                    &mut scratch_y,
                )?;
                let mask = match &before {
                    Some(w_before) => {
                        let mut sel_rng = c.rng.split(round_label);
                        select_mask(
                            policy,
                            &c.spec,
                            w_before,
                            &c.params,
                            if hetero { Some(cr.as_slice()) } else { None },
                            dropout_ref[n],
                            &mut sel_rng,
                        )
                    }
                    None => ChannelMask::full(&c.spec),
                };
                let uploaded = mask.upload_bytes(&c.spec);
                Ok(ClientRoundOutput { slot: n, loss, uploaded, mask })
            },
        )?;
        let mut loss_sum = 0.0;
        let mut uploaded = 0usize;
        for o in &outs {
            loss_sum += o.loss;
            uploaded += o.uploaded;
        }
        let mean_loss = loss_sum / outs.len().max(1) as f64;

        // ---- 3. sharded aggregation (Eq. 4) ----
        //
        // Participants are chunked into ≤ AGG_SHARDS contiguous shards;
        // each shard accumulates its clients in order into a private
        // num/den pair, and shards merge pairwise in fixed order. The
        // partition depends only on the participant count, so the
        // summation order — hence the result, bit for bit — is the same
        // for every worker count.
        let agg = if outs.is_empty() {
            Aggregator::new(&self.global_spec, self.backend)
        } else {
            let global_spec = &self.global_spec;
            let backend = self.backend;
            let clients = &self.clients;
            let shard_len = outs.len().div_ceil(AGG_SHARDS.min(outs.len()));
            let shards: Vec<&[ClientRoundOutput]> = outs.chunks(shard_len).collect();
            let partials = self.pool.scoped_try_map(
                shards,
                |chunk: &[ClientRoundOutput]| -> anyhow::Result<Aggregator> {
                    let mut shard = Aggregator::new(global_spec, backend);
                    for o in chunk {
                        let c = &clients[o.slot];
                        let elems = o.mask.to_elementwise(&c.spec);
                        shard.add_client(&c.params, &elems, c.m_n() as f32, Some(rt))?;
                    }
                    Ok(shard)
                },
            )?;
            Aggregator::merge(partials)?
        };
        self.global_params = agg.finalize(&self.global_params, Some(rt))?;
        for o in outs {
            self.last_masks[o.slot] = Some(o.mask);
        }

        // ---- 4. download merge (Eq. 5 / Eq. 6) ----
        if cfg.scheme == "feddd" {
            for &n in &participants {
                let c = &mut self.clients[n];
                if full_broadcast {
                    c.params = extract_params(&self.global_params, &c.spec);
                } else if let Some(mask) = &self.last_masks[n] {
                    let slice = extract_params(&self.global_params, &c.spec);
                    let elems = mask.to_elementwise(&c.spec);
                    sparse_merge(&mut c.params, &slice, &elems);
                }
            }
        }

        // ---- 5. virtual-time accounting (Eq. 7–12) ----
        let timings: Vec<RoundTiming> = participants
            .iter()
            .map(|&n| {
                let c = &self.clients[n];
                let up_bytes = self.last_masks[n]
                    .as_ref()
                    .map(|m| m.upload_bytes(&c.spec))
                    .unwrap_or_else(|| c.u_bytes()) as f64;
                let down_bytes = if full_broadcast {
                    c.u_bytes() as f64
                } else {
                    up_bytes // sparse download W^t ⊙ M_n^t
                };
                RoundTiming {
                    t_down: c.profile.t_down(down_bytes),
                    t_cmp: c
                        .profile
                        .t_cmp(c.samples_per_round(cfg.local_steps, cfg.batch)),
                    t_up: c.profile.t_up(up_bytes),
                }
            })
            .collect();
        let duration = self.clock.advance_round(&timings);

        Ok(RoundOutcome {
            duration,
            mean_loss,
            uploaded_bytes: uploaded,
            participants: participants.len(),
        })
    }

    /// Dropout rates for this round: the Eq. 16/17 optimum, or the
    /// uniform ablation (D_n = 1 − A_server for everyone).
    fn allocate_dropout(&self) -> anyhow::Result<Vec<f64>> {
        if self.cfg.alloc == "uniform" {
            let d = (1.0 - self.cfg.a_server).min(self.cfg.d_max);
            return Ok(vec![d; self.clients.len()]);
        }
        let m_total: f64 = self.clients.iter().map(|c| c.m_n() as f64).sum();
        let u_global = self.global_spec.size_bytes() as f64;
        let inputs: Vec<AllocInput> = self
            .clients
            .iter()
            .map(|c| AllocInput {
                u_bytes: c.u_bytes() as f64,
                t_cmp: c
                    .profile
                    .t_cmp(c.samples_per_round(self.cfg.local_steps, self.cfg.batch)),
                sec_per_byte: c.profile.sec_per_byte(),
                // re_n = (m_n/m)(Σ_c min(C·dis,1))(U_n/U)·loss_n  (Eq. 13)
                re: (c.m_n() as f64 / m_total)
                    * c.dis_score
                    * (c.u_bytes() as f64 / u_global)
                    * c.last_loss,
            })
            .collect();
        let params = AllocParams {
            d_max: self.cfg.d_max,
            a_server: self.cfg.a_server,
            delta: self.cfg.delta,
        };
        Ok(allocate_fast(&inputs, &params)?.d)
    }

    /// Run the full experiment.
    pub fn run(&mut self) -> anyhow::Result<RunResult> {
        let label = format!(
            "{}-{}-{}-{}",
            self.cfg.scheme, self.cfg.dataset, self.cfg.partition, self.cfg.model
        );
        let mut result = RunResult::new(&self.cfg.scheme, &label);
        let wall0 = Instant::now();
        let budget = self.budget_bytes();
        for t in 1..=self.cfg.rounds {
            let out = self.step_round()?;
            let mean_dropout = if self.cfg.scheme == "feddd" && t > 1 {
                1.0 - out.uploaded_bytes as f64
                    / self.clients.iter().map(|c| c.u_bytes()).sum::<usize>() as f64
            } else {
                0.0
            };
            result.rounds.push(RoundRecord {
                round: t,
                v_time: self.clock.now(),
                duration: out.duration,
                train_loss: out.mean_loss,
                uploaded_bytes: out.uploaded_bytes,
                budget_bytes: budget,
                participants: out.participants,
                mean_dropout,
                full_broadcast: t % self.cfg.h == 0 || self.cfg.scheme != "feddd",
            });
            if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
                let (acc, loss, pca) = self.evaluate()?;
                log::info!(
                    "[{}] round {t:3}/{} vt={:8.1}s loss={:.3} acc={:.3} up={}KB x{}",
                    label,
                    self.cfg.rounds,
                    self.clock.now(),
                    out.mean_loss,
                    acc,
                    out.uploaded_bytes / 1024,
                    out.participants,
                );
                result.evals.push(EvalRecord {
                    round: t,
                    v_time: self.clock.now(),
                    accuracy: acc,
                    loss,
                    per_class_accuracy: pca,
                });
            }
        }
        result.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(result)
    }
}

/// Convenience: build + run in one call.
pub fn run_experiment(cfg: ExpConfig) -> anyhow::Result<RunResult> {
    FedRun::new(cfg)?.run()
}

/// Re-exported server type name used in docs/prelude.
pub type FedDdServer = FedRun;
