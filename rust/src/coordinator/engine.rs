//! The round engine: synchronous Algorithm 1 plus the semi-asynchronous
//! event-driven variant, with scheme dispatch.
//!
//! One [`FedRun`] owns the fleet, the datasets, the runtime and the
//! global model; [`FedRun::run`] executes the configured number of rounds
//! and returns a [`RunResult`] with the full round/eval history.
//!
//! # Client-state virtualization (DESIGN.md §Fleet-Virtualization)
//!
//! FedDD has *no partial participation* — every client carries state for
//! the whole run — so the fleet's memory footprint, not the round math,
//! is what caps simulated scale. The engine therefore never stores a
//! dense model per client. Each [`ClientState`] holds a
//! [`ClientParams`]: an `Arc` reference into the [`SnapshotRing`] of
//! end-of-round global snapshots plus, when diverged, the sparse
//! residual of the channels its Eq. 5 downloads never overwrote. Dense
//! parameters exist only inside the per-client worker stage
//! (`materialize` → train → encode → drop), so peak dense memory is
//! O(micro-batch · model), not O(clients · model), and the persistent
//! fleet state is O(Σ_n residual_n + live snapshots) — zero per client
//! right after a full broadcast ([`FedRun::client_state_bytes`]).
//!
//! Two more planes are virtualized alongside the clients: the **data
//! plane** (`cfg.data_mode = "lazy"` regenerates training samples from
//! the seed on demand and the partitions are shared strided /
//! class-strided views, [`FedRun::data_state_bytes`]) and the **snapshot
//! ring** (`cfg.snapshot_ring_cap` bounds the live end-of-round
//! snapshots by evicting the oldest round's dependents,
//! [`FedRun::enforce_ring_cap`]). The simulation runtime's own footprint
//! is reported as [`FedRun::sim_state_bytes`].
//!
//! # Parallel round execution
//!
//! FedDD's round body is embarrassingly parallel across clients: local
//! training, Algorithm-2 mask selection and the Eq. 4 masked contribution
//! are all per-client. The engine fans these phases out over
//! `cfg.workers` threads ([`ThreadPool::scoped_map`]):
//!
//! 1. **per-client stage** — each participant (a disjoint `&mut
//!    ClientState`) materializes its dense model, trains, selects its
//!    upload mask with its own RNG stream, encodes the masked values
//!    into a `WireUpload` (the bytes the uplink is charged for) and
//!    gathers its post-round residual; outputs are collected in
//!    ascending client order, micro-batch by micro-batch.
//! 2. **sharded aggregation** — participants are chunked into at most
//!    [`AGG_SHARDS`] contiguous shards; each shard folds its clients'
//!    wire uploads (in order) into a private [`Aggregator`] via the
//!    zero-copy `absorb_wire` — a micro-batch's uploads fold as soon as
//!    they are produced, so they never accumulate fleet-wide — and the
//!    shard partials are merged pairwise in fixed shard order
//!    ([`Aggregator::merge`]) before `finalize`.
//!
//! Because the shard partition depends only on the participant list —
//! never on the worker count, the micro-batch size or the thread
//! schedule — and every f32/f64 accumulation happens in a fixed order, a
//! round is **bitwise identical for every `workers` value** (asserted by
//! `rust/tests/parallel_round.rs` and `rust/tests/fleet_virtualization.rs`,
//! benchmarked by `rust/benches/round.rs` and `rust/benches/fleet.rs`).
//!
//! # Round modes (`cfg.round_mode`)
//!
//! * **`sync`** (default) — Algorithm 1's barrier: the server waits for
//!   every participant, so the round clock is `max_n(t_n)` and the
//!   straggler sets the pace. This path is bitwise-identical to the
//!   classic engine for every worker count.
//! * **`semi_async`** — the scheduler, not the client loop, owns time
//!   (DESIGN.md §7). Every dispatched upload becomes an arrival event in
//!   a min-heap ([`EventQueue`]); the server closes a round when an
//!   arrival quorum `ceil(quorum · in_flight)` is reached or the round
//!   deadline `deadline_s` fires, whichever is earlier. Clients that
//!   miss the close are **not discarded**: they stay in flight on their
//!   own clocks ([`ClientClocks`]) and their uploads are folded into a
//!   later round's Eq. 4 with the staleness discount
//!   `m_n ← m_n · (1+s_n)^{-β}` ([`staleness_weight`]). With
//!   `quorum = 1` and no deadline the fold degenerates to the
//!   synchronous aggregation (asserted by `rust/tests/semi_async.rs`).
//!
//! # Transports (`coordinator::ingest`)
//!
//! Both drivers consume uploads through the run's [`UploadSource`]: the
//! staging phase above lives behind the [`LocalTransport`] default, and
//! serve mode swaps in a socket-backed source
//! (`transport::ServeCoordinator`) without the drivers changing a line.
//! The drivers keep everything transport-independent — scheduling,
//! quorum/deadline close, the Eq. 4 folds, snapshot rebasing — and the
//! ingest contract (envelopes delivered in ascending client order) keeps
//! every transport bitwise-identical to the in-process path.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::aggregation::{staleness_weight, AggBackend, Aggregator};
use crate::baselines::{self, RoundCtx, RoundPlan, Scheme};
use crate::codec::{recycle_wire_upload, CodecMode, EncodingMix, PlaneMix, PlaneMode, WireUpload};
use crate::config::ExpConfig;
use crate::data::{FedDataset, Partition, PartitionKind, SynthSpec};
use crate::metrics::{EvalAccumulator, EvalRecord, RoundRecord, RunResult};
use crate::model::{coverage_rates, ModelId, ModelSpec};
use crate::runtime::Runtime;
use crate::selection::Policy;
use crate::simnet::{
    churn_drops, AvailabilityTrace, ClientClocks, DeviceProfile, EventQueue, Fleet, VirtualClock,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::client::{ClientState, PendingUpdate};
use super::ingest::{
    drive_subset, AgentPending, CloseNote, DispatchSink, LocalTransport, RoundCall, SyncFold,
    UploadSink, UploadSource,
};
use super::scratch;
use super::state::{ClientParams, SnapshotRing};

/// Upper bound on aggregation shards per round. Fixed (worker-independent)
/// so the merge tree — and therefore the f32 summation order — is a pure
/// function of the participant list.
pub const AGG_SHARDS: usize = 8;

/// Outcome of a single round (for tests / tracing).
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub duration: f64,
    pub mean_loss: f64,
    /// Mean dropout this round: realized byte savings in sync mode,
    /// mean allocated rate over dispatched clients in semi-async mode
    /// (0 for baselines and round 1).
    pub mean_dropout: f64,
    /// Whether this round was a full-model broadcast round.
    pub full_broadcast: bool,
    pub uploaded_bytes: usize,
    /// Realized encoded upload bytes (headers + indices + values) folded
    /// this round — what the uplinks were actually charged for.
    pub wire_bytes: usize,
    /// Per-layout layer counts over the folded uploads.
    pub encodings: EncodingMix,
    /// Per-value-plane layer counts and serialized value bytes over the
    /// folded uploads (`cfg.value_plane`; all-f32 by default).
    pub planes: PlaneMix,
    /// Clients whose uploads were folded into this round's aggregation.
    pub participants: usize,
    /// Uploads still in flight when the round closed (semi-async; 0 in
    /// sync mode, where the barrier waits for everyone).
    pub stragglers: usize,
    /// Mean staleness (in rounds) of the folded uploads (0 in sync mode).
    pub mean_staleness: f64,
    /// Uploads that churned at arrival time this round (`cfg.trace =
    /// "churn"` under semi-async): the connection dropped, the upload was
    /// discarded unfolded and the client reconnects idle. Always 0 in
    /// sync mode and for every other trace.
    pub churned: usize,
    /// Fleet state footprint at the end of the round: per-client
    /// residual bytes + live shared snapshots
    /// ([`FedRun::client_state_bytes`]).
    pub client_state_bytes: usize,
    /// Simulation-runtime footprint at the end of the round: device
    /// profiles + per-client clocks + the arrival heap
    /// ([`FedRun::sim_state_bytes`]).
    pub sim_state_bytes: usize,
    /// Dataset + partition + shard-index footprint — constant across
    /// rounds ([`FedRun::data_state_bytes`]).
    pub data_state_bytes: usize,
}

pub struct FedRun {
    pub cfg: ExpConfig,
    pub runtime: Runtime,
    pub ds: FedDataset,
    pub clients: Vec<ClientState>,
    pub global_spec: ModelSpec,
    pub global_params: Vec<Tensor>,
    pub clock: VirtualClock,
    /// Coverage rates CR(k) per (layer, unit) of the global model.
    pub cr: Vec<Vec<f32>>,
    pub eval_artifact: String,
    rng: Rng,
    round: usize,
    policy: Policy,
    /// The scheme (`cfg.scheme`) as a strategy object: participant
    /// selection, dropout-rate allocation and the dispatch-mask policy
    /// all come from [`Scheme::plan_round`], and the drivers consult the
    /// trait's capability hooks (`stateful`, `reports_round_dropout`,
    /// `needs_observation`) instead of string-matching scheme names.
    scheme: Box<dyn Scheme>,
    backend: AggBackend,
    /// Wire-codec layout policy (`cfg.codec`): auto-pick or forced.
    codec: CodecMode,
    /// Upload value-plane policy (`cfg.value_plane`): f32 (default),
    /// forced f16/i8, or per-layer auto under `plane_error`.
    plane: PlaneMode,
    /// Relative error bound for `PlaneMode::Auto` (`cfg.plane_error`).
    plane_error: f64,
    /// Persistent worker pool for the per-client round phases
    /// (`cfg.workers`): threads are spawned once here and live for the
    /// whole run, so per-worker scratch arenas (`coordinator::scratch`,
    /// the native executor's buffer pool) are reused across micro-batches
    /// and rounds. Total OS thread spawns per run are O(workers), never
    /// O(micro-batches) — asserted by `rust/tests/pool_determinism.rs`
    /// and the round/fleet bench gates.
    pool: ThreadPool,
    /// Published end-of-round snapshots (weak accounting; lifetime is
    /// owned by the client states' `Arc`s).
    snapshots: SnapshotRing,
    /// Pending arrival events (semi-async mode; empty in sync mode).
    events: EventQueue,
    /// Per-client busy-until clocks (semi-async mode).
    client_clocks: ClientClocks,
    /// Dispatched-but-unfolded uploads keyed by client (semi-async mode).
    /// A `BTreeMap` keeps iteration deterministic while costing O(in
    /// flight), not O(fleet): with nothing outstanding the map is empty,
    /// where a `Vec<Option<_>>` would hold a fleet-sized slab of `None`s.
    pending: BTreeMap<usize, PendingUpdate>,
    /// Dataset + partition + shard-index bytes, computed once at build
    /// (all three are immutable for the life of the run).
    data_state_bytes: usize,
    /// Cumulative clients evicted by [`Self::enforce_ring_cap`].
    snapshot_evictions: usize,
    /// Client-availability trace (`cfg.trace`, DESIGN.md
    /// §Scenario-Matrix): a pure function of (client, virtual time) that
    /// gates dispatch in both round modes; `Churn` additionally drops
    /// in-flight uploads at arrival time in semi-async mode.
    trace: AvailabilityTrace,
    /// Cumulative uploads dropped by churn at arrival time.
    churned_total: usize,
    /// Where round uploads come from: the in-process [`LocalTransport`]
    /// by default, or a socket-backed source ([`Self::with_transport`]).
    transport: Box<dyn UploadSource>,
    /// Close notifications from the most recent round — every slot whose
    /// upload left flight (folded or churned), ascending. Handed to the
    /// transport with the next round's dispatch so remote agents rebase
    /// their replicas; the in-process transport ignores them (the driver
    /// already rebased the shared `ClientState`s directly).
    last_close: Vec<CloseNote>,
}

impl FedRun {
    /// Build the full experiment from a config: dataset, partition, fleet,
    /// clients, global model, runtime. Uploads stage in-process
    /// ([`LocalTransport`]).
    pub fn new(cfg: ExpConfig) -> anyhow::Result<FedRun> {
        Self::with_transport(cfg, Box::new(LocalTransport))
    }

    /// [`Self::new`] with an explicit upload transport — serve mode
    /// injects its socket-backed `transport::ServeCoordinator` here. The
    /// run itself is built identically either way (same RNG splits, same
    /// fleet, same initial global), which is what lets a remote agent
    /// hold a bitwise replica of the server's fleet from the same config.
    pub fn with_transport(
        cfg: ExpConfig,
        transport: Box<dyn UploadSource>,
    ) -> anyhow::Result<FedRun> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        // Dataset (with optional §6.7 class imbalance).
        let mut synth = SynthSpec::by_name(&cfg.dataset)?;
        if !cfg.rare_classes.is_empty() {
            synth = synth.imbalanced(&cfg.rare_classes, cfg.rare_ratio);
        }
        let test_n = (cfg.test_n / 64).max(1) * 64; // eval batch alignment
        let mut data_rng = rng.split(1);
        // `data_mode == "lazy"` (the default) keeps the training store
        // virtual: samples regenerate from the seed on demand,
        // byte-identical to the eager tensor (`data::synth`), so the
        // resident dataset is O(prototypes), not O(samples · dim).
        let ds = synth.generate_mode(
            cfg.train_per_client * cfg.n_clients,
            test_n,
            &mut data_rng,
            cfg.data_mode == "lazy",
        );
        // Partition (every deal stays lazy: the IID share is one shared
        // permutation with per-client strided views, the non-IID deals
        // are class-strided segment tables — no per-client index heap at
        // scale).
        let kind = PartitionKind::by_name(&cfg.partition)?;
        let mut part_rng = rng.split(2);
        let part = Partition::build(kind, &ds, cfg.n_clients, &mut part_rng);
        let dis_scores = part.distribution_scores(&ds);
        // Fleet.
        let mut fleet_rng = rng.split(3);
        let fleet = match cfg.fleet.as_str() {
            "testbed" => Fleet::testbed(&mut fleet_rng),
            _ => Fleet::simulated(cfg.n_clients, &mut fleet_rng),
        };
        anyhow::ensure!(
            fleet.len() >= cfg.n_clients,
            "fleet {} smaller than n_clients {}",
            fleet.len(),
            cfg.n_clients
        );
        // Runtime + global model.
        let runtime = Runtime::new(std::path::Path::new(&cfg.artifacts_dir))?;
        let global_name = if cfg.is_hetero() {
            format!("{}_1", cfg.model)
        } else {
            cfg.model.clone()
        };
        let global_spec = ModelSpec::get(&global_name, cfg.width_pct as f64 / 100.0)?;
        let mut init_rng = rng.split(4);
        let global_params = global_spec.init_params(&mut init_rng);
        // Round-0 snapshot: every client starts `Synced` against the
        // initial global model — zero per-client state.
        let mut snapshots = SnapshotRing::new();
        let snap0 = snapshots.publish(0, &global_params);
        // Clients: local model = global restricted to their sub-model.
        let mut clients = Vec::with_capacity(cfg.n_clients);
        for n in 0..cfg.n_clients {
            let name = cfg.client_model_name(n);
            let model_id = ModelId::new(&name, cfg.width_pct);
            let spec = ModelSpec::get(&name, cfg.width_pct as f64 / 100.0)?;
            let train_artifact = format!("{}_train", model_id.tag());
            runtime.manifest().get(&train_artifact)?; // fail fast
            let scan_name = format!("{}_train_scan", model_id.tag());
            let scan_artifact = runtime
                .manifest()
                .get(&scan_name)
                .ok()
                .map(|m| (scan_name, m.steps));
            clients.push(ClientState {
                id: n,
                spec,
                params: ClientParams::synced(snap0.clone()),
                data: part.shard(n),
                profile: fleet.profiles[n].clone(),
                dis_score: dis_scores[n],
                last_loss: 1.0,
                participations: 0,
                rng: rng.split(100 + n as u64),
                train_artifact,
                scan_artifact,
                model_id,
            });
        }
        // Data-plane footprint (constant for the life of the run): the
        // dataset store, the shared partition representation, and any
        // per-client shard indices that are actually owned heap (zero for
        // the lazy strided/class-strided deals).
        let data_state_bytes = ds.mem_bytes()
            + part.mem_bytes()
            + clients.iter().map(|c| c.data.owned_bytes()).sum::<usize>();
        let cr = {
            let specs: Vec<&ModelSpec> = clients.iter().map(|c| &c.spec).collect();
            coverage_rates(&specs, &global_spec)
        };
        let eval_artifact = format!("{}_eval", ModelId::new(&global_name, cfg.width_pct).tag());
        runtime.manifest().get(&eval_artifact)?;
        let policy = Policy::by_name(&cfg.selection)?;
        let backend = AggBackend::by_name(&cfg.agg_backend)?;
        let codec = CodecMode::by_name(&cfg.codec)?;
        let plane = PlaneMode::by_name(&cfg.value_plane)?;
        let plane_error = cfg.plane_error;
        let trace = AvailabilityTrace::by_name(&cfg.trace)?;
        // The scheme strategy object (`baselines::Scheme`). Construction
        // draws no RNG — the split sequence above (data, partition,
        // fleet, init, per-client) is part of the replica contract.
        let scheme = baselines::scheme_by_name(&cfg.scheme)?;
        let pool = ThreadPool::new(cfg.workers);
        let n = clients.len();
        Ok(FedRun {
            cfg,
            runtime,
            ds,
            clients,
            global_spec,
            global_params,
            clock: VirtualClock::new(),
            cr,
            eval_artifact,
            rng,
            round: 0,
            policy,
            scheme,
            backend,
            codec,
            plane,
            plane_error,
            pool,
            snapshots,
            events: EventQueue::new(),
            client_clocks: ClientClocks::new(n),
            pending: BTreeMap::new(),
            data_state_bytes,
            snapshot_evictions: 0,
            trace,
            churned_total: 0,
            transport,
            last_close: Vec::new(),
        })
    }

    /// Tear down the run's upload transport: serve mode sends DONE to
    /// every agent and joins its reader threads; the in-process default
    /// is a no-op. Call after [`Self::run`] so agents exit cleanly.
    pub fn shutdown_transport(&mut self) -> anyhow::Result<()> {
        self.transport.shutdown()
    }

    /// Resolved worker count of this run's persistent pool (`cfg.workers`
    /// with `0` resolved to the host's available parallelism).
    pub fn pool_workers(&self) -> usize {
        self.pool.workers()
    }

    /// OS threads this run's pool owns (0 when sequential). The pool is
    /// the run's **entire** spawn budget: stepping rounds spawns nothing
    /// further, however many micro-batches execute — the invariant the
    /// round/fleet benches gate via
    /// `util::threadpool::total_threads_spawned`.
    pub fn pool_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Test support: overwrite every per-worker scratch arena — the
    /// coordinator's materialization/batch buffers and the native
    /// executor's buffer pool, on the caller thread and on every pool
    /// worker — with sentinel values (NaN / `i32::MIN`), keeping lengths
    /// and shapes. Round outputs must be bitwise identical with or
    /// without poisoning: the executable proof that no job ever reads
    /// stale scratch contents (`rust/tests/pool_determinism.rs`).
    pub fn poison_worker_scratch(&self) {
        self.pool.broadcast(|| {
            scratch::poison_thread_scratch();
            crate::runtime::poison_native_scratch();
        });
    }

    /// Per-round byte budget A_server · Σ U_n.
    pub fn budget_bytes(&self) -> usize {
        let total: usize = self.clients.iter().map(|c| c.u_bytes()).sum();
        (self.cfg.a_server * total as f64).round() as usize
    }

    /// Fleet state footprint right now: Σ per-client residual bytes,
    /// plus the live shared snapshots (each counted once, however many
    /// clients reference it), plus any in-flight `PendingUpdate`s
    /// (semi-async: buffered encoded uploads + their residuals; always 0
    /// in sync mode, where nothing survives the round). Right after a
    /// full broadcast with nothing in flight this is exactly the
    /// snapshot bytes; between broadcasts it grows by each client's
    /// complement-of-mask residual — always strictly below the dense
    /// fleet's `clients · model` whenever any dropout was allocated.
    pub fn client_state_bytes(&self) -> usize {
        self.client_residual_bytes() + self.snapshot_bytes() + self.pending_bytes()
    }

    /// The per-client (residual-only) part of [`Self::client_state_bytes`].
    pub fn client_residual_bytes(&self) -> usize {
        self.clients.iter().map(|c| c.params.state_bytes()).sum()
    }

    /// Bytes buffered for dispatched-but-unfolded uploads (semi-async
    /// in-flight state): the decoded upload's in-memory size
    /// (`WireUpload::mem_bytes`, not the smaller serialized `wire_len`)
    /// plus the residual each upload carries for its arrival-time merge.
    pub fn pending_bytes(&self) -> usize {
        self.pending
            .values()
            .map(|pu| {
                pu.wire.mem_bytes() + pu.residual.as_ref().map_or(0, |r| r.heap_bytes())
            })
            .sum()
    }

    /// Bytes of the snapshots still referenced by some client.
    pub fn snapshot_bytes(&self) -> usize {
        self.snapshots.live_bytes()
    }

    /// Rounds whose snapshot is still alive (ring observability).
    pub fn live_snapshot_rounds(&self) -> Vec<usize> {
        self.snapshots.live_rounds()
    }

    /// Simulation-runtime footprint: the per-client device profiles (held
    /// inline in the client states), the per-client busy-until clocks,
    /// and the in-flight arrival heap. O(fleet) by design — each term is
    /// a handful of scalars per client — and reported per round so the
    /// fleet benches can gate it against the dense `clients · model`
    /// yardstick alongside [`Self::client_state_bytes`].
    pub fn sim_state_bytes(&self) -> usize {
        self.clients.len() * std::mem::size_of::<DeviceProfile>()
            + self.client_clocks.mem_bytes()
            + self.events.mem_bytes()
    }

    /// Dataset + partition + owned shard-index bytes (constant across
    /// rounds; see `FedRun::new`).
    pub fn data_state_bytes(&self) -> usize {
        self.data_state_bytes
    }

    /// Cumulative clients evicted by the snapshot-ring cap.
    pub fn snapshot_evictions(&self) -> usize {
        self.snapshot_evictions
    }

    /// Cumulative uploads dropped by arrival-time churn (`cfg.trace =
    /// "churn"`; always 0 otherwise).
    pub fn churned_uploads(&self) -> usize {
        self.churned_total
    }

    /// Clients of `participants` the coordinator can reach at virtual
    /// time `now` under `cfg.trace`. The common `trace = "none"` path
    /// returns the list untouched.
    fn available_participants(&self, participants: Vec<usize>, now: f64) -> Vec<usize> {
        if self.trace == AvailabilityTrace::None {
            return participants;
        }
        let n_clients = self.clients.len();
        participants
            .into_iter()
            .filter(|&n| {
                self.trace.is_available(n, n_clients, now, self.cfg.trace_period_s)
            })
            .collect()
    }

    /// Enforce `cfg.snapshot_ring_cap` on the live snapshot ring
    /// (DESIGN.md §Fleet-Virtualization). While more than `cap` snapshot
    /// rounds are alive, every client still based on the oldest live
    /// round is marked [`ClientParams::Evicted`], dropping its reference
    /// so the snapshot's memory is freed. An in-flight client never
    /// reads its pinned base again (its arrival rebases onto the
    /// close-time snapshot), so for it eviction is bitwise neutral; an
    /// idle client is force-re-synced at its next dispatch with a
    /// full-model downlink charge. `cap == 0` disables the gate.
    fn enforce_ring_cap(&mut self) {
        let cap = self.cfg.snapshot_ring_cap;
        if cap == 0 {
            return;
        }
        while self.snapshots.live_count() > cap {
            let Some(oldest) = self.snapshots.oldest_live_round() else { break };
            let mut evicted = 0usize;
            for c in &mut self.clients {
                if c.params.base_round() == Some(oldest) {
                    c.params = ClientParams::Evicted;
                    evicted += 1;
                }
            }
            self.snapshot_evictions += evicted;
            if evicted == 0 {
                // Only client states pin snapshots, so this is
                // unreachable; the break guards against an accounting bug
                // turning into a spin.
                break;
            }
        }
    }

    /// Evaluate the global model on the test set.
    pub fn evaluate(&self) -> anyhow::Result<(f64, f64, Vec<f64>)> {
        let eb = self.runtime.manifest().eval_batch;
        let dim = self.ds.sample_dim();
        let mut acc = EvalAccumulator::new(self.ds.num_classes);
        let mut x = vec![0.0f32; eb * dim];
        let mut y = vec![0i32; eb];
        let nb = self.ds.test_len() / eb;
        for b in 0..nb {
            for i in 0..eb {
                let s = b * eb + i;
                x[i * dim..(i + 1) * dim].copy_from_slice(self.ds.test_sample(s));
                y[i] = self.ds.test_y[s];
            }
            let (loss, correct, count) =
                self.runtime
                    .eval_batch(&self.eval_artifact, &self.global_params, &x, &y)?;
            acc.add_batch(loss, &correct, &count);
        }
        Ok((acc.accuracy(), acc.mean_loss(), acc.per_class_accuracy()))
    }

    /// Execute one round under the configured `round_mode`.
    pub fn step_round(&mut self) -> anyhow::Result<RoundOutcome> {
        match self.cfg.round_mode.as_str() {
            "semi_async" => self.step_round_semi_async(),
            _ => self.step_round_sync(),
        }
    }

    /// Step 0 of a round: the scheme's [`RoundPlan`] for round `t` —
    /// participants, per-client dropout rates and the dispatch-mask
    /// policy. The context hands the scheme exactly the inputs the old
    /// string-matched arms consumed (fleet, budget, engine RNG), so each
    /// scheme's RNG draws land on the same stream as before.
    fn plan_round(&mut self, t: usize) -> anyhow::Result<RoundPlan> {
        let budget_bytes = self.budget_bytes();
        let mut ctx = RoundCtx {
            cfg: &self.cfg,
            clients: &self.clients,
            global_spec: &self.global_spec,
            budget_bytes,
            rng: &mut self.rng,
        };
        self.scheme.plan_round(t, &mut ctx)
    }

    /// Full-model broadcast round? Round 1 always broadcasts — no client
    /// has ever received the global model, so there is nothing for a
    /// mask-sparse download to merge into — then every h-th round for
    /// the stateful schemes (FedDD, fed_dropout, afd); the stateless
    /// selection baselines always download the full model.
    fn is_full_broadcast(&self, t: usize) -> bool {
        t <= 1 || t % self.cfg.h == 0 || !self.scheme.stateful()
    }

    /// Shard length of the Eq. 4 fold partition over `n_items` ordered
    /// items: ≤ [`AGG_SHARDS`] contiguous chunks. The single source of
    /// truth for both round modes — the sync fold and the semi-async
    /// fresh-arrival fold must chunk identically or the cross-mode
    /// bitwise-equivalence claim breaks.
    pub(crate) fn shard_len(n_items: usize) -> usize {
        debug_assert!(n_items > 0, "shard partition of zero items");
        n_items.div_ceil(AGG_SHARDS.min(n_items))
    }

    /// Sharded Eq. 4 accumulation over `(client, wire upload)` pairs in
    /// the given order.
    ///
    /// The pairs are chunked into ≤ [`AGG_SHARDS`] contiguous shards; each
    /// shard folds its clients in order into a private num/den pair via
    /// the zero-copy `absorb_wire` — no elementwise mask expansion, no
    /// dense contribution tensors — and shards merge pairwise in fixed
    /// order. The partition depends only on the input list — never on the
    /// worker count — so the summation order (hence the result, bit for
    /// bit) is the same for every `workers` value.
    fn shard_aggregate(&self, items: &[(usize, &WireUpload)]) -> anyhow::Result<Aggregator> {
        if items.is_empty() {
            return Ok(Aggregator::new(&self.global_spec, self.backend));
        }
        let global_spec = &self.global_spec;
        let backend = self.backend;
        let clients = &self.clients;
        let shard_len = Self::shard_len(items.len());
        let shards: Vec<&[(usize, &WireUpload)]> = items.chunks(shard_len).collect();
        let partials = self.pool.scoped_try_map(
            shards,
            |chunk: &[(usize, &WireUpload)]| -> anyhow::Result<Aggregator> {
                let mut shard = Aggregator::new(global_spec, backend);
                for &(slot, wire) in chunk {
                    shard.absorb_wire(wire, clients[slot].m_n() as f32)?;
                }
                Ok(shard)
            },
        )?;
        Aggregator::merge(partials)
    }

    /// Execute one synchronous round (Algorithm 1 body).
    ///
    /// The shard partition over the participant list is the same pure
    /// function as ever (≤ [`AGG_SHARDS`] contiguous chunks, folded in
    /// ascending client order, merged pairwise), and the staging +
    /// folding now flows through the run's transport: the driver hands
    /// a [`RoundCall`] to its [`UploadSource`] with a [`SyncFold`] sink,
    /// and every envelope is absorbed into its position's shard
    /// aggregator the moment it is delivered. For [`LocalTransport`]
    /// that is exactly the old micro-batch streaming loop — peak
    /// transient memory stays O(micro · model) and the f32/f64 summation
    /// order (hence the result, bit for bit) is unchanged.
    fn step_round_sync(&mut self) -> anyhow::Result<RoundOutcome> {
        self.round += 1;
        let t = self.round;
        let cfg = self.cfg.clone();
        let full_broadcast = self.is_full_broadcast(t);

        // ---- 0. participants + dropout rates ----
        // Selection runs first (consuming its usual RNG), then the
        // availability trace removes the clients the coordinator cannot
        // reach at the round-start instant — the server schedules blind
        // to availability, exactly like a real parameter server timing
        // out unreachable devices.
        let plan = self.plan_round(t)?;
        let (dropout, masks) = (plan.dropout, plan.masks);
        let participants = self.available_participants(plan.participants, self.clock.now());
        let n_parts = participants.len();
        // Schemes that score the global update (AFD's activation map)
        // need the pre-round parameters after the fold overwrites them.
        let before = self.scheme.needs_observation().then(|| self.global_params.clone());

        // ---- 1+2+3. train / select / fold, through the transport ----
        // The previous round's close notes ride along with the dispatch
        // (remote agents rebase on them; the local transport has nothing
        // to do — the driver already rebased the shared states below).
        let notes = std::mem::take(&mut self.last_close);
        let mut fold = SyncFold::new(&participants, &self.global_spec, self.backend);
        let call = RoundCall {
            round: t,
            subset: &participants,
            dropout: &dropout,
            masks: &masks,
            full_broadcast,
            notes: &notes,
            cfg: &cfg,
            runtime: &self.runtime,
            ds: &self.ds,
            cr: &self.cr,
            global: &self.global_params,
            policy: self.policy,
            codec: self.codec,
            plane: self.plane,
            plane_error: self.plane_error,
            pool: &self.pool,
            clients: &mut self.clients,
        };
        self.transport.round_uploads(call, &mut fold)?;
        let fold = fold.finish()?;
        self.global_params = fold.agg.finalize(&self.global_params, Some(&self.runtime))?;
        let mean_loss = fold.loss_sum / n_parts.max(1) as f64;
        let uploaded = fold.uploaded;
        if let Some(before) = before {
            self.scheme
                .observe_round(t, &self.global_spec, &before, &self.global_params, mean_loss);
        }

        // ---- 4. download merge (Eq. 5 / Eq. 6) as a state rebase ----
        // Publishing the end-of-round snapshot and handing every
        // participant a reference *is* the download: a broadcast client
        // collapses to `Synced`, a sparse client keeps only its residual.
        // The previous round's snapshot dies with its last reference.
        // Stateless schemes never rebase at all — they re-extract from
        // the live global at every dispatch and never read their
        // virtualized params, so the whole fleet keeps sharing the
        // round-0 snapshot (rebasing them would pin one snapshot per
        // distinct last-participation round).
        if self.scheme.stateful() {
            let snap = self.snapshots.publish(t, &self.global_params);
            for (slot, residual) in fold.rebases {
                self.clients[slot].params =
                    ClientParams::after_download(snap.clone(), residual);
            }
            self.enforce_ring_cap();
        }
        // Close notes for the next dispatch: the barrier folded every
        // participant's upload, none churned.
        self.last_close = participants
            .iter()
            .map(|&slot| CloseNote { slot, churned: false })
            .collect();

        let duration = self.clock.advance_round_by(fold.slowest);

        // Realized dropout: the byte fraction the masks actually saved.
        let mean_dropout = if self.scheme.reports_round_dropout(t) {
            1.0 - uploaded as f64 / self.clients.iter().map(|c| c.u_bytes()).sum::<usize>() as f64
        } else {
            0.0
        };

        Ok(RoundOutcome {
            duration,
            mean_loss,
            mean_dropout,
            full_broadcast,
            uploaded_bytes: uploaded,
            wire_bytes: fold.wire_bytes,
            encodings: fold.encodings,
            planes: fold.planes,
            participants: n_parts,
            stragglers: 0,
            mean_staleness: 0.0,
            churned: 0,
            client_state_bytes: self.client_state_bytes(),
            sim_state_bytes: self.sim_state_bytes(),
            data_state_bytes: self.data_state_bytes,
        })
    }

    /// Execute one semi-asynchronous, event-driven round (DESIGN.md §7).
    ///
    /// The scheduler owns time: idle participants are dispatched and
    /// pushed into the arrival heap; the round closes at the earlier of
    /// the `ceil(quorum · in_flight)`-th arrival and the deadline; every
    /// upload that has arrived by then — fresh or buffered from an
    /// earlier round — is folded into Eq. 4, late ones discounted by
    /// `(1+s)^{-β}`. Clients still in flight keep their own clocks and
    /// arrive in a later round.
    fn step_round_semi_async(&mut self) -> anyhow::Result<RoundOutcome> {
        self.round += 1;
        let t = self.round;
        let cfg = self.cfg.clone();
        let round_start = self.clock.now();
        let full_broadcast = self.is_full_broadcast(t);

        // ---- 0. participants + dropout over the whole fleet ----
        let plan = self.plan_round(t)?;
        let (dropout, masks) = (plan.dropout, plan.masks);
        // The availability trace gates dispatch the same way it gates the
        // sync barrier: an offline client is simply unreachable this
        // round (its own in-flight work, if any, still arrives).
        let participants = self.available_participants(plan.participants, round_start);

        // ---- 1. dispatch idle participants (micro-batched) ----
        // Clients still uploading a previous round's update are skipped —
        // their own clocks run past the server's round boundary. A
        // dispatched client's state stays at its pre-dispatch base until
        // its upload arrives; the residual it will keep travels with the
        // pending update.
        let dispatch: Vec<usize> = participants
            .iter()
            .copied()
            .filter(|&n| !self.client_clocks.is_busy(n, round_start))
            .collect();
        // Allocated dropout this round: mean rate over the dispatch set.
        let mean_dropout = if self.scheme.reports_round_dropout(t) && !dispatch.is_empty() {
            dispatch.iter().map(|&n| dropout[n]).sum::<f64>() / dispatch.len() as f64
        } else {
            0.0
        };
        // Stage through the transport with a `DispatchSink`: every
        // delivered envelope becomes an arrival event on the virtual
        // clock plus a buffered `PendingUpdate` — the close logic below
        // never knows where the upload came from. The previous round's
        // close notes ride along (remote agents rebase on them).
        let notes = std::mem::take(&mut self.last_close);
        {
            let call = RoundCall {
                round: t,
                subset: &dispatch,
                dropout: &dropout,
                masks: &masks,
                full_broadcast,
                notes: &notes,
                cfg: &cfg,
                runtime: &self.runtime,
                ds: &self.ds,
                cr: &self.cr,
                global: &self.global_params,
                policy: self.policy,
                codec: self.codec,
                plane: self.plane,
                plane_error: self.plane_error,
                pool: &self.pool,
                clients: &mut self.clients,
            };
            let mut sink = DispatchSink {
                round: t,
                round_start,
                events: &mut self.events,
                clocks: &mut self.client_clocks,
                pending: &mut self.pending,
            };
            self.transport.round_uploads(call, &mut sink)?;
        }

        // ---- 2. close the round: arrival quorum K or deadline ----
        let in_flight = self.events.len();
        if in_flight == 0 {
            // Nothing outstanding (a baseline can select only busy
            // clients): a zero-duration no-op round, nothing folded.
            self.clock.advance_to(round_start);
            return Ok(RoundOutcome {
                duration: 0.0,
                mean_loss: 0.0,
                mean_dropout,
                full_broadcast,
                uploaded_bytes: 0,
                wire_bytes: 0,
                encodings: EncodingMix::default(),
                planes: PlaneMix::default(),
                participants: 0,
                stragglers: 0,
                mean_staleness: 0.0,
                churned: 0,
                client_state_bytes: self.client_state_bytes(),
                sim_state_bytes: self.sim_state_bytes(),
                data_state_bytes: self.data_state_bytes,
            });
        }
        let quorum_k = ((cfg.quorum * in_flight as f64).ceil() as usize).clamp(1, in_flight);
        let t_quorum = self.events.kth_finish(quorum_k).expect("quorum_k <= in_flight");
        let t_deadline = if cfg.deadline_s > 0.0 {
            round_start + cfg.deadline_s
        } else {
            f64::INFINITY
        };
        // A deadline no client meets still terminates the round: the
        // clock advances to the deadline and zero uploads are folded.
        let t_close = t_quorum.min(t_deadline);
        let mut arrivals = self.events.pop_until(t_close);
        let stragglers = self.events.len();
        // Mid-round churn (`cfg.trace = "churn"`): some arrivals are
        // observed disconnects instead of uploads. The dropped upload
        // still occupied its link until the arrival instant — so it
        // counted toward the quorum close time above — but it is never
        // folded, the client keeps its pre-dispatch base, and it
        // reconnects idle (its clock frees at the same instant). The
        // verdict is a pure hash of (seed, client, dispatch round)
        // (`simnet::churn_drops`), so no engine RNG state is consumed and
        // replays stay bitwise-identical for every worker count.
        let mut churned = 0usize;
        let mut churned_slots: Vec<usize> = Vec::new();
        if self.trace == AvailabilityTrace::Churn && cfg.churn_rate > 0.0 {
            arrivals.retain(|ev| {
                if churn_drops(cfg.seed, ev.client, ev.dispatch_round, cfg.churn_rate) {
                    let pu = self
                        .pending
                        .remove(&ev.client)
                        .expect("churned arrival without a pending upload");
                    recycle_wire_upload(pu.wire);
                    churned += 1;
                    churned_slots.push(ev.client);
                    false
                } else {
                    true
                }
            });
        }
        self.churned_total += churned;
        // Deterministic fold order: ascending client index within the
        // round (Eq. 4's f32 accumulation is order-sensitive).
        arrivals.sort_by_key(|e| e.client);

        // ---- 3. staleness-weighted aggregation (Eq. 4 + discount) ----
        // The round's loss/byte metrics describe what was actually folded
        // (fresh or buffered), summed in the same ascending-client order
        // the aggregation runs in.
        // Pre-fold parameters for schemes that score the global update
        // (cloned only when something will actually fold).
        let before = (self.scheme.needs_observation() && !arrivals.is_empty())
            .then(|| self.global_params.clone());
        let mut uploaded = 0usize;
        let mut wire_bytes = 0usize;
        let mut encodings = EncodingMix::default();
        let mut planes = PlaneMix::default();
        let mut staleness_sum = 0usize;
        let mut loss_sum = 0.0;
        {
            let mut fresh: Vec<(usize, &WireUpload)> = Vec::new();
            let mut stale: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for ev in &arrivals {
                let pu = self
                    .pending
                    .get(&ev.client)
                    .expect("arrival without a pending upload");
                let s = t - ev.dispatch_round;
                uploaded += pu.uploaded;
                wire_bytes += pu.wire.wire_len();
                encodings.merge(pu.wire.mix());
                planes.merge(pu.wire.plane_mix());
                staleness_sum += s;
                loss_sum += pu.loss;
                if s == 0 {
                    fresh.push((ev.client, &pu.wire));
                } else {
                    stale.entry(s).or_default().push(ev.client);
                }
            }
            // Fresh arrivals take the sharded path (identical to sync);
            // each staleness cohort accumulates separately and is absorbed
            // with its discount applied to numerator and denominator.
            let mut agg = self.shard_aggregate(&fresh)?;
            drop(fresh);
            for (&s, cohort) in &stale {
                let mut part = Aggregator::new(&self.global_spec, self.backend);
                for &n in cohort {
                    let pu = self.pending.get(&n).expect("stale cohort client");
                    part.absorb_wire(&pu.wire, self.clients[n].m_n() as f32)?;
                }
                agg.absorb(&part, staleness_weight(s, cfg.staleness_beta))?;
            }
            if agg.clients_added() > 0 {
                self.global_params = agg.finalize(&self.global_params, Some(&self.runtime))?;
            }
        }

        // ---- 4. download merge for the clients that arrived ----
        // Each FedDD client rebases onto the close-time snapshot with
        // the download its link was charged for at dispatch
        // (`pu.full_broadcast`): `Synced` for a broadcast dispatch, else
        // `Delta` with the residual selected at dispatch. Baselines only
        // clear their pending slot — they never read their virtualized
        // params (re-extracted from the live global at dispatch), so
        // rebasing them would pointlessly pin per-round snapshots.
        if !arrivals.is_empty() && self.scheme.stateful() {
            let snap = self.snapshots.publish(t, &self.global_params);
            for ev in &arrivals {
                let n = ev.client;
                let pu = self
                    .pending
                    .remove(&n)
                    .expect("arrival without a pending upload");
                self.clients[n].params = if pu.full_broadcast {
                    ClientParams::synced(snap.clone())
                } else {
                    ClientParams::after_download(snap.clone(), pu.residual)
                };
                recycle_wire_upload(pu.wire);
            }
            self.enforce_ring_cap();
        } else {
            for ev in &arrivals {
                let pu = self
                    .pending
                    .remove(&ev.client)
                    .expect("arrival without a pending upload");
                recycle_wire_upload(pu.wire);
            }
        }

        // Close notes for the next dispatch: everything that left flight
        // this round — folded arrivals plus churn drops — ascending by
        // slot (a slot cannot be both: churn removed it from `arrivals`).
        let mut closes: Vec<CloseNote> = arrivals
            .iter()
            .map(|ev| CloseNote { slot: ev.client, churned: false })
            .collect();
        closes.extend(churned_slots.into_iter().map(|slot| CloseNote { slot, churned: true }));
        closes.sort_unstable_by_key(|c| c.slot);
        self.last_close = closes;

        // ---- 5. advance the server clock to the close time ----
        let duration = self.clock.advance_to(t_close);
        let folded = arrivals.len();
        let mean_loss = loss_sum / folded.max(1) as f64;
        if folded > 0 {
            if let Some(before) = before {
                self.scheme.observe_round(
                    t,
                    &self.global_spec,
                    &before,
                    &self.global_params,
                    mean_loss,
                );
            }
        }
        let mean_staleness = if folded == 0 {
            0.0
        } else {
            staleness_sum as f64 / folded as f64
        };

        Ok(RoundOutcome {
            duration,
            mean_loss,
            mean_dropout,
            full_broadcast,
            uploaded_bytes: uploaded,
            wire_bytes,
            encodings,
            planes,
            participants: folded,
            stragglers,
            mean_staleness,
            churned,
            client_state_bytes: self.client_state_bytes(),
            sim_state_bytes: self.sim_state_bytes(),
            data_state_bytes: self.data_state_bytes,
        })
    }

    /// Agent side of serve mode, step 1 of a dispatch: install the
    /// server's post-close global (the round-`round` download base),
    /// then apply the relayed close notes — each noted slot's upload
    /// left flight on the server at the end of round `round - 1`, so the
    /// local replica rebases exactly as the in-process engine would
    /// have. A churned note just drops the pending record (the client
    /// keeps its pre-dispatch base); a folded note rebases onto the
    /// incoming global, which *is* the snapshot the server published at
    /// that close. Serve mode pins `snapshot_ring_cap == 0`, so no
    /// eviction pass runs here.
    ///
    /// `pendings` is the agent's record of its own dispatched-but-open
    /// uploads, keyed by slot (see [`AgentPending`]).
    pub fn install_dispatch_base(
        &mut self,
        round: usize,
        global: Vec<Tensor>,
        notes: &[CloseNote],
        pendings: &mut BTreeMap<usize, AgentPending>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            global.len() == self.global_params.len(),
            "dispatch global has {} tensors, model has {}",
            global.len(),
            self.global_params.len()
        );
        for (got, have) in global.iter().zip(&self.global_params) {
            anyhow::ensure!(
                got.shape() == have.shape(),
                "dispatch tensor shape {:?} != model shape {:?}",
                got.shape(),
                have.shape()
            );
        }
        self.global_params = global;
        if notes.is_empty() {
            return Ok(());
        }
        let rebase = self.scheme.stateful() && notes.iter().any(|n| !n.churned);
        let snap =
            rebase.then(|| self.snapshots.publish(round.saturating_sub(1), &self.global_params));
        for note in notes {
            let Some(p) = pendings.remove(&note.slot) else {
                anyhow::bail!("close note for slot {} without a pending dispatch", note.slot);
            };
            if note.churned {
                continue;
            }
            if let Some(snap) = &snap {
                self.clients[note.slot].params = if p.full_broadcast {
                    ClientParams::synced(snap.clone())
                } else {
                    ClientParams::after_download(snap.clone(), p.residual)
                };
            }
        }
        Ok(())
    }

    /// Agent side of serve mode, step 2 of a dispatch: train the
    /// dispatched subset of locally hosted slots and deliver the
    /// envelopes to `sink` (which ships them to the server and records
    /// each one's [`AgentPending`]), staged by the exact code
    /// [`LocalTransport`] runs in-process — same micro-batching, same
    /// RNG streams, same ascending order.
    pub fn stage_for_dispatch(
        &mut self,
        round: usize,
        full_broadcast: bool,
        subset: &[usize],
        dropout: &[f64],
        sink: &mut dyn UploadSink,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            dropout.len() == self.clients.len(),
            "dropout vector has {} rates for {} clients",
            dropout.len(),
            self.clients.len()
        );
        // Wire-supplied inputs fail the round, never the process (DESIGN
        // §Serve): a corrupt rate would otherwise reach the mask
        // machinery's debug asserts.
        for &s in subset {
            anyhow::ensure!(s < dropout.len(), "dispatched slot {s} out of range");
            anyhow::ensure!(
                (0.0..=1.0).contains(&dropout[s]),
                "dispatched dropout rate {} for slot {s} outside [0, 1]",
                dropout[s]
            );
        }
        // Serve agents recompute dispatch masks from the shared config;
        // a scheme whose masks live in server-side state cannot.
        let masks = self.scheme.agent_masks(&self.cfg).ok_or_else(|| {
            anyhow::anyhow!(
                "scheme {:?} keeps server-resident dispatch-mask state and cannot stage remotely",
                self.cfg.scheme
            )
        })?;
        let cfg = self.cfg.clone();
        let mut call = RoundCall {
            round,
            subset,
            dropout,
            masks: &masks,
            full_broadcast,
            notes: &[],
            cfg: &cfg,
            runtime: &self.runtime,
            ds: &self.ds,
            cr: &self.cr,
            global: &self.global_params,
            policy: self.policy,
            codec: self.codec,
            plane: self.plane,
            plane_error: self.plane_error,
            pool: &self.pool,
            clients: &mut self.clients,
        };
        drive_subset(&mut call, sink)
    }

    /// Run the full experiment.
    pub fn run(&mut self) -> anyhow::Result<RunResult> {
        let label = format!(
            "{}-{}-{}-{}",
            self.cfg.scheme, self.cfg.dataset, self.cfg.partition, self.cfg.model
        );
        let mut result = RunResult::new(&self.cfg.scheme, &label);
        let wall0 = Instant::now();
        let budget = self.budget_bytes();
        for t in 1..=self.cfg.rounds {
            let out = self.step_round()?;
            result.rounds.push(RoundRecord {
                round: t,
                v_time: self.clock.now(),
                duration: out.duration,
                train_loss: out.mean_loss,
                uploaded_bytes: out.uploaded_bytes,
                wire_bytes: out.wire_bytes,
                encodings: out.encodings,
                planes: out.planes,
                budget_bytes: budget,
                participants: out.participants,
                mean_dropout: out.mean_dropout,
                full_broadcast: out.full_broadcast,
                stragglers: out.stragglers,
                mean_staleness: out.mean_staleness,
                churned: out.churned,
                client_state_bytes: out.client_state_bytes,
                sim_state_bytes: out.sim_state_bytes,
                data_state_bytes: out.data_state_bytes,
            });
            if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
                let (acc, loss, pca) = self.evaluate()?;
                log::info!(
                    "[{}] round {t:3}/{} vt={:8.1}s loss={:.3} acc={:.3} up={}KB x{}",
                    label,
                    self.cfg.rounds,
                    self.clock.now(),
                    out.mean_loss,
                    acc,
                    out.uploaded_bytes / 1024,
                    out.participants,
                );
                result.evals.push(EvalRecord {
                    round: t,
                    v_time: self.clock.now(),
                    accuracy: acc,
                    loss,
                    per_class_accuracy: pca,
                });
            }
        }
        result.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(result)
    }
}

/// Convenience: build + run in one call.
pub fn run_experiment(cfg: ExpConfig) -> anyhow::Result<RunResult> {
    FedRun::new(cfg)?.run()
}

/// Re-exported server type name used in docs/prelude.
pub type FedDdServer = FedRun;
