//! Copy-on-write client-state virtualization (DESIGN.md §Fleet-Virtualization).
//!
//! FedDD has no partial participation — *every* client holds local state
//! every round — so a production-scale fleet cannot afford one dense
//! model replica per client (O(clients · model)). This module stores the
//! fleet's state against a shared ring of global snapshots instead:
//!
//! * [`GlobalSnapshot`] — the global parameters published at the end of a
//!   round, shared by `Arc`. Clients hold references, never copies.
//! * [`SnapshotRing`] — weak-reference bookkeeping over the published
//!   snapshots: a snapshot stays alive exactly while some client's state
//!   is still based on it (the `Arc` is the lifetime; the ring only
//!   observes it for accounting).
//! * [`SparseResidual`] — the channels of a client's model that its
//!   Eq. 5 sparse download did *not* overwrite: the complement of the
//!   upload mask `M_n`, holding the client's own trained values in the
//!   codec's canonical unit-group layout.
//! * [`ClientParams`] — `Synced` (the client equals the snapshot slice —
//!   nothing stored; every client right after an Eq. 6 full broadcast)
//!   or `Delta` (snapshot slice + sparse residual).
//!
//! The invariant that makes this *bitwise* equivalent to a dense
//! per-client replica: after a non-broadcast FedDD round, a client's
//! dense state is `W^t ⊙ M_n + Ŵ_n ⊙ (1 − M_n)` (Eq. 5). Materializing
//! `Delta { base: W^t, residual: (1−M_n) channels of Ŵ_n }` copies the
//! *same* f32 values from the same tensors — extract the snapshot slice,
//! then scatter the residual — so `materialize` reproduces the dense
//! merge bit for bit (asserted in `rust/tests/fleet_virtualization.rs`).
//! (Pedantic corner: the dense `sparse_merge` computes
//! `g·1 + l·0` at masked positions, which differs from a plain copy of
//! `g` only when `g` is `-0.0` or `l` is non-finite — values training
//! arithmetic does not produce; the virtualized copy is the cleaner of
//! the two there.)
//!
//! A delta **collapses back to `Synced`** whenever its residual is empty:
//! after a full broadcast, and after any round whose upload mask kept
//! every unit (round 1's `D¹ = 0`, or a client allocated `d = 0`).

//! # Capping the ring
//!
//! Uncapped, a pathological semi-async straggler tail pins one snapshot
//! per distinct dispatch round still in flight — O(tail · model) shared
//! bytes. With `snapshot_ring_cap > 0` the engine evicts the oldest live
//! round's dependents to [`ClientParams::Evicted`] whenever the live
//! count exceeds the cap, which drops their `Arc`s and frees the
//! snapshot. Two cases, one variant:
//!
//! * **In-flight dependents** (dispatched, not yet arrived): their pinned
//!   pre-dispatch base is dead weight — the arrival path rebases onto the
//!   close-time snapshot using only the `PendingUpdate` residual, and the
//!   dispatch filter skips busy clients — so evicting them is *bitwise
//!   neutral*.
//! * **Idle dependents**: their state is genuinely lost; the next
//!   dispatch detects `Evicted` and forces a full re-sync (an Eq. 6-style
//!   full download, charged through `simnet::downlink_bytes`) — a
//!   deliberate, accounted numeric change.

use std::sync::{Arc, Weak};

use crate::codec::{gather_unit_values, scatter_unit_values};
use crate::model::{extract_params_into, ModelSpec};
use crate::selection::ChannelMask;
use crate::tensor::Tensor;

/// Global model parameters published at the end of one round, shared by
/// every client whose state is based on that round.
#[derive(Debug)]
pub struct GlobalSnapshot {
    /// The round whose aggregation produced these parameters (0 = the
    /// initial model).
    pub round: usize,
    pub params: Vec<Tensor>,
}

impl GlobalSnapshot {
    /// Bytes of the snapshot's f32 payload.
    pub fn size_bytes(&self) -> usize {
        self.params.iter().map(|t| t.numel() * 4).sum()
    }
}

/// Accounting over the published snapshots. Lifetime is owned by the
/// `Arc`s inside client state — the ring holds only weak references, so
/// a snapshot is freed the moment the last client rebases past it (in
/// sync FedDD that is every round; in semi-async, when the last
/// straggler dispatched against it finally arrives).
#[derive(Debug, Default)]
pub struct SnapshotRing {
    slots: Vec<(usize, Weak<GlobalSnapshot>)>,
}

impl SnapshotRing {
    pub fn new() -> SnapshotRing {
        SnapshotRing::default()
    }

    /// Publish the end-of-round global parameters as a shared snapshot
    /// and prune ring entries whose snapshot has already been dropped.
    pub fn publish(&mut self, round: usize, params: &[Tensor]) -> Arc<GlobalSnapshot> {
        let snap = Arc::new(GlobalSnapshot { round, params: params.to_vec() });
        self.slots.retain(|(_, w)| w.strong_count() > 0);
        self.slots.push((round, Arc::downgrade(&snap)));
        snap
    }

    /// Rounds whose snapshot is still referenced by some client.
    pub fn live_rounds(&self) -> Vec<usize> {
        self.slots
            .iter()
            .filter(|(_, w)| w.strong_count() > 0)
            .map(|&(r, _)| r)
            .collect()
    }

    /// Total bytes of the snapshots still alive — the shared (not
    /// per-client) part of the fleet's state footprint.
    pub fn live_bytes(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|(_, w)| w.upgrade())
            .map(|s| s.size_bytes())
            .sum()
    }

    /// Number of snapshots still referenced by some client.
    pub fn live_count(&self) -> usize {
        self.slots.iter().filter(|(_, w)| w.strong_count() > 0).count()
    }

    /// The oldest round whose snapshot is still referenced — the eviction
    /// candidate when the ring exceeds its cap. Slots are pushed in
    /// publish order, so the first live slot is the oldest.
    pub fn oldest_live_round(&self) -> Option<usize> {
        self.slots
            .iter()
            .find(|(_, w)| w.strong_count() > 0)
            .map(|&(r, _)| r)
    }
}

/// One layer's residual channels: the units the client's sparse download
/// did not overwrite (ascending), with their value groups in the codec's
/// canonical layout (incoming weights then bias per unit).
#[derive(Clone, Debug, PartialEq)]
pub struct ResidualLayer {
    pub units: Vec<u32>,
    pub values: Vec<f32>,
}

/// A client's divergence from its base snapshot: exactly the complement
/// of its Eq. 5 upload mask, holding the client's own trained values.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseResidual {
    pub layers: Vec<ResidualLayer>,
}

impl SparseResidual {
    /// Build the residual a client must keep after a *non-broadcast*
    /// round: for every layer, the units **not** selected by the upload
    /// mask (their downloads never arrive), carrying the post-training
    /// values. Returns `None` when the mask kept every unit — the sparse
    /// download then overwrites the whole model and the client collapses
    /// to [`ClientParams::Synced`].
    pub fn complement_of(
        mask: &ChannelMask,
        params: &[Tensor],
        spec: &ModelSpec,
    ) -> Option<SparseResidual> {
        debug_assert_eq!(params.len(), spec.layers.len() * 2, "params arity");
        let mut layers = Vec::with_capacity(spec.layers.len());
        let mut any = false;
        for (l, layer) in spec.layers.iter().enumerate() {
            let sel = &mask.per_layer[l];
            debug_assert_eq!(sel.len(), layer.out_dim, "layer {l} mask length");
            let units: Vec<u32> = sel
                .iter()
                .enumerate()
                .filter(|(_, &s)| !s)
                .map(|(k, _)| k as u32)
                .collect();
            any |= !units.is_empty();
            let values = gather_unit_values(
                layer,
                params[2 * l].data(),
                params[2 * l + 1].data(),
                &units,
            );
            layers.push(ResidualLayer { units, values });
        }
        if any {
            Some(SparseResidual { layers })
        } else {
            None
        }
    }

    /// Overwrite the residual units' positions in dense client-shaped
    /// params; every other position is untouched.
    pub fn scatter_into(&self, params: &mut [Tensor], spec: &ModelSpec) {
        debug_assert_eq!(self.layers.len(), spec.layers.len(), "residual arity");
        for (l, (rl, layer)) in self.layers.iter().zip(&spec.layers).enumerate() {
            let (head, tail) = params.split_at_mut(2 * l + 1);
            scatter_unit_values(
                layer,
                head[2 * l].data_mut(),
                tail[0].data_mut(),
                &rl.units,
                &rl.values,
            );
        }
    }

    /// Residual units across all layers.
    pub fn unit_count(&self) -> usize {
        self.layers.iter().map(|rl| rl.units.len()).sum()
    }

    /// Heap bytes this residual pins per client (unit ids + f32 values).
    pub fn heap_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|rl| rl.units.len() * 4 + rl.values.len() * 4)
            .sum()
    }
}

/// A client's virtualized local model `W_n^t`.
#[derive(Clone, Debug)]
pub enum ClientParams {
    /// The client equals `extract_params(base, spec)` — nothing stored
    /// beyond the shared snapshot reference. Every client is `Synced`
    /// right after an Eq. 6 full broadcast; baselines (which re-sync to
    /// the current global at every dispatch) stay `Synced` permanently.
    Synced { base: Arc<GlobalSnapshot> },
    /// Masked channels come from `base` (the Eq. 5 sparse download); the
    /// complement keeps the client's own trained values.
    Delta {
        base: Arc<GlobalSnapshot>,
        residual: SparseResidual,
    },
    /// The ring cap evicted this client's base snapshot (see the module
    /// docs). Nothing is stored; the next dispatch must re-sync the
    /// client with a full download before training.
    Evicted,
}

impl ClientParams {
    /// State right after a full broadcast (or at fleet construction).
    pub fn synced(base: Arc<GlobalSnapshot>) -> ClientParams {
        ClientParams::Synced { base }
    }

    /// State right after a download merge: `Delta` while a residual
    /// diverges, collapsing to `Synced` when nothing does.
    pub fn after_download(
        base: Arc<GlobalSnapshot>,
        residual: Option<SparseResidual>,
    ) -> ClientParams {
        match residual {
            Some(residual) => ClientParams::Delta { base, residual },
            None => ClientParams::Synced { base },
        }
    }

    /// Round of the snapshot this state is based on (`None` once the
    /// ring cap evicted it).
    pub fn base_round(&self) -> Option<usize> {
        match self {
            ClientParams::Synced { base } => Some(base.round),
            ClientParams::Delta { base, .. } => Some(base.round),
            ClientParams::Evicted => None,
        }
    }

    pub fn is_synced(&self) -> bool {
        matches!(self, ClientParams::Synced { .. })
    }

    /// Reconstruct the dense client model — bitwise identical to the
    /// dense bookkeeping's Eq. 5 merge (extract the snapshot slice, then
    /// scatter the residual values over the complement channels). Called
    /// only inside the per-client worker stage, so at most
    /// O(workers · model) dense replicas exist at any instant.
    pub fn materialize(&self, spec: &ModelSpec) -> Vec<Tensor> {
        let mut out = Vec::new();
        self.materialize_into(spec, &mut out);
        out
    }

    /// [`Self::materialize`] into a reusable buffer — the per-worker
    /// scratch arena's dense materialization target. Same bits: the
    /// snapshot extraction fully overwrites every client-shaped tensor
    /// (`extract_params_into`), then the residual scatter rewrites its
    /// complement channels, so the buffer's previous contents — another
    /// client's model, or the poisoning sentinels of
    /// `rust/tests/pool_determinism.rs` — can never leak through.
    pub fn materialize_into(&self, spec: &ModelSpec, out: &mut Vec<Tensor>) {
        match self {
            ClientParams::Synced { base } => extract_params_into(&base.params, spec, out),
            ClientParams::Delta { base, residual } => {
                extract_params_into(&base.params, spec, out);
                residual.scatter_into(out, spec);
            }
            ClientParams::Evicted => {
                panic!("materialize: evicted client state must be re-synced at dispatch")
            }
        }
    }

    /// Per-client heap bytes this state pins (0 when `Synced` or
    /// `Evicted`; the shared snapshot is accounted once, by
    /// `SnapshotRing::live_bytes`).
    pub fn state_bytes(&self) -> usize {
        match self {
            ClientParams::Synced { .. } | ClientParams::Evicted => 0,
            ClientParams::Delta { residual, .. } => residual.heap_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::sparse_merge;
    use crate::selection::{select_mask, Policy};
    use crate::util::rng::Rng;

    fn perturbed(p: &[Tensor], rng: &mut Rng, s: f32) -> Vec<Tensor> {
        p.iter()
            .map(|t| {
                let d: Vec<f32> =
                    t.data().iter().map(|&x| x + rng.normal_f32(0.0, s)).collect();
                Tensor::new(t.shape().to_vec(), d)
            })
            .collect()
    }

    #[test]
    fn full_mask_has_no_residual_and_collapses_to_synced() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(0);
        let params = spec.init_params(&mut rng);
        let mask = ChannelMask::full(&spec);
        assert!(SparseResidual::complement_of(&mask, &params, &spec).is_none());
        let mut ring = SnapshotRing::new();
        let snap = ring.publish(1, &params);
        let state = ClientParams::after_download(snap, None);
        assert!(state.is_synced());
        assert_eq!(state.state_bytes(), 0);
        assert_eq!(state.base_round(), Some(1));
    }

    #[test]
    fn evicting_dependents_frees_the_oldest_snapshot() {
        // The cap mechanism in miniature: replacing every dependent of
        // the oldest live round with `Evicted` drops the last Arcs, the
        // snapshot dies, and the ring's live set shrinks — while the
        // evicted state itself pins nothing and reports no base.
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(6);
        let params = spec.init_params(&mut rng);
        let mut ring = SnapshotRing::new();
        let s1 = ring.publish(1, &params);
        let s2 = ring.publish(2, &params);
        let mut fleet = vec![
            ClientParams::synced(s1.clone()),
            ClientParams::synced(s1),
            ClientParams::synced(s2),
        ];
        assert_eq!(ring.live_count(), 2);
        assert_eq!(ring.oldest_live_round(), Some(1));
        let oldest = ring.oldest_live_round().unwrap();
        for c in &mut fleet {
            if c.base_round() == Some(oldest) {
                *c = ClientParams::Evicted;
            }
        }
        assert_eq!(ring.live_count(), 1);
        assert_eq!(ring.oldest_live_round(), Some(2));
        assert_eq!(fleet[0].base_round(), None);
        assert_eq!(fleet[0].state_bytes(), 0);
        assert!(!fleet[0].is_synced());
    }

    #[test]
    #[should_panic(expected = "evicted client state")]
    fn materializing_evicted_state_panics() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let _ = ClientParams::Evicted.materialize(&spec);
    }

    #[test]
    fn materialize_matches_dense_sparse_merge_bitwise() {
        // The crux lemma: Delta-materialization equals the dense
        // representation's Eq. 5 merge (sparse_merge) bit for bit.
        let spec = ModelSpec::get("mlp", 0.5).unwrap();
        let mut rng = Rng::new(1);
        let global = spec.init_params(&mut rng);
        for d in [0.1, 0.4, 0.8] {
            let trained = perturbed(&global, &mut rng, 0.05);
            let mask =
                select_mask(Policy::Random, &spec, &global, &trained, None, d, &mut rng);
            // dense bookkeeping: local ← W ⊙ M + trained ⊙ (1−M)
            let mut dense = trained.clone();
            sparse_merge(&mut dense, &global, &mask.to_elementwise(&spec));
            // virtualized bookkeeping
            let mut ring = SnapshotRing::new();
            let snap = ring.publish(3, &global);
            let residual = SparseResidual::complement_of(&mask, &trained, &spec)
                .expect("d > 0 must leave a residual");
            let state = ClientParams::after_download(snap, Some(residual));
            let virt = state.materialize(&spec);
            for (i, (a, b)) in dense.iter().zip(&virt).enumerate() {
                assert_eq!(a.data(), b.data(), "d={d}: tensor {i} differs");
            }
        }
    }

    #[test]
    fn residual_is_strictly_smaller_than_dense_whenever_dropout_drops() {
        let spec = ModelSpec::get("cnn1", 0.5).unwrap();
        let mut rng = Rng::new(2);
        let global = spec.init_params(&mut rng);
        let trained = perturbed(&global, &mut rng, 0.05);
        for d in [0.05, 0.3, 0.6, 0.9] {
            let mask =
                select_mask(Policy::Delta, &spec, &global, &trained, None, d, &mut rng);
            let r = SparseResidual::complement_of(&mask, &trained, &spec).unwrap();
            assert!(r.heap_bytes() > 0);
            assert!(
                r.heap_bytes() < spec.size_bytes(),
                "d={d}: residual {} !< dense {}",
                r.heap_bytes(),
                spec.size_bytes()
            );
        }
        // higher dropout -> more residual channels (monotone in d).
        let r_lo = SparseResidual::complement_of(
            &select_mask(Policy::Delta, &spec, &global, &trained, None, 0.2, &mut rng),
            &trained,
            &spec,
        )
        .unwrap();
        let r_hi = SparseResidual::complement_of(
            &select_mask(Policy::Delta, &spec, &global, &trained, None, 0.7, &mut rng),
            &trained,
            &spec,
        )
        .unwrap();
        assert!(r_hi.unit_count() > r_lo.unit_count());
    }

    #[test]
    fn materialize_into_dirty_reused_buffer_matches_materialize() {
        // The worker-arena path: after another client's job (here:
        // sentinel poisoning) the same buffer must materialize to the
        // same bits a fresh allocation does.
        let spec = ModelSpec::get("mlp", 0.5).unwrap();
        let mut rng = Rng::new(5);
        let global = spec.init_params(&mut rng);
        let trained = perturbed(&global, &mut rng, 0.05);
        let mask = select_mask(Policy::Random, &spec, &global, &trained, None, 0.5, &mut rng);
        let mut ring = SnapshotRing::new();
        let snap = ring.publish(1, &global);
        let residual = SparseResidual::complement_of(&mask, &trained, &spec).unwrap();
        let state = ClientParams::after_download(snap, Some(residual));
        let want = state.materialize(&spec);
        let mut buf: Vec<Tensor> = want
            .iter()
            .map(|t| Tensor::full(t.shape().to_vec(), f32::NAN))
            .collect();
        state.materialize_into(&spec, &mut buf);
        for (i, (a, b)) in want.iter().zip(&buf).enumerate() {
            assert_eq!(a.data(), b.data(), "tensor {i} differs from fresh materialize");
        }
    }

    #[test]
    fn snapshot_ring_frees_unreferenced_rounds() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(3);
        let params = spec.init_params(&mut rng);
        let mut ring = SnapshotRing::new();
        let s1 = ring.publish(1, &params);
        let bytes = s1.size_bytes();
        assert_eq!(ring.live_rounds(), vec![1]);
        assert_eq!(ring.live_bytes(), bytes);
        let s2 = ring.publish(2, &params);
        // both alive while both referenced
        assert_eq!(ring.live_rounds(), vec![1, 2]);
        assert_eq!(ring.live_bytes(), 2 * bytes);
        drop(s1);
        assert_eq!(ring.live_rounds(), vec![2]);
        assert_eq!(ring.live_bytes(), bytes);
        // clients sharing one snapshot count it once
        let clones: Vec<_> = (0..10).map(|_| ClientParams::synced(s2.clone())).collect();
        assert_eq!(ring.live_bytes(), bytes);
        assert!(clones.iter().all(|c| c.state_bytes() == 0));
        drop(clones);
        drop(s2);
        assert!(ring.live_rounds().is_empty());
        assert_eq!(ring.live_bytes(), 0);
    }

    #[test]
    fn residual_scatter_only_touches_complement_positions() {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let mut rng = Rng::new(4);
        let global = spec.init_params(&mut rng);
        let trained = perturbed(&global, &mut rng, 0.1);
        let mask =
            select_mask(Policy::Random, &spec, &global, &trained, None, 0.5, &mut rng);
        let residual = SparseResidual::complement_of(&mask, &trained, &spec).unwrap();
        let mut out = global.clone();
        residual.scatter_into(&mut out, &spec);
        let elems = mask.to_elementwise(&spec);
        for i in 0..out.len() {
            for j in 0..out[i].numel() {
                let want = if elems[i].data()[j] == 1.0 {
                    global[i].data()[j] // masked: untouched base value
                } else {
                    trained[i].data()[j] // complement: the trained value
                };
                assert_eq!(out[i].data()[j].to_bits(), want.to_bits(), "[{i}][{j}]");
            }
        }
    }
}
