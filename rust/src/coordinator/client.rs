//! Per-client state and the local-training step (the client side of
//! Algorithm 1 steps 1–3 and 7).
//!
//! Client model parameters are **virtualized** ([`ClientParams`],
//! DESIGN.md §Fleet-Virtualization): a client stores a reference to a
//! shared global snapshot plus, when diverged, the sparse residual of the
//! channels its Eq. 5 downloads never overwrote — never a dense replica.
//! The dense model exists only transiently, inside the round engine's
//! worker stage ([`ClientParams::materialize`] → train → drop).

use crate::codec::WireUpload;
use crate::data::{ClientShard, FedDataset};
use crate::model::{ModelId, ModelSpec};
use crate::runtime::Runtime;
use crate::simnet::DeviceProfile;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::state::{ClientParams, SparseResidual};

/// A dispatched upload that has not yet been folded by the server
/// (semi-asynchronous mode): the encoded upload in flight plus the
/// residual the client must keep once its Eq. 5 download arrives. The
/// client's [`ClientParams`] stays at its pre-dispatch base while the
/// upload is in flight — the client is busy until its arrival event
/// fires, so nothing materializes it in between.
#[derive(Clone, Debug)]
pub struct PendingUpdate {
    /// The encoded upload in flight; `wire.wire_len()` — the realized
    /// encoded bytes, not the full model and not the `upload_bytes`
    /// estimate — is what the upload link was charged for, and
    /// `Aggregator::absorb_wire` folds it without densifying.
    pub wire: WireUpload,
    /// The complement-of-mask residual selected at dispatch: the state
    /// the client keeps after the arrival-time Eq. 5 merge (`None` when
    /// the dispatch was a full broadcast or the mask kept every unit —
    /// the client then collapses to `Synced`).
    pub residual: Option<SparseResidual>,
    /// Mean training loss reported with the upload (folded into the
    /// server's round loss when the upload arrives). The dispatch round
    /// lives on the matching `simnet::ArrivalEvent`.
    pub loss: f64,
    /// Masked value payload bytes (`mask.payload_bytes`) for budget
    /// accounting — also the Eq. 5 downlink charge of a sparse dispatch.
    pub uploaded: usize,
    /// Whether the *dispatch* charged a full-model download (broadcast
    /// round, or the client's first dispatch ever). The arrival-time
    /// merge honors this flag so the client receives exactly the
    /// download its link was charged for (full model vs mask-sparse),
    /// even when it arrives in a round with the opposite phase.
    pub full_broadcast: bool,
}

/// One simulated client.
pub struct ClientState {
    pub id: usize,
    pub model_id: ModelId,
    pub spec: ModelSpec,
    /// Virtualized local model W_n^t: snapshot reference + sparse
    /// residual (see `coordinator::state`).
    pub params: ClientParams,
    /// This client's view of the shared train set (materialized indices
    /// or a lazy strided slice of the IID permutation).
    pub data: ClientShard,
    pub profile: DeviceProfile,
    /// Σ_c min(C·dis_n^c, 1) — the data-distribution contribution term.
    pub dis_score: f64,
    /// Last reported training loss (drives re_n and Oort utility).
    pub last_loss: f64,
    /// Rounds this client has participated in (exploration accounting;
    /// also flags the first dispatch, which always downloads the full
    /// model — a client cannot merge a mask-sparse slice before it has
    /// ever held the global model).
    pub participations: usize,
    pub rng: Rng,
    /// Name of this client's train artifact.
    pub train_artifact: String,
    /// Fused multi-step artifact (name, steps) when compiled — the L2
    /// `lax.scan` perf path that removes per-step host<->device round
    /// trips (EXPERIMENTS.md §Perf).
    pub scan_artifact: Option<(String, usize)>,
}

impl ClientState {
    /// m_n — the client's sample count (aggregation weight).
    pub fn m_n(&self) -> usize {
        self.data.len()
    }

    /// U_n in bytes.
    pub fn u_bytes(&self) -> usize {
        self.spec.size_bytes()
    }

    /// Samples processed in one round (local_steps minibatches).
    pub fn samples_per_round(&self, local_steps: usize, batch: usize) -> usize {
        local_steps * batch
    }

    /// Run `local_steps` SGD steps on this client's shard, mutating the
    /// materialized `params` in place; returns the mean loss.
    /// `scratch_x/y` are reusable batch buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn train_local(
        &mut self,
        runtime: &Runtime,
        ds: &FedDataset,
        local_steps: usize,
        batch: usize,
        lr: f32,
        params: &mut Vec<Tensor>,
        scratch_x: &mut Vec<f32>,
        scratch_y: &mut Vec<i32>,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(!self.data.is_empty(), "client {} has no data", self.id);
        let mut loss_sum = 0.0f64;
        let mut losses = 0usize;
        let mut idxs = Vec::with_capacity(batch);
        let mut remaining = local_steps;
        // Fused path: consume steps in scan-sized groups.
        if let Some((scan_name, steps)) = self.scan_artifact.clone() {
            while remaining >= steps {
                idxs.clear();
                for _ in 0..steps * batch {
                    let j = self.rng.below(self.data.len());
                    idxs.push(self.data.get(j));
                }
                ds.gather_train(&idxs, scratch_x, scratch_y);
                let loss =
                    runtime.train_scan(&scan_name, params, scratch_x, scratch_y, lr)?;
                loss_sum += loss as f64 * steps as f64;
                losses += steps;
                remaining -= steps;
            }
        }
        for _ in 0..remaining {
            idxs.clear();
            for _ in 0..batch {
                let j = self.rng.below(self.data.len());
                idxs.push(self.data.get(j));
            }
            ds.gather_train(&idxs, scratch_x, scratch_y);
            let loss =
                runtime.train_step(&self.train_artifact, params, scratch_x, scratch_y, lr)?;
            loss_sum += loss as f64;
            losses += 1;
        }
        let mean = loss_sum / losses.max(1) as f64;
        self.last_loss = mean;
        self.participations += 1;
        Ok(mean)
    }
}
