//! Per-worker scratch arenas for the round engine's client stage.
//!
//! Each worker thread of the persistent pool (`util::threadpool`) owns
//! one [`WorkerScratch`] in a thread-local: the dense materialization
//! target, the pre-training parameter copy and the minibatch gather
//! buffers are taken from it and **reused across micro-batches and
//! rounds** instead of reallocated per client job. The arena exists
//! exactly because the pool's threads are long-lived — with the old
//! spawn-per-call pool every buffer died with its thread.
//!
//! # Safety contract (why reuse cannot change a bit)
//!
//! Every consumer of an arena buffer fully overwrites the region it
//! later reads: `extract_params_into`/`materialize_into` rewrite the
//! whole client-shaped tensor set, `copy_tensors_into` rewrites every
//! retained element, and `FedDataset::gather_train` clears before
//! writing. Nothing reads a byte it did not just write, so a pooled run
//! is bitwise identical to `workers = 1` — and to prove it,
//! `FedRun::poison_worker_scratch` fills every arena with sentinels
//! (NaN / `i32::MIN`) between rounds in
//! `rust/tests/pool_determinism.rs`: any stale-scratch read would
//! surface as a NaN loss or diverged parameters.

use std::cell::RefCell;

use crate::tensor::Tensor;

/// Reusable buffers for one worker thread's client jobs.
pub struct WorkerScratch {
    /// Dense materialization target — the client's model for the round
    /// (snapshot slice + residual scatter, or the baseline re-extract).
    pub params: Vec<Tensor>,
    /// Pre-training copy of `params` (Algorithm-2 selection input).
    pub params_before: Vec<Tensor>,
    /// Flattened minibatch inputs for `FedDataset::gather_train`.
    pub x: Vec<f32>,
    /// Minibatch labels.
    pub y: Vec<i32>,
}

thread_local! {
    static SCRATCH: RefCell<WorkerScratch> = const {
        RefCell::new(WorkerScratch {
            params: Vec::new(),
            params_before: Vec::new(),
            x: Vec::new(),
            y: Vec::new(),
        })
    };
}

/// Run `f` with the calling thread's scratch arena. Client jobs are
/// never nested, so the `RefCell` borrow is uncontended.
pub fn with_scratch<R>(f: impl FnOnce(&mut WorkerScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Test support: overwrite the calling thread's arena with sentinel
/// values, keeping lengths and shapes — the reuse paths then face dirty,
/// wrong-valued memory rather than conveniently empty buffers. Reached
/// through `FedRun::poison_worker_scratch`, which broadcasts this to
/// every pool worker.
pub fn poison_thread_scratch() {
    with_scratch(|s| {
        for t in s.params.iter_mut().chain(s.params_before.iter_mut()) {
            t.data_mut().fill(f32::NAN);
        }
        s.x.fill(f32::NAN);
        s.y.fill(i32::MIN);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_persists_on_the_same_thread_and_poison_keeps_lengths() {
        with_scratch(|s| {
            s.x.clear();
            s.x.extend_from_slice(&[1.0, 2.0]);
            s.y.clear();
            s.y.extend_from_slice(&[7, 8, 9]);
            s.params = vec![Tensor::full(vec![2, 2], 1.5)];
        });
        with_scratch(|s| {
            assert_eq!(s.x, vec![1.0, 2.0], "arena must persist across calls");
        });
        poison_thread_scratch();
        with_scratch(|s| {
            assert_eq!(s.x.len(), 2);
            assert!(s.x.iter().all(|v| v.is_nan()));
            assert_eq!(s.y, vec![i32::MIN; 3]);
            assert_eq!(s.params[0].shape(), &[2, 2]);
            assert!(s.params[0].data().iter().all(|v| v.is_nan()));
        });
    }
}
