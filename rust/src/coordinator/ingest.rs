//! The ingest layer: everything between "the driver picked a participant
//! set" and "the driver folds uploads" lives behind the
//! [`UploadSource`]/[`UploadSink`] trait pair, so the round drivers in
//! `engine.rs` never know whether a client trained on a worker thread in
//! this process ([`LocalTransport`]) or shipped its checksummed
//! `WireUpload` over a TCP connection (`transport::ServeCoordinator`).
//!
//! # Contract
//!
//! Per round the driver builds a [`RoundCall`] — the participant subset
//! (strictly ascending), the Eq. 16/17 dropout rates, the broadcast
//! phase, the previous round's [`CloseNote`]s and the shared stage
//! context — and hands it to the run's [`UploadSource`] together with a
//! [`UploadSink`]. The source produces one [`UploadEnvelope`] per subset
//! slot and **must deliver them in ascending client order**: every
//! downstream f32/f64 accumulation (the Eq. 4 shard folds, the loss sum)
//! runs in delivery order, so ascending delivery is what makes a round
//! bitwise identical across transports, worker counts and arrival
//! interleavings. [`LocalTransport`] gets the order for free from
//! [`ThreadPool::scoped_try_map`]; a socket transport must reorder
//! arrivals before delivering.
//!
//! The two driver-side sinks mirror the two round modes: `SyncFold`
//! absorbs each envelope into its Eq. 4 shard aggregator the moment it is
//! delivered (micro-batch streaming — encoded uploads never accumulate
//! fleet-wide), `DispatchSink` turns each envelope into an arrival event
//! on the virtual clock (DESIGN.md §7). Both replicate the pre-split
//! accumulation order operation for operation; the determinism batteries
//! (`parallel_round`, `semi_async`, `pool_determinism`,
//! `wire_equivalence`) are the acceptance test.

use std::collections::BTreeMap;

use crate::aggregation::{AggBackend, Aggregator};
use crate::baselines::{dispatch_mask_rng, DispatchMasks};
use crate::codec::{
    encode_upload_planes, recycle_wire_upload, CodecMode, EncodingMix, PlaneMix, PlaneMode,
    WireUpload,
};
use crate::config::ExpConfig;
use crate::data::FedDataset;
use crate::model::{extract_params_into, ModelSpec};
use crate::runtime::Runtime;
use crate::selection::{mask_from_scores, random_mask, select_mask, ChannelMask, Policy};
use crate::simnet::{downlink_bytes, ArrivalEvent, ClientClocks, EventQueue, RoundTiming};
use crate::tensor::{copy_tensors_into, Tensor};
use crate::util::threadpool::ThreadPool;

use super::client::{ClientState, PendingUpdate};
use super::engine::FedRun;
use super::scratch;
use super::state::{ClientParams, SparseResidual};

/// Per-participant output of the client stage, in transit from a
/// transport to the round driver: the encoded wire upload (the bytes the
/// uplink is charged for, folded by `absorb_wire` without any dense
/// expansion), the Eq. 7–12 timing, and the post-round state handoff
/// (the complement-of-mask residual). Envelopes decoded off a socket
/// carry `residual: None` — the residual stays on the agent that
/// trained, which rebases from its own copy (see `transport::agent`).
#[derive(Debug)]
pub struct UploadEnvelope {
    /// Client index.
    pub slot: usize,
    pub loss: f64,
    /// Masked value payload bytes (`ChannelMask::payload_bytes`) — the
    /// budget-accounting column and the Eq. 5 sparse-download charge.
    pub uploaded: usize,
    /// Aggregation weight m_n (the client's sample count).
    pub m_n: f32,
    /// The encoded upload; `wire.wire_len()` is the realized wire bytes.
    pub wire: WireUpload,
    /// The residual this client keeps once its download merges (`None` ⇒
    /// collapse to `Synced`; always `None` off the wire).
    pub residual: Option<SparseResidual>,
    /// Whether this client's download was charged as a full broadcast
    /// (the round's phase, or forced for a first-ever dispatch).
    pub full_broadcast: bool,
    /// Eq. 7–12 latencies of this dispatch.
    pub timing: RoundTiming,
}

/// End-of-round notification for one client whose upload the previous
/// round folded (or dropped to churn). A remote transport relays these
/// on the next dispatch so agents rebase their replicas exactly when an
/// in-process client would; [`LocalTransport`] ignores them (the driver
/// already rebased the shared `ClientState`s directly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CloseNote {
    /// Client index whose pending upload left flight.
    pub slot: usize,
    /// `true` ⇒ the upload was dropped by arrival-time churn: the client
    /// keeps its pre-dispatch base instead of rebasing.
    pub churned: bool,
}

/// One round's staging request: everything a transport needs to produce
/// the subset's [`UploadEnvelope`]s, borrowed disjointly from the
/// [`FedRun`] for the duration of the call.
pub struct RoundCall<'a> {
    /// Round number `t` (1-based; also the mask-selection RNG label).
    pub round: usize,
    /// Participants to stage, strictly ascending client ids.
    pub subset: &'a [usize],
    /// Eq. 16/17 dropout rates indexed by **absolute** client id.
    pub dropout: &'a [f64],
    /// The scheme's dispatch-mask policy for this round: who chooses
    /// each client's channel mask (the client post-training, or the
    /// server at dispatch) and from what (`baselines::DispatchMasks`).
    pub masks: &'a DispatchMasks,
    /// Whether this round's download phase is a full-model broadcast.
    pub full_broadcast: bool,
    /// Close notifications from the previous round (ascending by slot).
    pub notes: &'a [CloseNote],
    pub cfg: &'a ExpConfig,
    pub runtime: &'a Runtime,
    pub ds: &'a FedDataset,
    /// Coverage rates CR(k) per (layer, unit) of the global model.
    pub cr: &'a [Vec<f32>],
    /// The current global parameters (the round's download base).
    pub global: &'a [Tensor],
    pub policy: Policy,
    pub codec: CodecMode,
    pub plane: PlaneMode,
    pub plane_error: f64,
    pub pool: &'a ThreadPool,
    pub clients: &'a mut [ClientState],
}

/// Where a transport pushes staged uploads, one envelope per subset slot,
/// **in ascending client order** (see the module docs for why the order
/// is load-bearing).
pub trait UploadSink {
    fn deliver(&mut self, env: UploadEnvelope) -> anyhow::Result<()>;
}

/// A round-upload transport: given one round's [`RoundCall`], produce the
/// subset's envelopes and deliver them to the sink in ascending client
/// order. Implementations: [`LocalTransport`] (in-process, the default)
/// and `transport::ServeCoordinator` (TCP agents).
pub trait UploadSource: Send {
    fn round_uploads(
        &mut self,
        call: RoundCall<'_>,
        sink: &mut dyn UploadSink,
    ) -> anyhow::Result<()>;

    /// Tear down transport resources (connections, acceptor threads) at
    /// the end of a run. The in-process default has nothing to close.
    fn shutdown(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The default in-process transport: trains the subset on the run's own
/// worker pool, micro-batch by micro-batch, and delivers each envelope
/// as it is produced. Bitwise-identical to the pre-split engine — the
/// staging closure, the micro-batch partition and the delivery order are
/// all unchanged.
pub struct LocalTransport;

impl UploadSource for LocalTransport {
    fn round_uploads(
        &mut self,
        mut call: RoundCall<'_>,
        sink: &mut dyn UploadSink,
    ) -> anyhow::Result<()> {
        drive_subset(&mut call, sink)
    }
}

/// Micro-batch size of the per-client worker stage: enough items to keep
/// every worker busy, small enough that the transient dense models and
/// encoded uploads stay O(micro), never O(fleet). Numerics are
/// independent of this value (each client is a pure function of its own
/// state, and all downstream accumulations run in ascending client order
/// regardless of the batch partition).
pub(crate) fn micro_batch(pool: &ThreadPool) -> usize {
    (pool.workers() * 4).max(32)
}

/// Stage the whole subset micro-batch by micro-batch, delivering each
/// envelope in ascending client order. Shared by [`LocalTransport`] and
/// the agent side of the socket transport ([`FedRun::stage_for_dispatch`]).
pub(crate) fn drive_subset(
    call: &mut RoundCall<'_>,
    sink: &mut dyn UploadSink,
) -> anyhow::Result<()> {
    let subset = call.subset;
    let micro = micro_batch(call.pool);
    for chunk in subset.chunks(micro) {
        for env in stage_clients(call, chunk)? {
            sink.deliver(env)?;
        }
    }
    Ok(())
}

/// Local training + mask selection for the given clients, fanned over
/// the worker pool; outputs come back in ascending client order.
///
/// Every listed client is an independent work item: it owns a disjoint
/// `&mut ClientState` (its virtualized params, RNG stream, loss
/// bookkeeping), materializes its dense model (stateful schemes:
/// snapshot + residual; stateless: re-extracted from the current
/// global), trains against the shared thread-safe runtime, resolves its
/// upload mask per the round's [`DispatchMasks`] policy,
/// encodes the wire upload, gathers its post-round residual and
/// computes its Eq. 7–12 timing. `scoped_try_map` returns outputs in
/// input (= ascending client) order, so downstream f64 accumulations
/// run in the same order for every worker count.
pub(crate) fn stage_clients(
    call: &mut RoundCall<'_>,
    subset: &[usize],
) -> anyhow::Result<Vec<UploadEnvelope>> {
    let cfg = call.cfg;
    let masks = call.masks;
    // `Full`-masked schemes are stateless: clients re-extract from the
    // live global every dispatch and never keep residuals. Everything
    // else downloads mask-sparse between broadcasts and carries the
    // complement residual — whoever chose the mask.
    let stateful = !matches!(masks, DispatchMasks::Full);
    // Only client-chosen Algorithm-2 masks score the local update, which
    // needs the pre-training copy.
    let client_selects = matches!(masks, DispatchMasks::ClientChoice);
    let hetero = cfg.is_hetero();
    let round_label = call.round as u64;
    let rt = call.runtime;
    let ds = call.ds;
    let cr = call.cr;
    let gp = call.global;
    let policy = call.policy;
    let codec = call.codec;
    let plane = call.plane;
    let plane_error = call.plane_error;
    let dropout = call.dropout;
    let round_full_broadcast = call.full_broadcast;
    // Gather the disjoint `&mut ClientState` items by walking the fleet
    // slice once over the (ascending) subset — O(subset), not O(fleet):
    // with micro-batching this runs many times per round, so a
    // fleet-wide scan per call would be O(fleet²/micro).
    let mut items: Vec<(usize, &mut ClientState)> = Vec::with_capacity(subset.len());
    let mut rest: &mut [ClientState] = &mut *call.clients;
    let mut base = 0usize;
    for &n in subset {
        // Release-mode assert: the walk's `n - base` would otherwise
        // wrap on an unsorted subset and die far from the cause.
        assert!(n >= base, "subset must be strictly ascending (got {n} after {base})");
        let taken = std::mem::take(&mut rest);
        let (_, tail) = taken.split_at_mut(n - base);
        let (c, after) = tail.split_first_mut().expect("subset id out of range");
        items.push((n, c));
        rest = after;
        base = n + 1;
    }
    call.pool.scoped_try_map(
        items,
        |(n, c): (usize, &mut ClientState)| -> anyhow::Result<UploadEnvelope> {
            // The whole job runs against the worker's persistent
            // scratch arena: the dense materialization target, the
            // pre-training copy and the batch buffers are reused
            // across micro-batches and rounds (every consumer fully
            // overwrites what it reads — see `coordinator::scratch`;
            // `pool_determinism.rs` sentinel-poisons the arenas
            // between rounds to prove no stale byte leaks through).
            scratch::with_scratch(|s| -> anyhow::Result<UploadEnvelope> {
                // A first-ever dispatch always downloads the full
                // model: the client has never held the global, so a
                // mask-sparse slice would merge into nothing. A
                // ring-cap-evicted client is in the same boat — its
                // base snapshot is gone, so it is force-re-synced
                // with a full download charged to its link.
                let evicted = matches!(c.params, ClientParams::Evicted);
                let full_bc = round_full_broadcast || c.participations == 0 || evicted;
                // Materialize the dense model for this round only
                // (stateless schemes re-sync to the current global at
                // dispatch; an evicted stateful client re-syncs from
                // the live global the same way). Only client-chosen
                // masks need the pre-training copy to score against.
                if stateful {
                    if evicted {
                        extract_params_into(gp, &c.spec, &mut s.params);
                    } else {
                        c.params.materialize_into(&c.spec, &mut s.params);
                    }
                    if client_selects {
                        copy_tensors_into(&s.params, &mut s.params_before);
                    }
                } else {
                    extract_params_into(gp, &c.spec, &mut s.params);
                }
                let loss = c.train_local(
                    rt,
                    ds,
                    cfg.local_steps,
                    cfg.batch,
                    cfg.lr,
                    &mut s.params,
                    &mut s.x,
                    &mut s.y,
                )?;
                let mask = match masks {
                    // FedDD: the client scores its own update with its
                    // own RNG stream after training (Algorithm 2).
                    DispatchMasks::ClientChoice => {
                        let mut sel_rng = c.rng.split(round_label);
                        select_mask(
                            policy,
                            &c.spec,
                            &s.params_before,
                            &s.params,
                            if hetero { Some(cr) } else { None },
                            dropout[n],
                            &mut sel_rng,
                        )
                    }
                    DispatchMasks::Full => ChannelMask::full(&c.spec),
                    // Server-chosen masks are fixed at dispatch time;
                    // the mask RNG is a pure hash of (seed, round,
                    // client) — no client RNG state is consumed, so a
                    // serve agent recomputes the identical mask from
                    // the shared config.
                    DispatchMasks::Random => random_mask(
                        &c.spec,
                        dropout[n],
                        &mut dispatch_mask_rng(cfg.seed, round_label, n),
                    ),
                    DispatchMasks::Scored { scores } => {
                        mask_from_scores(&c.spec, scores, dropout[n])?
                    }
                };
                // Client-side encode: the bytes this upload really
                // puts on the wire (debug-asserted <= the
                // upload_bytes bound).
                let wire =
                    encode_upload_planes(&mask, &s.params, &c.spec, codec, plane, plane_error);
                // Budget-accounting payload: the serialized value
                // bytes under the realized planes (== the f32
                // `mask.payload_bytes` on the default plane).
                let uploaded = wire.payload_bytes();
                // Post-merge state handoff: nothing after a full
                // broadcast; else the complement-of-mask residual
                // (the channels the Eq. 5 download will not
                // overwrite).
                let residual = if !stateful || full_bc {
                    None
                } else {
                    SparseResidual::complement_of(&mask, &s.params, &c.spec)
                };
                // Eq. 7–12: the uplink is charged the *realized*
                // encoded bytes; the downlink the full model on
                // broadcast, else the Eq. 5 masked values only — the
                // mask is the client's own upload echoed back, so
                // its index/framing bytes are never re-billed
                // (DESIGN.md §6). The echo is always full-precision
                // f32 (the server merged the dequantized values), so
                // the sparse charge stays `mask.payload_bytes`
                // whatever the upload plane was.
                let down =
                    downlink_bytes(full_bc, c.u_bytes(), mask.payload_bytes(&c.spec)) as f64;
                let timing = RoundTiming {
                    t_down: c.profile.t_down(down),
                    t_cmp: c.profile.t_cmp(c.samples_per_round(cfg.local_steps, cfg.batch)),
                    t_up: c.profile.t_up(wire.wire_len() as f64),
                };
                Ok(UploadEnvelope {
                    slot: n,
                    loss,
                    uploaded,
                    m_n: c.m_n() as f32,
                    wire,
                    residual,
                    full_broadcast: full_bc,
                    timing,
                })
            })
        },
    )
}

/// The synchronous driver's sink: absorbs every delivered envelope into
/// its position's Eq. 4 shard aggregator the moment it arrives and
/// recycles the wire buffers, replicating the pre-split fold loop
/// operation for operation (loss/byte sums, encoding/plane mixes, the
/// running `max` round clock, the rebase list — all in delivery order).
pub(crate) struct SyncFold<'a> {
    subset: &'a [usize],
    shard_len: usize,
    shards: Vec<Aggregator>,
    /// Position in subset order (== deliveries so far).
    pos: usize,
    loss_sum: f64,
    uploaded: usize,
    wire_bytes: usize,
    encodings: EncodingMix,
    planes: PlaneMix,
    slowest: f64,
    rebases: Vec<(usize, Option<SparseResidual>)>,
}

/// What [`SyncFold::finish`] hands back to the driver.
pub(crate) struct SyncFoldOut {
    pub(crate) agg: Aggregator,
    pub(crate) loss_sum: f64,
    pub(crate) uploaded: usize,
    pub(crate) wire_bytes: usize,
    pub(crate) encodings: EncodingMix,
    pub(crate) planes: PlaneMix,
    pub(crate) slowest: f64,
    pub(crate) rebases: Vec<(usize, Option<SparseResidual>)>,
}

impl<'a> SyncFold<'a> {
    pub(crate) fn new(subset: &'a [usize], spec: &ModelSpec, backend: AggBackend) -> SyncFold<'a> {
        // Empty round: a single empty aggregator, merged and finalized
        // like always (finalize keeps the previous global untouched).
        let (n_shards, shard_len) = if subset.is_empty() {
            (1, 1)
        } else {
            let len = FedRun::shard_len(subset.len());
            (subset.len().div_ceil(len), len)
        };
        SyncFold {
            subset,
            shard_len,
            shards: (0..n_shards).map(|_| Aggregator::new(spec, backend)).collect(),
            pos: 0,
            loss_sum: 0.0,
            uploaded: 0,
            wire_bytes: 0,
            encodings: EncodingMix::default(),
            planes: PlaneMix::default(),
            slowest: 0.0,
            rebases: Vec::with_capacity(subset.len()),
        }
    }

    pub(crate) fn finish(self) -> anyhow::Result<SyncFoldOut> {
        anyhow::ensure!(
            self.pos == self.subset.len(),
            "sync round closed with {} of {} uploads delivered",
            self.pos,
            self.subset.len()
        );
        Ok(SyncFoldOut {
            agg: Aggregator::merge(self.shards)?,
            loss_sum: self.loss_sum,
            uploaded: self.uploaded,
            wire_bytes: self.wire_bytes,
            encodings: self.encodings,
            planes: self.planes,
            slowest: self.slowest,
            rebases: self.rebases,
        })
    }
}

impl UploadSink for SyncFold<'_> {
    fn deliver(&mut self, env: UploadEnvelope) -> anyhow::Result<()> {
        let expected = self.subset.get(self.pos).copied();
        anyhow::ensure!(
            expected == Some(env.slot),
            "upload for slot {} delivered at position {} (expected {:?}) — \
             sources must deliver the subset in ascending order",
            env.slot,
            self.pos,
            expected
        );
        self.loss_sum += env.loss;
        self.uploaded += env.uploaded;
        self.wire_bytes += env.wire.wire_len();
        self.encodings.merge(env.wire.mix());
        self.planes.merge(env.wire.plane_mix());
        self.shards[self.pos / self.shard_len].absorb_wire(&env.wire, env.m_n)?;
        // The upload is folded; its buffers go back to the encode
        // freelist for the next micro-batch.
        recycle_wire_upload(env.wire);
        self.pos += 1;
        self.slowest = self.slowest.max(env.timing.total());
        self.rebases.push((env.slot, env.residual));
        Ok(())
    }
}

/// The semi-asynchronous driver's sink: every delivered envelope becomes
/// an arrival event on the virtual clock (DESIGN.md §7) — the client's
/// own finish instant on the min-heap, a busy-until mark on its clock,
/// and a buffered [`PendingUpdate`] for the fold at whichever round's
/// close observes the arrival.
pub(crate) struct DispatchSink<'a> {
    /// Dispatch round `t`.
    pub(crate) round: usize,
    /// Virtual time the round opened at.
    pub(crate) round_start: f64,
    pub(crate) events: &'a mut EventQueue,
    pub(crate) clocks: &'a mut ClientClocks,
    pub(crate) pending: &'a mut BTreeMap<usize, PendingUpdate>,
}

impl UploadSink for DispatchSink<'_> {
    fn deliver(&mut self, env: UploadEnvelope) -> anyhow::Result<()> {
        let finish = self.round_start + env.timing.total();
        self.events.push(ArrivalEvent {
            finish,
            client: env.slot,
            dispatch_round: self.round,
        });
        self.clocks.dispatch(env.slot, finish);
        self.pending.insert(
            env.slot,
            PendingUpdate {
                wire: env.wire,
                residual: env.residual,
                loss: env.loss,
                uploaded: env.uploaded,
                full_broadcast: env.full_broadcast,
            },
        );
        Ok(())
    }
}

/// Agent-side record of a dispatched-but-unclosed upload (serve mode):
/// the residual and broadcast flag the agent's replica needs to rebase
/// itself when the close note arrives — the exact payload a
/// [`PendingUpdate`] carries for the in-process engine, minus the wire
/// (which shipped to the server).
#[derive(Debug)]
pub struct AgentPending {
    pub residual: Option<SparseResidual>,
    pub full_broadcast: bool,
}
