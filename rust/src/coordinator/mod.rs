//! The FedDD coordinator (L3): the synchronous FL round engine of
//! Algorithm 1, with the dropout-rate allocation (solver), uploaded-
//! parameter selection (selection), mask-weighted aggregation
//! (aggregation) and virtual-time accounting (simnet) wired together.
//!
//! The same engine runs the client-selection baselines (FedAvg / FedCS /
//! Oort) under an identical byte budget so every comparison in the paper's
//! evaluation section is apples-to-apples — see `baselines`.

mod client;
mod engine;

pub use client::*;
pub use engine::*;
