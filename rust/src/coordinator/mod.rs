//! The FedDD coordinator (L3): the FL round engine of Algorithm 1 —
//! synchronous barrier or semi-asynchronous event scheduler
//! (`round_mode`, DESIGN.md §7) — with the dropout-rate allocation
//! (solver), uploaded-parameter selection (selection), mask-weighted /
//! staleness-discounted aggregation (aggregation) and virtual-time
//! accounting (simnet) wired together.
//!
//! The same engine runs the client-selection baselines (FedAvg / FedCS /
//! Oort) under an identical byte budget so every comparison in the paper's
//! evaluation section is apples-to-apples — see `baselines`.

mod client;
mod engine;
// The transport-agnostic ingest layer: round drivers consume uploads
// through the `UploadSource`/`UploadSink` traits, with `LocalTransport`
// (in-process staging) as the default implementation and the socket
// transport (`crate::transport`) as the serve-mode one.
mod ingest;
// Per-worker scratch arenas are module-internal: jobs reach them through
// `scratch::with_scratch` on their own thread, and tests poison them
// through `FedRun::poison_worker_scratch` (which covers *every* worker —
// a lone `poison_thread_scratch` call would touch only the caller).
mod scratch;
mod state;

pub use client::*;
pub use engine::*;
pub use ingest::*;
pub use state::*;
