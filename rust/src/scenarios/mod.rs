//! Scenario-matrix evaluation harness: a registry of named evaluation
//! scenarios crossed with schemes × seeds at smoke/small/medium tiers,
//! a per-cell report emitter (one-line-per-cell JSON + Markdown +
//! auto-regenerated `reports/INDEX.md`) and a regression-only compare
//! mode (`feddd matrix --compare A.json B.json`, mirrored by
//! `ci/matrix_diff.py`).
//!
//! Every registered scenario is documented in `docs/SCENARIOS.md` — the
//! catalogue and the registry are kept in lockstep by
//! `rust/tests/scenario_matrix.rs`, which fails when a registered name
//! has no catalogue heading. The matrix is where FedDD's multi-scenario
//! claims (Table 4/5, the §6.7 rare-class result) meet the
//! dropout-family baselines: random Federated Dropout (Caldas et al.,
//! arXiv:1812.07210) and Adaptive Federated Dropout (Bouacida et al.,
//! arXiv:2011.04050) only become comparable-at-a-glance once every
//! scenario × scheme × seed cell lands in one report with
//! accuracy / wire-bytes / virtual-time / staleness columns.
//!
//! # Determinism contract (DESIGN.md §Scenario-Matrix)
//!
//! Every cell runs on the virtual-clock/bitwise-replay machinery: a cell
//! is a pure function of `(scenario, scheme, seed, tier)`. The cell
//! record holds **only deterministic columns** — the nondeterministic
//! `wall_seconds` never enters a report — and serializes through the
//! sorted-key [`Json`] writer, so a report is byte-identical across
//! worker counts, runs and hosts (golden-tested for workers {1, 4}).

use std::path::{Path, PathBuf};

use crate::config::ExpConfig;
use crate::coordinator::run_experiment;
use crate::metrics::RunResult;
use crate::util::json::{self, Json};

/// The schemes every matrix cell row is crossed with by default: FedDD
/// plus the selection baselines (fedavg/fedcs/oort) and the
/// dropout-family baselines (fed_dropout/afd) sharing its
/// codec/simnet stack — `baselines::SCHEME_NAMES`.
pub const MATRIX_SCHEMES: &[&str] = crate::baselines::SCHEME_NAMES;

/// Matrix scale tier. The tier sets the *scale* knobs (fleet size,
/// rounds, per-client data); the scenario then sets the *shape* knobs on
/// top. Smoke keeps every cell on the FC/`mlp` stack so the whole matrix
/// runs on the pure-Rust native executor (no compiled artifacts needed);
/// small/medium may substitute the paper-exact conv models where the
/// traced table demands them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Smoke,
    Small,
    Medium,
}

impl Tier {
    pub fn by_name(name: &str) -> anyhow::Result<Tier> {
        match name {
            "smoke" => Ok(Tier::Smoke),
            "small" => Ok(Tier::Small),
            "medium" => Ok(Tier::Medium),
            _ => anyhow::bail!("unknown tier {name:?} (smoke|small|medium)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Tier::Smoke => "smoke",
            Tier::Small => "small",
            Tier::Medium => "medium",
        }
    }

    /// Apply this tier's scale knobs to a default config.
    fn apply(&self, cfg: &mut ExpConfig) {
        match self {
            Tier::Smoke => {
                cfg.n_clients = 8;
                cfg.rounds = 6;
                cfg.local_steps = 2;
                cfg.train_per_client = 48;
                cfg.test_n = 128;
                cfg.eval_every = 3;
            }
            Tier::Small => {
                cfg.n_clients = 20;
                cfg.rounds = 30;
                cfg.local_steps = 4;
                cfg.train_per_client = 120;
                cfg.test_n = 384;
                cfg.eval_every = 5;
            }
            Tier::Medium => {
                cfg.n_clients = 50;
                cfg.rounds = 80;
                cfg.local_steps = 4;
                cfg.train_per_client = 240;
                cfg.test_n = 640;
                cfg.eval_every = 10;
            }
        }
    }

    pub fn all() -> [Tier; 3] {
        [Tier::Smoke, Tier::Small, Tier::Medium]
    }
}

/// One registered evaluation scenario: a named config transform applied
/// on top of the tier's scale knobs. See `docs/SCENARIOS.md` for the
/// catalogue entry every scenario must have (knobs, paper claim,
/// expected signal, per-tier run lines).
#[derive(Clone, Copy)]
pub struct Scenario {
    /// Registry key (also the `docs/SCENARIOS.md` heading).
    pub name: &'static str,
    /// One-line human description for `feddd matrix --list`.
    pub title: &'static str,
    /// Paper table/claim this scenario traces to, or "beyond-paper".
    pub claim: &'static str,
    apply: fn(&mut ExpConfig, Tier),
}

impl Scenario {
    /// The full cell config for this scenario at a tier and seed:
    /// defaults → tier scale → scenario shape.
    pub fn config(&self, tier: Tier, seed: u64) -> ExpConfig {
        let mut cfg = ExpConfig::default();
        cfg.seed = seed;
        tier.apply(&mut cfg);
        (self.apply)(&mut cfg, tier);
        cfg
    }
}

fn apply_baseline_iid(_cfg: &mut ExpConfig, _tier: Tier) {
    // Table 4 defaults at tier scale: IID partition, simulated fleet,
    // synchronous rounds. The reference point every other cell is read
    // against.
}

fn apply_geo_testbed(cfg: &mut ExpConfig, tier: Tier) {
    cfg.fleet = "testbed".into();
    cfg.n_clients = 10; // the Table 5 fleet is exactly 10 geo profiles
    cfg.h = 1;
    if tier == Tier::Medium {
        // Paper-exact Table 5 stack (needs compiled conv artifacts).
        cfg.dataset = "cifar10".into();
        cfg.model = "cnn2".into();
        cfg.lr = 0.02;
        cfg.local_steps = 3;
    }
}

fn apply_class_imbalance(cfg: &mut ExpConfig, _tier: Tier) {
    cfg.partition = "noniid_b".into();
    cfg.rare_classes = vec![0, 1, 2];
    cfg.rare_ratio = 0.4;
    cfg.a_server = 0.2;
    cfg.d_max = 0.85;
}

fn apply_hetero_fleet(cfg: &mut ExpConfig, tier: Tier) {
    cfg.n_clients = 10;
    if tier != Tier::Smoke {
        // Model heterogeneity proper: het_b sub-models 1..5 round-robin
        // (needs compiled conv artifacts); smoke keeps the homogeneous
        // mlp and exercises only the device heterogeneity + plumbing.
        cfg.dataset = "cifar10".into();
        cfg.model = "het_b".into();
        cfg.width_pct = 25;
        cfg.lr = 0.02;
    }
}

fn semi_async_base(cfg: &mut ExpConfig) {
    cfg.round_mode = "semi_async".into();
    cfg.quorum = 0.7;
    cfg.staleness_beta = 0.5;
}

fn apply_diurnal(cfg: &mut ExpConfig, _tier: Tier) {
    semi_async_base(cfg);
    cfg.trace = "diurnal".into();
    cfg.trace_period_s = 600.0;
}

fn apply_flash_crowd(cfg: &mut ExpConfig, _tier: Tier) {
    semi_async_base(cfg);
    cfg.trace = "flash_crowd".into();
    cfg.trace_period_s = 600.0;
}

fn apply_churn(cfg: &mut ExpConfig, _tier: Tier) {
    semi_async_base(cfg);
    cfg.trace = "churn".into();
    cfg.churn_rate = 0.2;
}

/// The scenario registry. Order is report order. Every entry must have a
/// `docs/SCENARIOS.md` heading (`## \`name\``) — enforced by
/// `rust/tests/scenario_matrix.rs::catalogue_covers_every_scenario`.
pub fn registry() -> &'static [Scenario] {
    const REGISTRY: &[Scenario] = &[
        Scenario {
            name: "baseline_iid",
            title: "IID / simulated fleet / sync rounds (the reference cell)",
            claim: "Table 4 simulation defaults",
            apply: apply_baseline_iid,
        },
        Scenario {
            name: "geo_testbed",
            title: "10-client geo-distributed testbed fleet, h=1",
            claim: "Table 5 / Fig. 18",
            apply: apply_geo_testbed,
        },
        Scenario {
            name: "class_imbalance",
            title: "non-IID(b) with rare classes {0,1,2} at 40% share",
            claim: "Fig. 21 / §6.7 rare-class generalization",
            apply: apply_class_imbalance,
        },
        Scenario {
            name: "hetero_fleet",
            title: "heterogeneous fleet (het_b sub-models above smoke tier)",
            claim: "Fig. 9-10 model-heterogeneous setting",
            apply: apply_hetero_fleet,
        },
        Scenario {
            name: "diurnal",
            title: "semi-async with a rolling half of the fleet offline",
            claim: "beyond-paper (availability dynamics)",
            apply: apply_diurnal,
        },
        Scenario {
            name: "flash_crowd",
            title: "semi-async; ~10% vanguard, whole fleet joins at t=period",
            claim: "beyond-paper (arrival burst)",
            apply: apply_flash_crowd,
        },
        Scenario {
            name: "churn",
            title: "semi-async with 20% of in-flight uploads dropping mid-round",
            claim: "beyond-paper (mid-round churn/reconnection)",
            apply: apply_churn,
        },
    ];
    REGISTRY
}

/// Look up a registered scenario by name.
pub fn by_name(name: &str) -> anyhow::Result<&'static Scenario> {
    registry().iter().find(|s| s.name == name).ok_or_else(|| {
        let names: Vec<&str> = registry().iter().map(|s| s.name).collect();
        anyhow::anyhow!("unknown scenario {name:?} (one of: {})", names.join(", "))
    })
}

/// The shared config shape the `examples/*.rs` wrappers run: a registry
/// scenario at a tier, seeded with the repo default, fanned over all
/// cores, against the default artifacts directory. Keeping the examples
/// on this single entry point is what makes scenario configs live in
/// exactly one place.
pub fn example_config(scenario: &str, tier: Tier) -> anyhow::Result<ExpConfig> {
    let mut cfg = by_name(scenario)?.config(tier, 17);
    cfg.workers = 0; // one worker per core
    let dir = crate::runtime::default_artifacts_dir();
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    Ok(cfg)
}

/// One matrix cell: the deterministic summary of a single
/// `(scenario, scheme, seed, tier)` run. Never includes wall-clock time.
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    pub scenario: String,
    pub scheme: String,
    pub tier: String,
    pub seed: u64,
    pub rounds: usize,
    /// Final-eval overall accuracy.
    pub accuracy: f64,
    /// Final-eval mean accuracy over the scenario's rare classes
    /// (`None` when the scenario has no rare-class holdout).
    pub rare_accuracy: Option<f64>,
    /// Total masked payload bytes uploaded across the run.
    pub uploaded_bytes: usize,
    /// Total realized wire bytes across the run.
    pub wire_bytes: usize,
    /// Virtual time at the end of the run (seconds).
    pub v_time: f64,
    pub mean_staleness: f64,
    pub mean_stragglers: f64,
    /// Mean folded uploads per round.
    pub mean_participants: f64,
    /// Total uploads dropped by arrival-time churn.
    pub churned: usize,
    pub peak_client_state_bytes: usize,
}

impl Cell {
    /// Build the cell from a finished run and the config that produced it.
    pub fn from_run(cfg: &ExpConfig, tier: Tier, scenario: &str, r: &RunResult) -> Cell {
        Cell {
            scenario: scenario.to_string(),
            scheme: cfg.scheme.clone(),
            tier: tier.name().to_string(),
            seed: cfg.seed,
            rounds: cfg.rounds,
            accuracy: r.final_accuracy().unwrap_or(0.0),
            rare_accuracy: if cfg.rare_classes.is_empty() {
                None
            } else {
                r.rare_class_accuracy(&cfg.rare_classes)
            },
            uploaded_bytes: r.total_uploaded(),
            wire_bytes: r.total_wire_bytes(),
            v_time: r.final_v_time(),
            mean_staleness: r.mean_staleness(),
            mean_stragglers: r.mean_stragglers(),
            mean_participants: r.mean_participants(),
            churned: r.total_churned(),
            peak_client_state_bytes: r.peak_client_state_bytes(),
        }
    }

    /// The compare-mode identity of this cell.
    pub fn key(&self) -> String {
        format!("{}/{}/seed{}/{}", self.scenario, self.scheme, self.seed, self.tier)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::s(&self.scenario)),
            ("scheme", Json::s(&self.scheme)),
            ("tier", Json::s(&self.tier)),
            ("seed", Json::Num(self.seed as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("accuracy", Json::Num(self.accuracy)),
            ("rare_accuracy", self.rare_accuracy.map_or(Json::Null, Json::Num)),
            ("uploaded_bytes", Json::Num(self.uploaded_bytes as f64)),
            ("wire_bytes", Json::Num(self.wire_bytes as f64)),
            ("v_time", Json::Num(self.v_time)),
            ("mean_staleness", Json::Num(self.mean_staleness)),
            ("mean_stragglers", Json::Num(self.mean_stragglers)),
            ("mean_participants", Json::Num(self.mean_participants)),
            ("churned", Json::Num(self.churned as f64)),
            ("peak_client_state_bytes", Json::Num(self.peak_client_state_bytes as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Cell> {
        Ok(Cell {
            scenario: j.req_str("scenario")?.to_string(),
            scheme: j.req_str("scheme")?.to_string(),
            tier: j.req_str("tier")?.to_string(),
            seed: j.req_f64("seed")? as u64,
            rounds: j.req_usize("rounds")?,
            accuracy: j.req_f64("accuracy")?,
            rare_accuracy: j.get("rare_accuracy").and_then(|v| v.as_f64()),
            uploaded_bytes: j.req_usize("uploaded_bytes")?,
            wire_bytes: j.req_usize("wire_bytes")?,
            v_time: j.req_f64("v_time")?,
            mean_staleness: j.req_f64("mean_staleness")?,
            mean_stragglers: j.req_f64("mean_stragglers")?,
            mean_participants: j.req_f64("mean_participants")?,
            churned: j.req_usize("churned")?,
            peak_client_state_bytes: j.req_usize("peak_client_state_bytes")?,
        })
    }
}

/// What to run: the matrix cross product and the execution knobs.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub tier: Tier,
    /// Report label (part of the output filename).
    pub label: String,
    /// Scenario names to run; empty = the whole registry.
    pub scenarios: Vec<String>,
    /// Schemes to cross with; empty = [`MATRIX_SCHEMES`].
    pub schemes: Vec<String>,
    pub seeds: Vec<u64>,
    /// Worker threads per cell run (cells run one at a time; the
    /// parallelism lives inside the round engine).
    pub workers: usize,
    pub artifacts_dir: String,
}

/// One finished matrix run: the spec echo plus every cell, in
/// (registry, scheme, seed) order.
#[derive(Clone, Debug)]
pub struct MatrixReport {
    pub tier: String,
    pub label: String,
    pub scenarios: Vec<String>,
    pub schemes: Vec<String>,
    pub seeds: Vec<u64>,
    pub cells: Vec<Cell>,
}

impl MatrixReport {
    /// Report filename stem (`MATRIX_<tier>_<label>`), label sanitized to
    /// `[A-Za-z0-9_-]`.
    pub fn file_stem(&self) -> String {
        let label: String = self
            .label
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || c == '_' || c == '-' {
                    c
                } else {
                    '-'
                }
            })
            .collect();
        format!("MATRIX_{}_{}", self.tier, label)
    }

    /// One-line-per-cell JSON: a `matrix` meta object, then each cell as
    /// one compact line inside `cells`. Valid JSON for any parser; the
    /// line-per-cell layout keeps text diffs readable cell by cell.
    pub fn to_json_string(&self) -> String {
        let scenarios: Vec<Json> = self.scenarios.iter().map(|s| Json::s(s)).collect();
        let schemes: Vec<Json> = self.schemes.iter().map(|s| Json::s(s)).collect();
        let seeds: Vec<Json> = self.seeds.iter().map(|&s| Json::Num(s as f64)).collect();
        let meta = Json::obj(vec![
            ("tier", Json::s(&self.tier)),
            ("label", Json::s(&self.label)),
            ("scenarios", Json::Arr(scenarios)),
            ("schemes", Json::Arr(schemes)),
            ("seeds", Json::Arr(seeds)),
        ]);
        let mut out = String::new();
        out.push_str("{\"matrix\":");
        out.push_str(&meta.to_string_compact());
        out.push_str(",\n\"cells\":[\n");
        for (i, c) in self.cells.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&c.to_json().to_string_compact());
        }
        out.push_str("\n]}\n");
        out
    }

    pub fn from_json(j: &Json) -> anyhow::Result<MatrixReport> {
        let meta = j.req("matrix")?;
        let strs = |key: &str| -> Vec<String> {
            let mut out = Vec::new();
            if let Some(arr) = meta.get(key).and_then(|v| v.as_arr()) {
                for x in arr {
                    if let Some(s) = x.as_str() {
                        out.push(s.to_string());
                    }
                }
            }
            out
        };
        let mut seeds = Vec::new();
        if let Some(arr) = meta.get("seeds").and_then(|v| v.as_arr()) {
            for x in arr {
                if let Some(v) = x.as_f64() {
                    seeds.push(v as u64);
                }
            }
        }
        let mut cells = Vec::new();
        for c in j.req_arr("cells")? {
            cells.push(Cell::from_json(c)?);
        }
        Ok(MatrixReport {
            tier: meta.req_str("tier")?.to_string(),
            label: meta.req_str("label")?.to_string(),
            scenarios: strs("scenarios"),
            schemes: strs("schemes"),
            seeds,
            cells,
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<MatrixReport> {
        Self::from_json(&json::from_file(path)?)
    }

    /// The per-run Markdown table (every cell, report order).
    pub fn markdown(&self) -> String {
        let mut out = format!(
            "# Scenario matrix — tier `{}`, label `{}`\n\n\
             {} cells: {} scenario(s) × {} scheme(s) × {} seed(s).\n\n",
            self.tier,
            self.label,
            self.cells.len(),
            self.scenarios.len(),
            self.schemes.len(),
            self.seeds.len(),
        );
        out.push_str(
            "| scenario | scheme | seed | acc | rare acc | wire KiB | v-time s \
             | staleness | stragglers | churned |\n\
             |---|---|---|---|---|---|---|---|---|---|\n",
        );
        for c in &self.cells {
            let rare = match c.rare_accuracy {
                Some(v) => format!("{v:.4}"),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "| {} | {} | {} | {:.4} | {} | {:.1} | {:.1} | {:.2} | {:.2} | {} |\n",
                c.scenario,
                c.scheme,
                c.seed,
                c.accuracy,
                rare,
                c.wire_bytes as f64 / 1024.0,
                c.v_time,
                c.mean_staleness,
                c.mean_stragglers,
                c.churned,
            ));
        }
        out
    }
}

/// Run the matrix: every requested scenario × scheme × seed at the
/// spec's tier, sequentially (the worker pool parallelizes inside each
/// cell). Cells are pure functions of their key, so a spec always
/// produces the same report bytes.
pub fn run_matrix(spec: &MatrixSpec) -> anyhow::Result<MatrixReport> {
    let scenario_names: Vec<String> = if spec.scenarios.is_empty() {
        registry().iter().map(|s| s.name.to_string()).collect()
    } else {
        spec.scenarios.clone()
    };
    let schemes: Vec<String> = if spec.schemes.is_empty() {
        MATRIX_SCHEMES.iter().map(|s| s.to_string()).collect()
    } else {
        spec.schemes.clone()
    };
    anyhow::ensure!(!spec.seeds.is_empty(), "matrix needs at least one seed");
    let mut cells = Vec::new();
    for name in &scenario_names {
        let sc = by_name(name)?;
        for scheme in &schemes {
            for &seed in &spec.seeds {
                let mut cfg = sc.config(spec.tier, seed);
                cfg.scheme = scheme.clone();
                cfg.workers = spec.workers;
                cfg.artifacts_dir = spec.artifacts_dir.clone();
                let r = run_experiment(cfg.clone())?;
                let cell = Cell::from_run(&cfg, spec.tier, name, &r);
                println!(
                    "matrix cell {}: acc={:.4} wire={}KiB vt={:.1}s",
                    cell.key(),
                    cell.accuracy,
                    cell.wire_bytes / 1024,
                    cell.v_time,
                );
                cells.push(cell);
            }
        }
    }
    Ok(MatrixReport {
        tier: spec.tier.name().to_string(),
        label: spec.label.clone(),
        scenarios: scenario_names,
        schemes,
        seeds: spec.seeds.clone(),
        cells,
    })
}

/// Write a report's JSON + Markdown into `out_dir` and regenerate
/// `out_dir/INDEX.md` from every `MATRIX_*.json` present. Returns the
/// JSON path.
pub fn write_report(out_dir: &Path, report: &MatrixReport) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(out_dir)?;
    let stem = report.file_stem();
    let json_path = out_dir.join(format!("{stem}.json"));
    std::fs::write(&json_path, report.to_json_string())?;
    std::fs::write(out_dir.join(format!("{stem}.md")), report.markdown())?;
    write_index(out_dir)?;
    Ok(json_path)
}

/// Regenerate `INDEX.md` by scanning `out_dir` for matrix reports. Rows
/// are filename-sorted, so the index is deterministic for a given set of
/// reports (no timestamps).
pub fn write_index(out_dir: &Path) -> anyhow::Result<()> {
    let mut files: Vec<String> = std::fs::read_dir(out_dir)?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("MATRIX_") && n.ends_with(".json"))
        .collect();
    files.sort();
    let mut out = String::from(
        "# Matrix report index\n\n\
         Auto-generated by `feddd matrix` — regenerated on every report \
         write; do not edit by hand.\n\n\
         | report | tier | label | cells | scenarios | schemes | seeds |\n\
         |---|---|---|---|---|---|---|\n",
    );
    for f in &files {
        let r = MatrixReport::load(&out_dir.join(f))?;
        out.push_str(&format!(
            "| [{stem}]({stem}.md) | {} | {} | {} | {} | {} | {} |\n",
            r.tier,
            r.label,
            r.cells.len(),
            r.scenarios.len(),
            r.schemes.len(),
            r.seeds.len(),
            stem = f.trim_end_matches(".json"),
        ));
    }
    std::fs::write(out_dir.join("INDEX.md"), out)?;
    Ok(())
}

/// Compare verdict for a baseline/current report pair.
#[derive(Clone, Debug, Default)]
pub struct MatrixDiff {
    /// Hard failures: metric regressions and vanished cells.
    pub regressions: Vec<String>,
    /// Informational notes (new cells). Never fatal.
    pub notes: Vec<String>,
}

impl MatrixDiff {
    pub fn has_failures(&self) -> bool {
        !self.regressions.is_empty()
    }

    /// Regression-only report: failures and notes, never the full table.
    pub fn markdown(&self) -> String {
        let mut out = String::from("# Matrix diff\n\n");
        if self.regressions.is_empty() {
            out.push_str("No regressions.\n");
        } else {
            out.push_str(&format!("{} regression(s):\n\n", self.regressions.len()));
            for r in &self.regressions {
                out.push_str(&format!("- FAIL {r}\n"));
            }
        }
        if !self.notes.is_empty() {
            out.push('\n');
            for n in &self.notes {
                out.push_str(&format!("- note: {n}\n"));
            }
        }
        out
    }
}

/// Compare two reports, printing only regressions (the rules are
/// mirrored exactly by `ci/matrix_diff.py`; DESIGN.md §Scenario-Matrix):
///
/// * cells match on `(scenario, scheme, seed, tier)`;
/// * accuracy may not drop by more than `tol_acc` (absolute);
/// * the deterministic byte totals (`wire_bytes`, `uploaded_bytes`) may
///   not grow at all;
/// * a cell present only in the current report is a **note** — there is
///   no baseline, so no delta or ratio is ever computed for it (the
///   undefined-division rule);
/// * a cell that vanished from the current report is a **failure**: a
///   gate that silently stops covering a cell is itself a regression.
pub fn compare_reports(
    baseline: &MatrixReport,
    current: &MatrixReport,
    tol_acc: f64,
) -> MatrixDiff {
    let mut diff = MatrixDiff::default();
    if current.cells.is_empty() {
        diff.regressions.push("current report has no cells".to_string());
        return diff;
    }
    let cur: std::collections::BTreeMap<String, &Cell> =
        current.cells.iter().map(|c| (c.key(), c)).collect();
    let base: std::collections::BTreeMap<String, &Cell> =
        baseline.cells.iter().map(|c| (c.key(), c)).collect();
    for (key, b) in &base {
        let Some(c) = cur.get(key) else {
            diff.regressions.push(format!(
                "{key}: cell vanished from the current report — its gate would be \
                 silently disarmed"
            ));
            continue;
        };
        if c.accuracy < b.accuracy - tol_acc {
            diff.regressions.push(format!(
                "{key}: accuracy {:.4} -> {:.4} (drop {:.4} > tol {tol_acc})",
                b.accuracy,
                c.accuracy,
                b.accuracy - c.accuracy,
            ));
        }
        if c.wire_bytes > b.wire_bytes {
            diff.regressions.push(format!(
                "{key}: wire_bytes {} -> {} (deterministic byte total may not grow)",
                b.wire_bytes,
                c.wire_bytes,
            ));
        }
        if c.uploaded_bytes > b.uploaded_bytes {
            diff.regressions.push(format!(
                "{key}: uploaded_bytes {} -> {} (deterministic byte total may not grow)",
                b.uploaded_bytes,
                c.uploaded_bytes,
            ));
        }
    }
    for key in cur.keys() {
        if !base.contains_key(key) {
            diff.notes.push(format!("new cell {key} — no baseline, no delta computed"));
        }
    }
    diff
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_scenario_validates_at_every_tier() {
        for sc in registry() {
            for tier in Tier::all() {
                let cfg = sc.config(tier, 17);
                cfg.validate().unwrap_or_else(|e| {
                    panic!("scenario {} invalid at {}: {e}", sc.name, tier.name())
                });
            }
        }
    }

    #[test]
    fn smoke_tier_stays_on_the_native_fc_stack() {
        // The CI matrix leg runs without compiled artifacts: every smoke
        // cell must stay on the mlp family the native executor supports.
        for sc in registry() {
            let cfg = sc.config(Tier::Smoke, 17);
            assert_eq!(cfg.model, "mlp", "scenario {} leaves the FC stack at smoke", sc.name);
            assert_eq!(cfg.dataset, "mnist");
        }
    }

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        let mut names: Vec<&str> = registry().iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), registry().len(), "duplicate scenario name");
        for sc in registry() {
            assert_eq!(by_name(sc.name).unwrap().name, sc.name);
        }
        assert!(by_name("nope").is_err());
    }

    fn sample_cell() -> Cell {
        Cell {
            scenario: "baseline_iid".into(),
            scheme: "feddd".into(),
            tier: "smoke".into(),
            seed: 17,
            rounds: 6,
            accuracy: 0.8125,
            rare_accuracy: None,
            uploaded_bytes: 123_456,
            wire_bytes: 130_000,
            v_time: 901.5,
            mean_staleness: 0.25,
            mean_stragglers: 1.5,
            mean_participants: 7.0,
            churned: 0,
            peak_client_state_bytes: 40_000,
        }
    }

    #[test]
    fn cell_round_trips_through_json() {
        let c = sample_cell();
        let text = c.to_json().to_string_compact();
        let back = Cell::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(c, back);
        // rare_accuracy: Some survives too, via the Null-vs-Num encoding
        let mut r = sample_cell();
        r.rare_accuracy = Some(0.625);
        let text = r.to_json().to_string_compact();
        let back = Cell::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(r, back);
    }

    fn sample_report(cells: Vec<Cell>) -> MatrixReport {
        MatrixReport {
            tier: "smoke".into(),
            label: "test".into(),
            scenarios: vec!["baseline_iid".into()],
            schemes: vec!["feddd".into()],
            seeds: vec![17],
            cells,
        }
    }

    #[test]
    fn report_round_trips_and_is_one_line_per_cell() {
        let mut c2 = sample_cell();
        c2.scheme = "fedavg".into();
        let rep = sample_report(vec![sample_cell(), c2]);
        let text = rep.to_json_string();
        // one line per cell: both cell objects sit on their own lines
        let mut cell_lines = 0;
        for l in text.lines() {
            if l.trim_start().starts_with("{\"accuracy\"") {
                cell_lines += 1;
            }
        }
        assert_eq!(cell_lines, 2, "cells must serialize one per line:\n{text}");
        let back = MatrixReport::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.cells, rep.cells);
        assert_eq!(back.tier, "smoke");
        assert_eq!(back.label, "test");
        assert_eq!(back.seeds, vec![17]);
    }

    #[test]
    fn markdown_has_a_row_per_cell() {
        let rep = sample_report(vec![sample_cell()]);
        let md = rep.markdown();
        assert!(md.contains("| scenario | scheme |"));
        assert!(md.contains("| baseline_iid | feddd | 17 | 0.8125 | - |"), "{md}");
    }

    #[test]
    fn compare_green_on_identical_reports() {
        let rep = sample_report(vec![sample_cell()]);
        let diff = compare_reports(&rep, &rep, 0.01);
        assert!(!diff.has_failures(), "{:?}", diff.regressions);
        assert!(diff.notes.is_empty());
    }

    #[test]
    fn compare_fails_on_accuracy_drop_beyond_tol() {
        let base = sample_report(vec![sample_cell()]);
        let mut worse = sample_cell();
        worse.accuracy -= 0.05;
        let cur = sample_report(vec![worse]);
        let diff = compare_reports(&base, &cur, 0.01);
        assert!(diff.has_failures());
        assert!(diff.regressions[0].contains("accuracy"), "{:?}", diff.regressions);
        // within tolerance passes
        let mut ok = sample_cell();
        ok.accuracy -= 0.005;
        assert!(!compare_reports(&base, &sample_report(vec![ok]), 0.01).has_failures());
    }

    #[test]
    fn compare_fails_on_any_byte_growth() {
        let base = sample_report(vec![sample_cell()]);
        let mut fat = sample_cell();
        fat.wire_bytes += 1;
        let diff = compare_reports(&base, &sample_report(vec![fat]), 0.01);
        assert!(diff.has_failures());
        assert!(diff.regressions[0].contains("wire_bytes"));
        let mut fat = sample_cell();
        fat.uploaded_bytes += 1;
        assert!(compare_reports(&base, &sample_report(vec![fat]), 0.01).has_failures());
        // shrinking is fine
        let mut lean = sample_cell();
        lean.wire_bytes -= 1;
        assert!(!compare_reports(&base, &sample_report(vec![lean]), 0.01).has_failures());
    }

    #[test]
    fn compare_new_cell_is_a_note_vanished_is_fatal() {
        let base = sample_report(vec![sample_cell()]);
        let mut extra = sample_cell();
        extra.scheme = "oort".into();
        let cur = sample_report(vec![sample_cell(), extra]);
        let diff = compare_reports(&base, &cur, 0.01);
        assert!(!diff.has_failures(), "{:?}", diff.regressions);
        assert_eq!(diff.notes.len(), 1);
        assert!(diff.notes[0].contains("new cell"));
        // the reverse direction: the cell vanished — fatal
        let diff = compare_reports(&cur, &base, 0.01);
        assert!(diff.has_failures());
        assert!(diff.regressions[0].contains("vanished"));
        // empty current report is fatal outright
        assert!(compare_reports(&base, &sample_report(vec![]), 0.01).has_failures());
    }

    #[test]
    fn diff_markdown_prints_only_regressions() {
        let base = sample_report(vec![sample_cell()]);
        let mut worse = sample_cell();
        worse.accuracy = 0.1;
        let diff = compare_reports(&base, &sample_report(vec![worse]), 0.01);
        let md = diff.markdown();
        assert!(md.contains("FAIL"));
        assert!(!md.contains("| scenario |"), "diff must not dump the full table");
        let green = compare_reports(&base, &base, 0.01).markdown();
        assert!(green.contains("No regressions."));
    }

    #[test]
    fn file_stem_sanitizes_labels() {
        let mut rep = sample_report(vec![]);
        rep.label = "pr 7/diff".into();
        assert_eq!(rep.file_stem(), "MATRIX_smoke_pr-7-diff");
    }
}
