//! Semi-asynchronous round engine: event-scheduler semantics against the
//! synchronous barrier, on the native-exec runtime (pure-Rust FC
//! executor — runs on any host, no libxla or prebuilt artifacts).
//!
//! Covers the scheduler's contract:
//! * `round_mode=sync` is untouched (asserted bit-for-bit by
//!   `parallel_round.rs`, which this file deliberately does not modify);
//! * quorum == N (wait for everyone) reduces the semi-async fold to the
//!   synchronous output exactly — same losses, same global parameters,
//!   bit for bit;
//! * a deadline no client can meet still terminates every round;
//! * with a 70% quorum on the skewed Table-4 fleet, semi-async reaches
//!   the same eval accuracy (±1%) in strictly less virtual time.

use std::path::PathBuf;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::metrics::RunResult;
use feddd::runtime::write_native_manifest;
use feddd::tensor::Tensor;

fn native_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("feddd_semi_async_{}_{tag}", std::process::id()));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(round_mode: &str, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = "feddd".into();
    cfg.n_clients = 10;
    cfg.rounds = 12;
    cfg.local_steps = 2;
    cfg.test_n = 128;
    cfg.train_per_client = 60;
    cfg.eval_every = 12;
    cfg.workers = 2;
    cfg.round_mode = round_mode.into();
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

fn run_once(cfg: ExpConfig) -> (RunResult, Vec<Tensor>) {
    let mut run = FedRun::new(cfg).unwrap();
    let result = run.run().unwrap();
    (result, run.global_params.clone())
}

#[test]
fn quorum_one_reduces_to_sync_output() {
    // quorum = 1.0 with no deadline: every round waits for all uploads,
    // every fold is fresh (staleness 0, discount exactly 1), and the
    // fresh path shares the sync engine's sharded aggregation — so
    // losses, uploaded bytes and global parameters must be *bitwise*
    // identical to the synchronous barrier. Virtual time is compared
    // with a tolerance: the scheduler tracks absolute arrival instants,
    // so round durations differ from sync only by f64 add/subtract
    // rounding.
    let dir = native_dir("quorum1");
    let (sync_res, sync_params) = run_once(cfg("sync", &dir));
    let mut c = cfg("semi_async", &dir);
    c.quorum = 1.0;
    c.deadline_s = 0.0; // none
    c.staleness_beta = 0.7; // must be irrelevant when nothing is ever late
    let (semi_res, semi_params) = run_once(c);

    assert_eq!(sync_res.rounds.len(), semi_res.rounds.len());
    for (a, b) in sync_res.rounds.iter().zip(&semi_res.rounds) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "round {} train_loss {} vs {}",
            a.round,
            a.train_loss,
            b.train_loss
        );
        assert_eq!(a.uploaded_bytes, b.uploaded_bytes, "round {}", a.round);
        assert_eq!(a.participants, b.participants, "round {}", a.round);
        assert_eq!(b.stragglers, 0, "round {}: quorum 1.0 left stragglers", a.round);
        assert_eq!(b.mean_staleness, 0.0, "round {}", a.round);
        let rel = (a.duration - b.duration).abs() / a.duration.max(1e-12);
        assert!(rel < 1e-9, "round {}: duration {} vs {}", a.round, a.duration, b.duration);
    }
    for (a, b) in sync_res.evals.iter().zip(&semi_res.evals) {
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "eval accuracy");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "eval loss");
    }
    assert_eq!(sync_params.len(), semi_params.len());
    for (i, (a, b)) in sync_params.iter().zip(&semi_params).enumerate() {
        assert_eq!(a.data(), b.data(), "global param tensor {i}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn impossible_deadline_still_terminates() {
    // A deadline far below any client's round time means most rounds
    // fold zero uploads — but every round must still close (at the
    // deadline), the clock must advance monotonically, and the buffered
    // uploads must eventually fold once enough deadlines have elapsed
    // (they are never discarded).
    let dir = native_dir("deadline");
    let mut c = cfg("semi_async", &dir);
    c.rounds = 8;
    c.eval_every = 8;
    c.quorum = 1.0;
    c.deadline_s = 1e-3; // no client finishes a round in 1 ms
    let (res, _) = run_once(c);
    assert_eq!(res.rounds.len(), 8, "run did not terminate every round");
    let mut prev = 0.0;
    for r in &res.rounds {
        assert!(r.v_time >= prev, "clock went backwards");
        prev = r.v_time;
        assert!(r.duration <= 1e-3 + 1e-12, "round overshot the deadline");
    }
    // All 10 clients were dispatched in round 1 and none can arrive by
    // any 1 ms deadline within 8 rounds (8 ms total << seconds-scale
    // round times), so every fold is empty and everyone stays in flight.
    assert!(
        res.rounds.iter().all(|r| r.participants == 0),
        "a client met an impossible deadline"
    );
    assert_eq!(res.rounds.last().unwrap().stragglers, 10);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn buffered_stragglers_fold_later_with_staleness() {
    // Tight-but-possible deadline: early rounds leave stragglers in
    // flight; their uploads must fold in later rounds with staleness > 0
    // and never be dropped (folds across the run = dispatches).
    let dir = native_dir("staleness");
    let mut c = cfg("semi_async", &dir);
    c.rounds = 20;
    c.eval_every = 20;
    c.quorum = 1.0; // close on deadline only
    c.deadline_s = 40.0; // under the slowest client's round time
    c.staleness_beta = 1.0;
    let mut run = FedRun::new(c).unwrap();
    let mut folded = 0usize;
    let mut saw_staleness = false;
    let mut saw_straggler = false;
    for _ in 0..20 {
        let out = run.step_round().unwrap();
        folded += out.participants;
        saw_staleness |= out.mean_staleness > 0.0;
        saw_straggler |= out.stragglers > 0;
        assert!(out.mean_loss.is_finite());
    }
    assert!(saw_straggler, "deadline never left a straggler in flight");
    assert!(saw_staleness, "no upload ever folded late");
    assert!(folded > 0, "nothing ever folded");
    // Global params stayed finite through staleness-discounted folds.
    for t in &run.global_params {
        assert!(t.data().iter().all(|x| x.is_finite()));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn quorum_rounds_beat_sync_to_same_accuracy() {
    // The acceptance experiment: on the skewed Table-4 fleet (simulated
    // profiles, seconds-scale straggler spread), semi-async with a 70%
    // quorum must reach the same final eval accuracy within ±1% in
    // strictly less virtual time than the synchronous barrier, at the
    // same round count.
    // h=1 (full broadcast every round) keeps both trajectories anchored
    // to the shared global model, so the plateau accuracies coincide;
    // enough rounds/steps that both runs sit on that plateau.
    let tune = |c: &mut ExpConfig| {
        c.rounds = 40;
        c.eval_every = 40;
        c.local_steps = 3;
        c.train_per_client = 80;
        c.h = 1;
    };
    let dir = native_dir("t2a");
    let mut sync_cfg = cfg("sync", &dir);
    tune(&mut sync_cfg);
    let (sync_res, _) = run_once(sync_cfg);

    let mut semi_cfg = cfg("semi_async", &dir);
    tune(&mut semi_cfg);
    semi_cfg.quorum = 0.7;
    semi_cfg.staleness_beta = 1.0;
    let (semi_res, _) = run_once(semi_cfg);

    let acc_sync = sync_res.final_accuracy().unwrap();
    let acc_semi = semi_res.final_accuracy().unwrap();
    assert!(
        (acc_sync - acc_semi).abs() <= 0.01 + 1e-12,
        "accuracy diverged: sync {acc_sync:.4} vs semi_async {acc_semi:.4}"
    );
    let vt_sync = sync_res.final_v_time();
    let vt_semi = semi_res.final_v_time();
    assert!(
        vt_semi < vt_sync,
        "semi_async not faster: {vt_semi:.1}s vs sync {vt_sync:.1}s"
    );
    // the speedup metric agrees
    assert!(semi_res.speedup_vs(&sync_res) > 1.0);
    // and the semi-async run actually exercised the buffer path
    assert!(semi_res.mean_stragglers() > 0.0, "quorum never left a straggler");
    let _ = std::fs::remove_dir_all(&dir);
}
