//! Quantized wire-plane guarantees (DESIGN.md §Codec): fp16/int8 value
//! planes round-trip within the documented error bound and re-encode
//! idempotently; engine runs under every plane × round mode stay
//! bitwise worker-invariant (the golden digest is the workers=1 run);
//! lossy planes actually change the wire (and shrink it) without ever
//! escaping the frame checksum when corrupted.

use std::path::PathBuf;

use feddd::codec::{encode_upload_planes, CodecMode, PlaneMode, ValuePlane, WireUpload};
use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::model::ModelSpec;
use feddd::runtime::write_native_manifest;
use feddd::selection::{select_mask, ChannelMask, Policy};
use feddd::tensor::Tensor;
use feddd::util::proptest::check;
use feddd::util::rng::Rng;

fn perturbed(p: &[Tensor], rng: &mut Rng, s: f32) -> Vec<Tensor> {
    p.iter()
        .map(|t| {
            let d: Vec<f32> = t.data().iter().map(|&x| x + rng.normal_f32(0.0, s)).collect();
            Tensor::new(t.shape().to_vec(), d)
        })
        .collect()
}

fn scheme_mask(spec: &ModelSpec, prev: &[Tensor], after: &[Tensor], rng: &mut Rng) -> ChannelMask {
    match rng.below(4) {
        0 => ChannelMask::full(spec),
        _ => {
            let d = rng.range_f64(0.05, 0.9);
            select_mask(Policy::Importance, spec, prev, after, None, d, rng)
        }
    }
}

#[test]
fn lossy_planes_roundtrip_within_bound_and_reencode_identically() {
    // Property: every plane mode survives encode → bytes → decode →
    // re-encode with identical bytes, and the realized per-value error
    // vs the exact f32 encode respects each plane's bound (auto: the
    // configured plane_error · max|value| per layer).
    check("plane roundtrip", 12, |rng| {
        for name in ["mlp", "cnn1"] {
            let spec = ModelSpec::get(name, 0.5).unwrap();
            let prev = spec.init_params(rng);
            let after = perturbed(&prev, rng, 0.05);
            let mask = scheme_mask(&spec, &prev, &after, rng);
            let exact = encode_upload_planes(
                &mask, &after, &spec, CodecMode::Auto, PlaneMode::F32, 0.0,
            );
            for plane in [PlaneMode::F16, PlaneMode::I8, PlaneMode::Auto] {
                let up = encode_upload_planes(
                    &mask, &after, &spec, CodecMode::Auto, plane, 0.005,
                );
                let bytes = up.to_bytes();
                let dec = WireUpload::from_bytes(&bytes)
                    .map_err(|e| format!("{name} {plane:?}: decode failed: {e}"))?;
                if dec != up {
                    return Err(format!("{name} {plane:?}: decode != encode"));
                }
                if dec.to_bytes() != bytes {
                    return Err(format!("{name} {plane:?}: re-encode not idempotent"));
                }
                for (l, (lw, le)) in up.layers.iter().zip(&exact.layers).enumerate() {
                    let max_abs = le
                        .values
                        .iter()
                        .fold(0.0f32, |a, &v| a.max(v.abs()));
                    for (&q, &v) in lw.values.iter().zip(&le.values) {
                        let err = (q - v).abs();
                        let ok = match (plane, lw.plane) {
                            (_, ValuePlane::F32) => err == 0.0,
                            // f16 RNE: half-ulp relative in the normal
                            // range plus the subnormal absolute step.
                            (PlaneMode::F16, ValuePlane::F16) => {
                                err <= v.abs() * 4.9e-4 + 6.0e-8
                            }
                            // i8: half a quantization step (+ f32 slack).
                            (PlaneMode::I8, ValuePlane::I8 { scale }) => {
                                err <= 0.5001 * scale + 1.0e-7
                            }
                            // auto: the configured relative bound.
                            (PlaneMode::Auto, _) => err <= 0.005 * max_abs,
                            (m, p) => {
                                return Err(format!(
                                    "{name} layer {l}: mode {m:?} produced plane {p:?}"
                                ))
                            }
                        };
                        if !ok {
                            return Err(format!(
                                "{name} {plane:?} layer {l}: err {err} too large \
                                 (value {v}, max_abs {max_abs})"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn corrupted_quantized_value_plane_fails_the_frame_checksum() {
    // A flipped byte inside an f16/i8 value plane must fail the frame
    // checksum — quantized bodies get the same integrity seal as f32.
    let mut rng = Rng::new(77);
    let spec = ModelSpec::get("mlp", 0.5).unwrap();
    let prev = spec.init_params(&mut rng);
    let after = perturbed(&prev, &mut rng, 0.05);
    let mask = scheme_mask(&spec, &prev, &after, &mut rng);
    for plane in [PlaneMode::F16, PlaneMode::I8] {
        let up = encode_upload_planes(&mask, &after, &spec, CodecMode::Auto, plane, 0.005);
        let bytes = up.to_bytes();
        assert!(WireUpload::from_bytes(&bytes).is_ok(), "{plane:?}: clean decode");
        let mut bad = bytes.clone();
        // Last body byte: the final quantized value, just before the
        // 8-byte trailing checksum.
        let i = bad.len() - 9;
        bad[i] ^= 0x40;
        assert!(
            WireUpload::from_bytes(&bad).is_err(),
            "{plane:?}: corrupted value plane decoded"
        );
    }
}

// ---------------------------------------------------------------------
// Engine level: golden digests per plane × round mode × worker count.
// ---------------------------------------------------------------------

fn native_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "feddd_quant_planes_{}_{tag}",
        std::process::id()
    ));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(plane: &str, round_mode: &str, workers: usize, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = "feddd".into();
    cfg.n_clients = 5;
    cfg.rounds = 3;
    cfg.local_steps = 2;
    cfg.test_n = 128;
    cfg.train_per_client = 60;
    cfg.eval_every = 3;
    cfg.workers = workers;
    cfg.round_mode = round_mode.into();
    cfg.value_plane = plane.into();
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

/// FNV-1a 64 over the bit patterns of every global parameter.
fn digest(params: &[Tensor]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in params {
        for &v in t.data() {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1_0000_0001_b3);
            }
        }
    }
    h
}

#[test]
fn golden_digests_per_plane_are_worker_and_mode_invariant() {
    // For every plane × round mode, the workers=1 run is the golden:
    // higher worker counts must reproduce its global parameters bit for
    // bit (quantization happens per client, before any fan-out, so
    // determinism cannot decay). Lossy planes must also *change* the
    // digest vs f32 — otherwise the quantizer never engaged.
    let dir = native_dir("digests");
    for round_mode in ["sync", "semi_async"] {
        let mut by_plane: Vec<(&str, u64)> = Vec::new();
        for plane in ["f32", "f16", "i8", "auto"] {
            let run_once = |workers: usize| {
                let mut run = FedRun::new(cfg(plane, round_mode, workers, &dir)).unwrap();
                run.run().unwrap();
                digest(&run.global_params)
            };
            let golden = run_once(1);
            for workers in [2usize, 4] {
                assert_eq!(
                    run_once(workers),
                    golden,
                    "{plane}/{round_mode}: workers={workers} diverged from the golden"
                );
            }
            by_plane.push((plane, golden));
        }
        let f32_digest = by_plane[0].1;
        for &(plane, d) in &by_plane[1..] {
            assert_ne!(
                d, f32_digest,
                "{plane}/{round_mode}: lossy run equals the f32 run — quantizer inert"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn auto_plane_shrinks_the_wire_end_to_end() {
    // Same config and seed, value_plane auto vs f32: the realized wire
    // total must be strictly smaller, the i8 plane must actually engage,
    // and the payload/wire invariant survives the narrower planes.
    let dir = native_dir("shrink");
    let run_with = |plane: &str| {
        let mut run = FedRun::new(cfg(plane, "sync", 2, &dir)).unwrap();
        run.run().unwrap()
    };
    let f32_res = run_with("f32");
    let auto_res = run_with("auto");
    assert!(
        auto_res.total_wire_bytes() < f32_res.total_wire_bytes(),
        "auto wire {} !< f32 wire {}",
        auto_res.total_wire_bytes(),
        f32_res.total_wire_bytes()
    );
    let mix = auto_res.plane_mix();
    assert!(mix.i8_layers > 0, "auto never picked i8: {mix:?}");
    for r in &auto_res.rounds {
        assert!(r.wire_bytes >= r.uploaded_bytes, "round {}: wire below payload", r.round);
        assert!(r.train_loss.is_finite(), "round {}: loss diverged", r.round);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
