//! Cross-module integration tests that don't need the training loop:
//! config -> engine construction, solver parity at fleet scale, manifest
//! vs registry pinning, selection + aggregation composition.

use feddd::config::ExpConfig;
use feddd::data::{Partition, PartitionKind, SynthSpec};
use feddd::model::ModelSpec;
use feddd::runtime::default_artifacts_dir;
use feddd::simnet::Fleet;
use feddd::solver::{allocate_fast, allocate_lp, AllocInput, AllocParams};
use feddd::util::rng::Rng;

fn have_artifacts() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

#[test]
fn solver_parity_at_table4_scale() {
    // 100 clients drawn from the Table 4 distributions; fast == simplex.
    let mut rng = Rng::new(42);
    let fleet = Fleet::simulated(100, &mut rng);
    let spec = ModelSpec::get("cnn2", 1.0).unwrap();
    let inputs: Vec<AllocInput> = fleet
        .profiles
        .iter()
        .map(|p| AllocInput {
            u_bytes: spec.size_bytes() as f64,
            t_cmp: p.t_cmp(64),
            sec_per_byte: p.sec_per_byte(),
            re: rng.range_f64(0.0, 0.2),
        })
        .collect();
    let params = AllocParams { d_max: 0.8, a_server: 0.6, delta: 1.0 };
    let fast = allocate_fast(&inputs, &params).unwrap();
    let lp = allocate_lp(&inputs, &params).unwrap();
    assert!(
        (fast.objective - lp.objective).abs() / lp.objective.max(1.0) < 1e-4,
        "fast {} vs simplex {}",
        fast.objective,
        lp.objective
    );
    // budget equality
    let total: f64 = inputs.iter().map(|i| i.u_bytes).sum();
    let up: f64 = inputs
        .iter()
        .zip(&fast.d)
        .map(|(i, &d)| i.u_bytes * (1.0 - d))
        .sum();
    assert!((up - 0.6 * total).abs() / total < 1e-6);
}

#[test]
fn partition_scores_feed_allocator() {
    let mut rng = Rng::new(7);
    let ds = SynthSpec::mnist_like().generate(3000, 100, &mut rng);
    let part = Partition::build(PartitionKind::NonIidB, &ds, 15, &mut rng);
    let scores = part.distribution_scores(&ds);
    assert_eq!(scores.len(), 15);
    // Non-IID-b clients hold <=3 classes => score <= 3 + epsilon
    assert!(scores.iter().all(|&s| s <= 3.0 + 1e-9), "{scores:?}");
}

#[test]
fn engine_builds_all_scheme_and_model_combos() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for (model, ds) in [("mlp", "mnist"), ("het_b", "cifar10")] {
        for scheme in ["feddd", "fedavg", "fedcs", "oort"] {
            let mut cfg = ExpConfig::smoke();
            cfg.n_clients = 5;
            cfg.test_n = 64;
            cfg.train_per_client = 40;
            cfg.model = model.into();
            cfg.dataset = ds.into();
            if model == "het_b" {
                cfg.width_pct = 25;
            }
            cfg.scheme = scheme.into();
            cfg.artifacts_dir =
                default_artifacts_dir().to_string_lossy().into_owned();
            let run = feddd::coordinator::FedRun::new(cfg).unwrap();
            assert_eq!(run.clients.len(), 5);
            // hetero: coverage rates drop off for the wider layers
            if model == "het_b" {
                let first_layer = &run.cr[0];
                assert!(first_layer.iter().any(|&c| c < 1.0));
            }
        }
    }
}

#[test]
fn testbed_fleet_has_table5_shape() {
    let mut rng = Rng::new(1);
    let fleet = Fleet::testbed(&mut rng);
    assert_eq!(fleet.len(), 10);
}

#[test]
fn manifest_covers_every_config_combination() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let m = feddd::runtime::Manifest::load(&default_artifacts_dir()).unwrap();
    // every client model the config system can produce must have train+eval
    for fam in ["mlp", "cnn1", "cnn2"] {
        for kind in ["train", "eval"] {
            m.get(&format!("{fam}_w100_{kind}")).unwrap();
        }
    }
    for fam in ["het_a", "het_b"] {
        for i in 1..=5 {
            for kind in ["train", "eval"] {
                m.get(&format!("{fam}_{i}_w25_{kind}")).unwrap();
            }
        }
    }
}

#[test]
fn config_presets_are_runnable() {
    for preset in ["smoke", "table4", "testbed", "fleet"] {
        ExpConfig::preset(preset).unwrap().validate().unwrap();
    }
}

// ---------------------------------------------------------------------
// Failure injection: wrong configs, missing artifacts, empty shards.
// ---------------------------------------------------------------------

#[test]
fn runtime_missing_artifact_dir_is_clean_error() {
    let err = feddd::runtime::Runtime::new(std::path::Path::new("/nonexistent-xyz"));
    assert!(err.is_err());
}

#[test]
fn engine_rejects_unknown_width_artifacts() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = ExpConfig::smoke();
    cfg.width_pct = 73; // never compiled
    cfg.n_clients = 2;
    cfg.artifacts_dir = default_artifacts_dir().to_string_lossy().into_owned();
    assert!(feddd::coordinator::FedRun::new(cfg).is_err());
}

#[test]
fn infeasible_budget_rejected_by_validate() {
    let mut cfg = ExpConfig::smoke();
    cfg.a_server = 0.1;
    cfg.d_max = 0.5; // cannot drop 90% when max dropout is 50%
    assert!(cfg.validate().is_err());
}

#[test]
fn uniform_alloc_ablation_runs_and_reports_uniform_rates() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = ExpConfig::smoke();
    cfg.alloc = "uniform".into();
    cfg.n_clients = 4;
    cfg.rounds = 2;
    cfg.test_n = 64;
    cfg.train_per_client = 40;
    cfg.eval_every = 2;
    cfg.artifacts_dir = default_artifacts_dir().to_string_lossy().into_owned();
    let mut run = feddd::coordinator::FedRun::new(cfg).unwrap();
    let res = run.run().unwrap();
    // uniform D = 1 - A = 0.4 -> uploaded ≈ 60% of full after round 1
    let full: usize = run.clients.iter().map(|c| c.u_bytes()).sum();
    let r2 = &res.rounds[1];
    let ratio = r2.uploaded_bytes as f64 / full as f64;
    assert!((ratio - 0.6).abs() < 0.05, "ratio {ratio}");
}

#[test]
fn solver_handles_degenerate_single_client() {
    let inputs = vec![AllocInput {
        u_bytes: 1e6,
        t_cmp: 1.0,
        sec_per_byte: 1e-5,
        re: 0.5,
    }];
    let p = AllocParams { d_max: 0.8, a_server: 0.6, delta: 1.0 };
    let a = allocate_fast(&inputs, &p).unwrap();
    assert!((a.d[0] - 0.4).abs() < 1e-6); // only way to meet the budget
}

#[test]
fn selection_policy_names_roundtrip() {
    for name in ["importance", "random", "max", "delta", "ordered"] {
        feddd::selection::Policy::by_name(name).unwrap();
    }
    assert!(feddd::selection::Policy::by_name("topk").is_err());
}
