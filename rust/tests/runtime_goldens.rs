//! Golden-replay integration tests: the python build path (aot.py) wrote
//! deterministic input/output pairs under artifacts/goldens/; here the
//! rust PJRT runtime executes the same artifacts on the same inputs and
//! must reproduce the outputs bit-for-bit (up to f32 accumulation order).
//!
//! This is THE cross-language correctness seal: L2/L1 (jax+pallas) vs the
//! L3 runtime executing the AOT HLO text.

use std::path::PathBuf;

use feddd::runtime::{default_artifacts_dir, Runtime};
use feddd::tensor::Tensor;
use feddd::util::json;

struct Golden {
    artifact: String,
    inputs: Vec<(Vec<usize>, String, String)>, // (shape, dtype, file)
    outputs: Vec<(Vec<usize>, String, String)>,
}

fn load_goldens() -> Option<(PathBuf, Vec<Golden>)> {
    let dir = default_artifacts_dir().join("goldens");
    let j = json::from_file(&dir.join("goldens.json")).ok()?;
    let mut out = Vec::new();
    for g in j.as_arr()? {
        let parse_io = |key: &str| -> Vec<(Vec<usize>, String, String)> {
            g.req_arr(key)
                .unwrap()
                .iter()
                .map(|i| {
                    (
                        i.req_arr("shape")
                            .unwrap()
                            .iter()
                            .filter_map(|x| x.as_usize())
                            .collect(),
                        i.req_str("dtype").unwrap().to_string(),
                        i.req_str("file").unwrap().to_string(),
                    )
                })
                .collect()
        };
        out.push(Golden {
            artifact: g.req_str("artifact").unwrap().to_string(),
            inputs: parse_io("inputs"),
            outputs: parse_io("outputs"),
        });
    }
    Some((dir, out))
}

fn read_f32(path: &PathBuf) -> Vec<f32> {
    let bytes = std::fs::read(path).unwrap();
    bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

fn read_i32(path: &PathBuf) -> Vec<i32> {
    let bytes = std::fs::read(path).unwrap();
    bytes
        .chunks_exact(4)
        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = g.abs().max(w.abs()).max(1.0);
        assert!(
            (g - w).abs() <= tol * scale,
            "{ctx}[{i}]: {g} vs {w}"
        );
    }
}

#[test]
fn goldens_replay_through_pjrt() {
    let Some((dir, goldens)) = load_goldens() else {
        eprintln!("skipping: goldens not built (run `make artifacts`)");
        return;
    };
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    assert!(!goldens.is_empty());
    for g in &goldens {
        // Build literal args in order.
        let mut args = Vec::new();
        for (shape, dtype, file) in &g.inputs {
            let path = dir.join(file);
            let lit = if dtype == "i32" {
                rt.lit_i32(&read_i32(&path), shape).unwrap()
            } else {
                rt.lit_f32(&read_f32(&path), shape).unwrap()
            };
            args.push(lit);
        }
        let outs = rt.execute(&g.artifact, &args).unwrap();
        assert_eq!(outs.len(), g.outputs.len(), "{}: output arity", g.artifact);
        for (i, (shape, _dtype, file)) in g.outputs.iter().enumerate() {
            let want = read_f32(&dir.join(file));
            let got: Vec<f32> = outs[i].to_vec().unwrap();
            assert_eq!(got.len(), shape.iter().product::<usize>());
            assert_close(&got, &want, 1e-4, &format!("{} out{}", g.artifact, i));
        }
    }
}

#[test]
fn kernel_artifacts_match_rust_mirrors() {
    // The rust tensor ops must agree with the Pallas kernels (both are
    // "the same math"); stream random data through both paths.
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = feddd::util::rng::Rng::new(99);
    let n = 20_000; // forces chunking (chunk = 16384)
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let dw: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.3)).collect();
    let mask: Vec<f32> = (0..n).map(|_| if rng.bool(0.5) { 1.0 } else { 0.0 }).collect();
    let prev: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    // masked_acc
    let mut num_x = vec![0.0f32; n];
    let mut den_x = vec![0.0f32; n];
    rt.k_masked_acc(&mut num_x, &mut den_x, &w, &mask, 3.5).unwrap();
    let mut num_r = vec![0.0f32; n];
    let mut den_r = vec![0.0f32; n];
    feddd::tensor::axpy_masked(&mut num_r, 3.5, &w, &mask);
    feddd::tensor::axpy(&mut den_r, 3.5, &mask);
    assert_close(&num_x, &num_r, 1e-5, "masked_acc num");
    assert_close(&den_x, &den_r, 1e-5, "masked_acc den");

    // masked_fin
    let mut fin_x = vec![0.0f32; n];
    rt.k_masked_fin(&num_x, &den_x, &prev, &mut fin_x).unwrap();
    let mut fin_r = vec![0.0f32; n];
    feddd::tensor::masked_div(&mut fin_r, &num_r, &den_r, &prev);
    assert_close(&fin_x, &fin_r, 1e-5, "masked_fin");

    // importance
    let mut imp_x = vec![0.0f32; n];
    rt.k_importance(&w, &dw, &mut imp_x).unwrap();
    let mut imp_r = vec![0.0f32; n];
    feddd::tensor::importance_scores(&mut imp_r, &w, &dw);
    assert_close(&imp_x, &imp_r, 1e-4, "importance");
}

#[test]
fn xla_aggregator_matches_rust_aggregator() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let spec = feddd::model::ModelSpec::get("mlp", 0.25).unwrap();
    let mut rng = feddd::util::rng::Rng::new(5);
    let prev = spec.init_params(&mut rng);
    let clients: Vec<Vec<Tensor>> = (0..3)
        .map(|_| {
            prev.iter()
                .map(|t| {
                    let d: Vec<f32> = t
                        .data()
                        .iter()
                        .map(|&x| x + rng.normal_f32(0.0, 0.05))
                        .collect();
                    Tensor::new(t.shape().to_vec(), d)
                })
                .collect()
        })
        .collect();
    let masks: Vec<Vec<Tensor>> = (0..3)
        .map(|i| {
            feddd::selection::select_mask(
                feddd::selection::Policy::Random,
                &spec,
                &prev,
                &clients[i],
                None,
                0.5,
                &mut rng,
            )
            .to_elementwise(&spec)
        })
        .collect();

    let run = |backend: feddd::aggregation::AggBackend| -> Vec<Tensor> {
        let mut agg = feddd::aggregation::Aggregator::new(&spec, backend);
        for (i, c) in clients.iter().enumerate() {
            agg.add_client(c, &masks[i], (i + 1) as f32, Some(&rt)).unwrap();
        }
        agg.finalize(&prev, Some(&rt)).unwrap()
    };
    let a = run(feddd::aggregation::AggBackend::Rust);
    let b = run(feddd::aggregation::AggBackend::Xla);
    for (x, y) in a.iter().zip(&b) {
        assert_close(x.data(), y.data(), 1e-5, "agg backend parity");
    }
}
