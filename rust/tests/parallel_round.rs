//! Parallel round-engine determinism: `FedRun` with `workers = N > 1`
//! must produce a `RunResult` — losses, uploaded bytes, virtual-time
//! accounting, eval metrics — and global parameters that are **bitwise
//! identical** to `workers = 1`. These tests run against a native-exec
//! artifact manifest (pure-Rust FC executor), so they exercise the full
//! train → select → shard-aggregate → merge round on any host, no libxla
//! or prebuilt HLO artifacts required.

use std::path::PathBuf;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::metrics::RunResult;
use feddd::runtime::write_native_manifest;
use feddd::tensor::Tensor;

fn native_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "feddd_parallel_round_{}_{tag}",
        std::process::id()
    ));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(scheme: &str, workers: usize, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.n_clients = 5;
    cfg.rounds = 3;
    cfg.local_steps = 2;
    cfg.test_n = 128;
    cfg.train_per_client = 60;
    cfg.eval_every = 3;
    cfg.workers = workers;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

fn run_once(scheme: &str, workers: usize, dir: &PathBuf) -> (RunResult, Vec<Tensor>) {
    let mut run = FedRun::new(cfg(scheme, workers, dir)).unwrap();
    let result = run.run().unwrap();
    (result, run.global_params.clone())
}

fn assert_bitwise_equal(
    (ra, pa): &(RunResult, Vec<Tensor>),
    (rb, pb): &(RunResult, Vec<Tensor>),
    ctx: &str,
) {
    assert_eq!(ra.rounds.len(), rb.rounds.len(), "{ctx}: round count");
    for (x, y) in ra.rounds.iter().zip(&rb.rounds) {
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{ctx}: round {} train_loss {} vs {}",
            x.round,
            x.train_loss,
            y.train_loss
        );
        assert_eq!(x.uploaded_bytes, y.uploaded_bytes, "{ctx}: round {}", x.round);
        assert_eq!(x.participants, y.participants, "{ctx}: round {}", x.round);
        assert_eq!(x.duration.to_bits(), y.duration.to_bits(), "{ctx}: round {}", x.round);
        assert_eq!(x.v_time.to_bits(), y.v_time.to_bits(), "{ctx}: round {}", x.round);
        assert_eq!(
            x.mean_dropout.to_bits(),
            y.mean_dropout.to_bits(),
            "{ctx}: round {}",
            x.round
        );
    }
    assert_eq!(ra.evals.len(), rb.evals.len(), "{ctx}: eval count");
    for (x, y) in ra.evals.iter().zip(&rb.evals) {
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{ctx}: eval accuracy");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{ctx}: eval loss");
    }
    assert_eq!(pa.len(), pb.len(), "{ctx}: param arity");
    for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
        assert_eq!(x.data(), y.data(), "{ctx}: global param tensor {i}");
    }
}

#[test]
fn workers_do_not_change_results_bitwise() {
    // The headline guarantee: every scheme, workers ∈ {2, 4, 0=auto}
    // reproduces the workers=1 run bit for bit.
    let dir = native_dir("bitwise");
    for scheme in ["feddd", "fedavg", "fedcs", "oort"] {
        let sequential = run_once(scheme, 1, &dir);
        assert!(
            sequential.0.rounds.iter().all(|r| r.train_loss.is_finite()),
            "{scheme}: non-finite loss"
        );
        for workers in [2usize, 4, 0] {
            let parallel = run_once(scheme, workers, &dir);
            assert_bitwise_equal(&sequential, &parallel, &format!("{scheme} w{workers}"));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn parallel_feddd_respects_byte_budget() {
    // After round 1 (full upload), the masked uploads obey the budget up
    // to per-layer keep-count rounding.
    let dir = native_dir("budget");
    let mut run = FedRun::new(cfg("feddd", 4, &dir)).unwrap();
    let budget = run.budget_bytes();
    let result = run.run().unwrap();
    for r in result.rounds.iter().skip(1) {
        assert!(
            r.uploaded_bytes as f64 <= budget as f64 * 1.05,
            "round {} uploaded {} > budget {}",
            r.round,
            r.uploaded_bytes,
            budget
        );
        assert_eq!(r.participants, 5);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn xla_kernel_backend_matches_rust_backend_on_native_runtime() {
    // On the native runtime the "xla" aggregation backend dispatches to
    // the same flat tensor ops the rust backend calls directly, so the
    // two must agree bitwise — a cheap guard that the backend dispatch
    // stays wired correctly under sharded aggregation.
    let dir = native_dir("backend");
    let run_with = |backend: &str| {
        let mut c = cfg("feddd", 4, &dir);
        c.agg_backend = backend.into();
        c.rounds = 2;
        let mut run = FedRun::new(c).unwrap();
        let result = run.run().unwrap();
        (result, run.global_params.clone())
    };
    let rust = run_with("rust");
    let xla = run_with("xla");
    assert_bitwise_equal(&rust, &xla, "rust vs xla backend");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn native_smoke_run_learns_a_little() {
    // Not a tight learning bound (that is the artifact-gated e2e test's
    // job) — just that real training happens: losses are finite and the
    // final loss improves on the first round's.
    let dir = native_dir("learns");
    let mut c = cfg("feddd", 2, &dir);
    c.rounds = 8;
    c.local_steps = 4;
    c.eval_every = 8;
    let mut run = FedRun::new(c).unwrap();
    let result = run.run().unwrap();
    let first = result.rounds.first().unwrap().train_loss;
    let last = result.rounds.last().unwrap().train_loss;
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first, "loss did not improve: {first} -> {last}");
    assert!(result.final_accuracy().unwrap() > 0.15, "accuracy at chance");
    let _ = std::fs::remove_dir_all(&dir);
}
