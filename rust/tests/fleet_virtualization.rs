//! Client-state virtualization (DESIGN.md §Fleet-Virtualization):
//!
//! * the **dense-equivalence lemma** — materializing
//!   `Delta{snapshot, complement-of-mask residual}` must reproduce the
//!   dense representation's Eq. 5 merge (`sparse_merge`) **bitwise**, for
//!   every selection policy / mask shape the schemes produce, dropout
//!   rate, model family and hetero sub-model corner;
//! * the engine built on it stays bitwise-invariant across worker
//!   counts, codec layouts and the two round modes;
//! * round 1 is always a full broadcast (regression: clients used to be
//!   charged a mask-sparse download before ever holding the global);
//! * state accounting: zero residuals after a broadcast, strictly below
//!   the dense fleet under any dropout, collapse back to `Synced` on the
//!   next broadcast, and a single live snapshot per sync round.

use std::path::PathBuf;

use feddd::aggregation::sparse_merge;
use feddd::config::ExpConfig;
use feddd::coordinator::{ClientParams, FedRun, SnapshotRing, SparseResidual};
use feddd::metrics::RunResult;
use feddd::model::{extract_params, ModelSpec};
use feddd::runtime::write_native_manifest;
use feddd::selection::{select_mask, ChannelMask, Policy};
use feddd::tensor::Tensor;
use feddd::util::proptest::check;
use feddd::util::rng::Rng;

fn perturbed(p: &[Tensor], rng: &mut Rng, s: f32) -> Vec<Tensor> {
    p.iter()
        .map(|t| {
            let d: Vec<f32> = t.data().iter().map(|&x| x + rng.normal_f32(0.0, s)).collect();
            Tensor::new(t.shape().to_vec(), d)
        })
        .collect()
}

/// A client mask in one of the shapes the schemes produce: the baselines'
/// full mask or a FedDD policy selection at a random rate.
fn scheme_mask(spec: &ModelSpec, prev: &[Tensor], after: &[Tensor], rng: &mut Rng) -> ChannelMask {
    let policies = [
        Policy::Importance,
        Policy::Random,
        Policy::Max,
        Policy::Delta,
        Policy::Ordered,
    ];
    match rng.below(6) {
        0 => ChannelMask::full(spec),
        i => {
            let d = rng.range_f64(0.05, 0.9);
            select_mask(policies[i - 1], spec, prev, after, None, d, rng)
        }
    }
}

#[test]
fn virtualized_state_matches_dense_representation_bitwise() {
    // The dense bookkeeping kept, per client, the merged model
    //   W_n ← W ⊙ M_n + Ŵ_n ⊙ (1 − M_n)            (Eq. 5, sparse_merge)
    // The virtualized bookkeeping keeps only the complement residual and
    // rebuilds the same tensor on demand. Bitwise equality, across every
    // policy/mask shape and dropout rate the schemes produce.
    check("virtualized == dense client state", 20, |rng| {
        for name in ["mlp", "cnn1"] {
            let spec = ModelSpec::get(name, 0.5).unwrap();
            let global = spec.init_params(rng);
            let trained = perturbed(&global, rng, 0.05);
            let mask = scheme_mask(&spec, &global, &trained, rng);

            let mut dense = trained.clone();
            sparse_merge(&mut dense, &global, &mask.to_elementwise(&spec));

            let mut ring = SnapshotRing::new();
            let snap = ring.publish(7, &global);
            let residual = SparseResidual::complement_of(&mask, &trained, &spec);
            // full mask ⇒ no residual ⇒ collapse to Synced
            if mask == ChannelMask::full(&spec) && residual.is_some() {
                return Err(format!("{name}: full mask produced a residual"));
            }
            let state = ClientParams::after_download(snap, residual);
            let virt = state.materialize(&spec);
            for (i, (a, b)) in dense.iter().zip(&virt).enumerate() {
                if a.data() != b.data() {
                    return Err(format!("{name}: tensor {i} differs from dense merge"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn virtualized_state_matches_dense_in_hetero_corners() {
    // Hetero fleets: the snapshot holds the *global* (widest) model; a
    // sub-model client materializes its leading corner + residual. The
    // dense path sliced first, then merged — same bits required.
    check("virtualized == dense (hetero)", 8, |rng| {
        let global_spec = ModelSpec::get("het_a_1", 0.25).unwrap();
        let global = global_spec.init_params(rng);
        for i in 1..=5 {
            let sub = ModelSpec::get(&format!("het_a_{i}"), 0.25).unwrap();
            let slice = extract_params(&global, &sub);
            let trained = perturbed(&slice, rng, 0.05);
            let mask = scheme_mask(&sub, &slice, &trained, rng);

            let mut dense = trained.clone();
            sparse_merge(&mut dense, &slice, &mask.to_elementwise(&sub));

            let mut ring = SnapshotRing::new();
            let snap = ring.publish(3, &global);
            let state = ClientParams::after_download(
                snap,
                SparseResidual::complement_of(&mask, &trained, &sub),
            );
            let virt = state.materialize(&sub);
            for (ti, (a, b)) in dense.iter().zip(&virt).enumerate() {
                if a.data() != b.data() {
                    return Err(format!("het_a_{i}: tensor {ti} differs"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Engine level (native-exec runtime — runs on any host).
// ---------------------------------------------------------------------

fn native_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("feddd_fleet_virt_{}_{tag}", std::process::id()));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = "feddd".into();
    cfg.n_clients = 5;
    cfg.rounds = 4;
    cfg.h = 3; // rounds 1 and 3 broadcast; 2 and 4 leave residuals
    cfg.local_steps = 2;
    cfg.test_n = 128;
    cfg.train_per_client = 60;
    cfg.eval_every = 4;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

fn run_once(cfg: ExpConfig) -> (RunResult, Vec<Tensor>) {
    let mut run = FedRun::new(cfg).unwrap();
    let result = run.run().unwrap();
    (result, run.global_params.clone())
}

fn assert_bitwise(a: &(RunResult, Vec<Tensor>), b: &(RunResult, Vec<Tensor>), ctx: &str) {
    assert_eq!(a.0.rounds.len(), b.0.rounds.len(), "{ctx}: round count");
    for (x, y) in a.0.rounds.iter().zip(&b.0.rounds) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{ctx} r{}", x.round);
        assert_eq!(x.uploaded_bytes, y.uploaded_bytes, "{ctx} r{}", x.round);
        assert_eq!(x.duration.to_bits(), y.duration.to_bits(), "{ctx} r{}", x.round);
        assert_eq!(x.client_state_bytes, y.client_state_bytes, "{ctx} r{}", x.round);
        assert_eq!(x.full_broadcast, y.full_broadcast, "{ctx} r{}", x.round);
    }
    for (i, (x, y)) in a.1.iter().zip(&b.1).enumerate() {
        assert_eq!(x.data(), y.data(), "{ctx}: global tensor {i}");
    }
}

#[test]
fn engine_is_bitwise_invariant_across_workers_codecs_and_modes() {
    // The virtualized engine keeps PR-1's headline guarantee: workers,
    // codec layout and quorum-1 semi-async never change a bit — states,
    // losses, durations, global params, state-byte accounting included.
    let dir = native_dir("bitwise");
    let reference = run_once(cfg(&dir));
    for workers in [2usize, 4] {
        let mut c = cfg(&dir);
        c.workers = workers;
        assert_bitwise(&reference, &run_once(c), &format!("workers={workers}"));
    }
    for codec in ["bitmap", "coo"] {
        let mut c = cfg(&dir);
        c.codec = codec.into();
        let out = run_once(c);
        // wire bytes move with the layout; the model and the client
        // state must not.
        for (x, y) in reference.0.rounds.iter().zip(&out.0.rounds) {
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{codec}");
            assert_eq!(x.client_state_bytes, y.client_state_bytes, "{codec}");
        }
        for (i, (x, y)) in reference.1.iter().zip(&out.1).enumerate() {
            assert_eq!(x.data(), y.data(), "{codec}: global tensor {i}");
        }
    }
    {
        let mut c = cfg(&dir);
        c.round_mode = "semi_async".into();
        c.quorum = 1.0;
        c.deadline_s = 0.0;
        let out = run_once(c);
        for (x, y) in reference.0.rounds.iter().zip(&out.0.rounds) {
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "semi_async q1");
            assert_eq!(x.client_state_bytes, y.client_state_bytes, "semi_async q1");
        }
        for (i, (x, y)) in reference.1.iter().zip(&out.1).enumerate() {
            assert_eq!(x.data(), y.data(), "semi_async q1: global tensor {i}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn round_one_is_always_a_full_broadcast() {
    // Regression (Eq. 9/11 charging): with h > 1, round 1 used to be
    // charged as a mask-sparse download although no client had ever
    // received the global model. Both round modes must now flag (and
    // charge) round 1 as a full broadcast, and clients must come out of
    // it with zero residual state.
    for round_mode in ["sync", "semi_async"] {
        let dir = native_dir(&format!("r1bc_{round_mode}"));
        let mut c = cfg(&dir);
        c.h = 5; // 1 % 5 != 0 — the old predicate said "sparse"
        c.rounds = 2;
        c.eval_every = 2;
        c.round_mode = round_mode.into();
        if round_mode == "semi_async" {
            c.quorum = 1.0; // everyone arrives in-round
        }
        let mut run = FedRun::new(c).unwrap();
        let r1 = run.step_round().unwrap();
        assert!(r1.full_broadcast, "{round_mode}: round 1 not a full broadcast");
        assert_eq!(
            run.client_residual_bytes(),
            0,
            "{round_mode}: a broadcast round left residuals"
        );
        let r2 = run.step_round().unwrap();
        assert!(!r2.full_broadcast, "{round_mode}: round 2 (h=5) must be sparse");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn client_state_collapses_on_broadcast_and_stays_below_dense() {
    // The accounting contract across a broadcast/sparse/broadcast cycle:
    // * after a broadcast round every client is Synced — residuals are
    //   exactly 0 and the whole footprint is the single live snapshot;
    // * after a sparse round every client carries its complement
    //   residual — > 0 (dropout dropped something) and strictly below
    //   the dense fleet's clients × model bytes;
    // * the ring holds exactly one live snapshot after every sync round
    //   (all clients rebase together).
    let dir = native_dir("accounting");
    let mut run = FedRun::new(cfg(&dir)).unwrap();
    let dense_fleet: usize = run.clients.iter().map(|c| c.u_bytes()).sum();
    assert_eq!(run.client_residual_bytes(), 0, "fresh fleet must be Synced");
    assert_eq!(run.live_snapshot_rounds(), vec![0]);

    let r1 = run.step_round().unwrap(); // broadcast (round 1)
    assert!(r1.full_broadcast);
    assert_eq!(run.client_residual_bytes(), 0);
    assert_eq!(r1.client_state_bytes, run.snapshot_bytes());
    assert_eq!(run.live_snapshot_rounds(), vec![1]);

    let r2 = run.step_round().unwrap(); // sparse (h=3)
    assert!(!r2.full_broadcast);
    let residuals = run.client_residual_bytes();
    assert!(residuals > 0, "sparse round left no residual");
    assert!(
        residuals < dense_fleet,
        "residuals {residuals} not strictly below dense fleet {dense_fleet}"
    );
    assert_eq!(r2.client_state_bytes, residuals + run.snapshot_bytes());
    assert_eq!(run.live_snapshot_rounds(), vec![2]);

    let r3 = run.step_round().unwrap(); // broadcast again (3 % 3 == 0)
    assert!(r3.full_broadcast);
    assert_eq!(run.client_residual_bytes(), 0, "broadcast must collapse deltas");
    assert_eq!(run.live_snapshot_rounds(), vec![3]);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The pathological straggler tail both ring tests run: a low quorum
/// over a skewed fleet keeps MANY uploads (and hence base snapshots)
/// outstanding at once.
fn tail_cfg(dir: &PathBuf) -> ExpConfig {
    let mut c = cfg(dir);
    c.n_clients = 16;
    c.rounds = 1000; // stepped manually
    c.eval_every = 1000;
    c.round_mode = "semi_async".into();
    c.quorum = 0.1; // close after ~2 arrivals — the tail stays in flight
    c.deadline_s = 0.0;
    c.staleness_beta = 1.0;
    c
}

#[test]
fn snapshot_ring_accounting_under_pathological_straggler_tail() {
    // The uncapped ring (`snapshot_ring_cap = 0`, the default): an
    // in-flight client pins its pre-dispatch snapshot until its upload
    // arrives, so the tail keeps MANY snapshots alive at once. With no
    // cap the contract is exact weak-ref accounting:
    //   (1) the ring's live set is precisely the distinct base rounds
    //       still referenced by some client — nothing leaks, nothing is
    //       freed early (an `Evicted` client references nothing, but no
    //       client is ever evicted here);
    //   (2) the reported footprint decomposes into residuals + live
    //       snapshots + in-flight pending bytes, every round;
    //   (3) the hazard is real: the tail pins several snapshots at once;
    //   (4) draining the tail (quorum 1) collapses the ring back to a
    //       single live snapshot and empties the pending set.
    // The capped companion below proves the eviction gate bounds (3).
    let dir = native_dir("ring_tail");
    let mut run = FedRun::new(tail_cfg(&dir)).unwrap();
    let mut max_live = 0usize;
    for t in 1..=24 {
        let out = run.step_round().unwrap();
        let live = run.live_snapshot_rounds();
        let mut expect: Vec<usize> = run
            .clients
            .iter()
            .filter_map(|cl| cl.params.base_round())
            .collect();
        expect.sort_unstable();
        expect.dedup();
        assert_eq!(live, expect, "round {t}: ring live set drifted from client bases");
        assert_eq!(
            out.client_state_bytes,
            run.client_residual_bytes() + run.snapshot_bytes() + run.pending_bytes(),
            "round {t}: footprint does not decompose"
        );
        max_live = max_live.max(live.len());
    }
    assert_eq!(run.snapshot_evictions(), 0, "uncapped ring must never evict");
    assert!(
        max_live >= 4,
        "a pathological tail should pin several snapshots at once, saw at most {max_live}"
    );
    run.cfg.quorum = 1.0; // next close waits for every in-flight upload
    run.step_round().unwrap();
    let live = run.live_snapshot_rounds();
    assert_eq!(live.len(), 1, "drained ring must hold one live snapshot, got {live:?}");
    assert_eq!(run.pending_bytes(), 0, "nothing may stay in flight after the drain");
    assert_eq!(
        run.client_state_bytes(),
        run.client_residual_bytes() + run.snapshot_bytes()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_ring_cap_bounds_the_tail_and_charges_resyncs() {
    // The capped ring under the *same* pathological tail: the live set
    // may never exceed `snapshot_ring_cap`, the cap must actually bite
    // (evictions > 0 where the uncapped run pinned >= 4 snapshots), the
    // footprint decomposition must keep holding with evicted clients in
    // the fleet (an `Evicted` client contributes 0 resident bytes), and
    // the run must stay numerically healthy — an evicted idle client is
    // force-re-synced from the live global at its next dispatch, charged
    // as a full broadcast.
    let cap = 3usize;
    let dir = native_dir("ring_cap");
    let mut c = tail_cfg(&dir);
    c.snapshot_ring_cap = cap;
    let mut run = FedRun::new(c).unwrap();
    for t in 1..=24 {
        let out = run.step_round().unwrap();
        let live = run.live_snapshot_rounds();
        assert!(
            live.len() <= cap,
            "round {t}: {} live snapshots exceed the cap {cap}: {live:?}",
            live.len()
        );
        // Every live snapshot is still referenced by some client — the
        // cap evicts, it never leaks.
        let mut expect: Vec<usize> = run
            .clients
            .iter()
            .filter_map(|cl| cl.params.base_round())
            .collect();
        expect.sort_unstable();
        expect.dedup();
        assert_eq!(live, expect, "round {t}: capped live set drifted from client bases");
        assert_eq!(
            out.client_state_bytes,
            run.client_residual_bytes() + run.snapshot_bytes() + run.pending_bytes(),
            "round {t}: capped footprint does not decompose"
        );
    }
    assert!(
        run.snapshot_evictions() > 0,
        "the cap never bit a tail that uncapped pins >= 4 snapshots"
    );
    for t in &run.global_params {
        assert!(t.data().iter().all(|x| x.is_finite()));
    }
    // Draining the tail still collapses the ring to one live snapshot.
    run.cfg.quorum = 1.0;
    run.step_round().unwrap();
    assert_eq!(run.live_snapshot_rounds().len(), 1);
    assert_eq!(run.pending_bytes(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lazy_and_eager_data_modes_run_bitwise_identically() {
    // `data_mode = "lazy"` regenerates every training sample from the
    // seed on demand; `"eager"` materializes the same plan into a dense
    // tensor up front. The data layer proves the stores byte-identical
    // (`data::synth`); this pins the end-to-end consequence: whole runs
    // — losses, durations, uploads, globals — are bitwise equal, while
    // only the lazy run's data plane is sublinear in the sample count.
    let dir = native_dir("data_mode");
    let mut lazy_cfg = cfg(&dir);
    lazy_cfg.data_mode = "lazy".into();
    let mut eager_cfg = cfg(&dir);
    eager_cfg.data_mode = "eager".into();
    let lazy = run_once(lazy_cfg);
    let eager = run_once(eager_cfg);
    assert_bitwise(&lazy, &eager, "lazy vs eager data plane");
    let lazy_bytes = lazy.0.data_state_bytes();
    let eager_bytes = eager.0.data_state_bytes();
    assert!(
        lazy_bytes < eager_bytes,
        "lazy data plane ({lazy_bytes} B) not below eager ({eager_bytes} B)"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn semi_async_stragglers_keep_consistent_state() {
    // Deadline rounds leave uploads in flight; the in-flight clients must
    // keep their pre-dispatch base (pinning its snapshot) and rebase only
    // when they arrive — no discarded updates, no dangling snapshots,
    // finite state throughout.
    let dir = native_dir("straggler");
    let mut c = cfg(&dir);
    c.n_clients = 8;
    c.rounds = 16;
    c.eval_every = 16;
    c.round_mode = "semi_async".into();
    c.quorum = 1.0; // close on the deadline only
    c.deadline_s = 40.0; // under the slowest client's round time
    c.staleness_beta = 1.0;
    let mut run = FedRun::new(c).unwrap();
    let dense_fleet: usize = run.clients.iter().map(|x| x.u_bytes()).sum();
    let mut folded = 0usize;
    for _ in 0..16 {
        let out = run.step_round().unwrap();
        folded += out.participants;
        // The persistent per-client part (residuals) stays strictly
        // below the dense fleet; the full metric additionally counts
        // live snapshots and the in-flight pending uploads.
        assert!(run.client_residual_bytes() < dense_fleet);
        assert_eq!(
            out.client_state_bytes,
            run.client_residual_bytes() + run.snapshot_bytes() + run.pending_bytes()
        );
        // the ring only ever holds snapshots some client still references
        for r in run.live_snapshot_rounds() {
            assert!(r <= 16);
        }
    }
    assert!(folded > 0, "nothing ever folded");
    for t in &run.global_params {
        assert!(t.data().iter().all(|x| x.is_finite()));
    }
    let _ = std::fs::remove_dir_all(&dir);
}
